"""Docs gate (CI `docs` job): fail on broken intra-repo markdown links and
on missing docstrings for the public API.

Checks:
  1. every relative link target in README.md / DESIGN.md /
     benchmarks/README.md exists (http(s)/mailto and pure-anchor links are
     skipped; a trailing ``#anchor`` is stripped before the existence test);
  2. every name exported in ``repro.core.__all__`` and
     ``repro.core.observability.__all__`` carries a docstring — the
     class/function's *own* ``__doc__`` (inheritance does not count), or
     the type's docstring for exported instances (INT, FLOAT, ...).

Run locally:  python tools/check_docs.py
"""
from __future__ import annotations

import inspect
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

DOC_FILES = ["README.md", "DESIGN.md", os.path.join("benchmarks",
                                                    "README.md")]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list[str]:
    errors = []
    for rel in DOC_FILES:
        path = os.path.join(ROOT, rel)
        if not os.path.exists(path):
            errors.append(f"{rel}: file missing")
            continue
        base = os.path.dirname(path)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        # fenced code blocks contain example paths, not navigation links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for lineno_text in text.splitlines():
            for target in LINK_RE.findall(lineno_text):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                target = target.split("#", 1)[0]
                if not target:
                    continue          # pure in-page anchor
                if not os.path.exists(os.path.join(base, target)):
                    errors.append(f"{rel}: broken link -> {target}")
    return errors


def _check_module_all(modname: str) -> list[str]:
    import importlib
    mod = importlib.import_module(modname)
    errors = []
    for name in mod.__all__:
        obj = getattr(mod, name, None)
        if obj is None:
            errors.append(f"{modname}.__all__ names {name!r} "
                          f"but it is not importable")
            continue
        if inspect.isclass(obj) or inspect.isroutine(obj):
            doc = obj.__doc__           # own docstring, not inherited
        else:
            doc = type(obj).__doc__     # exported instances (INT, ...)
        if not doc or not doc.strip():
            errors.append(f"{modname}.{name}: missing docstring")
    return errors


def check_docstrings() -> list[str]:
    return (_check_module_all("repro.core")
            + _check_module_all("repro.core.observability"))


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        print(f"{len(errors)} docs check(s) failed")
        return 1
    print("docs checks OK "
          f"({len(DOC_FILES)} files linked, public API documented)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
