"""Render and validate observability artifacts from the command line
(DESIGN.md §12/§13).

Three artifact kinds, auto-detected by schema / extension:

  * Chrome trace-event JSON (``Tracer.export_chrome_trace``) — validated
    against the trace-event contract (required keys per phase type,
    numeric ts/dur, metadata before data when sorted) and summarized as
    per-track span/counter counts.  Load the same file in
    ``chrome://tracing`` or https://ui.perfetto.dev for the visual view.
  * Run reports (``build_report(...).to_json``, schema
    ``repro.run_report/v1``) — rendered as the standard human-readable
    breakdown (critical path, per-stage totals, wait percentiles,
    per-site utilization).
  * Health metrics streams (``HealthMonitor.attach_sink``, JSONL with
    schema ``repro.metrics_stream/v1``, detected by the ``.jsonl``
    extension) — validated line-by-line (schema tag, numeric
    monotone-non-decreasing ``t``, well-formed per-site entries) and
    rendered as the last line's per-site table
    (``tools/live_monitor.py`` is the live view).

Usage::

    python tools/trace_view.py trace.json            # auto-detect + render
    python tools/trace_view.py trace.json --validate # schema check only
    python tools/trace_view.py report.json --json    # re-emit normalized
    python tools/trace_view.py run.jsonl --validate  # metrics-stream check

Exit status is non-zero on a malformed artifact, so CI can gate on it
(the ``docs`` job runs this against the committed sample trace).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import Counter

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

_PHASES = {"X", "B", "E", "C", "i", "I", "M", "b", "e", "n", "s", "t", "f"}


def validate_chrome_trace(trace: dict) -> list[str]:
    """Structural validation of a Chrome trace-event JSON object; returns
    a list of problems (empty = valid)."""
    errors = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: bad phase {ph!r}")
            continue
        if "name" not in ev:
            errors.append(f"{where}: missing name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errors.append(f"{where}: {key} must be an integer")
        if ph == "M":
            continue                    # metadata events carry no ts
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"{where}: ts must be numeric")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            errors.append(f"{where}: complete event missing numeric dur")
        if ph == "C" and "args" not in ev:
            errors.append(f"{where}: counter event missing args")
        if len(errors) > 20:
            errors.append("... (truncated)")
            break
    return errors


_METRICS_SCHEMA = "repro.metrics_stream/v1"
_SITE_REQUIRED = ("state", "error_rate", "window_completions",
                  "outstanding", "queue")
_SITE_STATES = {"healthy", "degraded", "drained", "blacklisted"}


def validate_metrics_stream(lines: list[str]) -> list[str]:
    """Line-by-line validation of a ``repro.metrics_stream/v1`` JSONL
    stream (``HealthMonitor.attach_sink`` output); returns a list of
    problems (empty = valid).  Line numbers are 1-based."""
    errors = []
    n_valid = 0
    last_t = None
    for lineno, raw in enumerate(lines, 1):
        where = f"line {lineno}"
        raw = raw.strip()
        if not raw:
            continue
        try:
            obj = json.loads(raw)
        except ValueError as e:
            errors.append(f"{where}: not valid JSON ({e})")
            continue
        if not isinstance(obj, dict):
            errors.append(f"{where}: not an object")
            continue
        if obj.get("schema") != _METRICS_SCHEMA:
            errors.append(f"{where}: schema={obj.get('schema')!r}, "
                          f"expected {_METRICS_SCHEMA!r}")
            continue
        t = obj.get("t")
        if not isinstance(t, (int, float)) or isinstance(t, bool):
            errors.append(f"{where}: 't' must be numeric")
            continue
        if last_t is not None and t < last_t:
            errors.append(f"{where}: 't' went backwards "
                          f"({t} < {last_t})")
        last_t = t
        sites = obj.get("sites")
        if not isinstance(sites, dict):
            errors.append(f"{where}: 'sites' missing or not an object")
            continue
        for name, entry in sites.items():
            if not isinstance(entry, dict):
                errors.append(f"{where}: site {name!r} entry not an "
                              f"object")
                continue
            missing = [k for k in _SITE_REQUIRED if k not in entry]
            if missing:
                errors.append(f"{where}: site {name!r} missing keys "
                              f"{missing}")
            state = entry.get("state")
            if state not in _SITE_STATES:
                errors.append(f"{where}: site {name!r} bad state "
                              f"{state!r}")
            er = entry.get("error_rate")
            if not isinstance(er, (int, float)) or isinstance(er, bool) \
                    or not 0.0 <= er <= 1.0:
                errors.append(f"{where}: site {name!r} error_rate "
                              f"{er!r} not in [0, 1]")
        for key in ("backlog", "inflight", "tracked", "stragglers",
                    "revoked", "transitions"):
            v = obj.get(key)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                errors.append(f"{where}: {key!r} must be a non-negative "
                              f"integer (got {v!r})")
        n_valid += 1
        if len(errors) > 20:
            errors.append("... (truncated)")
            break
    if n_valid == 0 and not errors:
        errors.append("no metrics-stream lines found")
    return errors


def summarize_chrome_trace(trace: dict) -> str:
    events = trace["traceEvents"]
    procs: dict[int, str] = {}
    threads: dict[tuple, str] = {}
    by_kind: Counter = Counter()
    per_track: Counter = Counter()
    t_max = 0.0
    for ev in events:
        ph = ev["ph"]
        if ph == "M":
            if ev["name"] == "process_name":
                procs[ev["pid"]] = ev["args"]["name"]
            elif ev["name"] == "thread_name":
                threads[(ev["pid"], ev["tid"])] = ev["args"]["name"]
            continue
        by_kind[ph] += 1
        per_track[(ev["pid"], ev.get("tid", 0))] += 1
        end = ev.get("ts", 0.0) + ev.get("dur", 0.0)
        if end > t_max:
            t_max = end
    lines = [f"chrome trace: {len(events)} events, "
             f"{len(procs)} tracks, span {t_max / 1e6:.3f} s"]
    other = trace.get("otherData", {})
    if other:
        keys = ("tasks_seen", "tasks_done", "tasks_failed",
                "critical_path_s", "sample_stride")
        known = {k: other[k] for k in keys if k in other}
        if known:
            lines.append("  run: " + ", ".join(
                f"{k}={v}" for k, v in known.items()))
    lines.append("  events by phase: " + ", ".join(
        f"{k}={v}" for k, v in sorted(by_kind.items())))
    for (pid, tid), n in sorted(per_track.items()):
        pname = procs.get(pid, f"pid{pid}")
        tname = threads.get((pid, tid), "" if tid == 0 else f"tid{tid}")
        label = f"{pname}/{tname}" if tname else pname
        lines.append(f"  track {label:<32} {n} events")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="render/validate repro traces and run reports")
    ap.add_argument("path", help="chrome trace or run-report JSON file")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only, no rendering")
    ap.add_argument("--json", action="store_true",
                    help="re-emit the parsed artifact as normalized JSON")
    args = ap.parse_args(argv)

    if args.path.endswith(".jsonl"):
        with open(args.path, encoding="utf-8") as f:
            lines = f.readlines()
        errors = validate_metrics_stream(lines)
        for e in errors:
            print(f"FAIL {e}")
        if errors:
            print(f"{len(errors)} metrics-stream problem(s) in "
                  f"{args.path}")
            return 1
        snaps = [json.loads(ln) for ln in lines if ln.strip()]
        if args.json:
            json.dump(snaps, sys.stdout, indent=2)
            print()
        elif args.validate:
            print(f"valid metrics stream: {args.path} "
                  f"({len(snaps)} lines)")
        else:
            from live_monitor import render_table
            print(render_table(snaps[-1]))
        return 0

    with open(args.path, encoding="utf-8") as f:
        data = json.load(f)

    if "traceEvents" in data:
        errors = validate_chrome_trace(data)
        for e in errors:
            print(f"FAIL {e}")
        if errors:
            print(f"{len(errors)} trace problem(s) in {args.path}")
            return 1
        if args.json:
            json.dump(data, sys.stdout, indent=2)
            print()
        elif args.validate:
            print(f"valid chrome trace: {args.path} "
                  f"({len(data['traceEvents'])} events)")
        else:
            print(summarize_chrome_trace(data))
        return 0

    from repro.core.observability import REPORT_SCHEMA, RunReport
    schema = data.get("schema")
    if schema != REPORT_SCHEMA:
        print(f"FAIL {args.path}: unrecognized artifact "
              f"(schema={schema!r}; expected a chrome trace with "
              f"'traceEvents' or a {REPORT_SCHEMA} report)")
        return 1
    required = ("makespan_s", "tasks", "critical_path_s", "stages",
                "percentiles", "utilization")
    missing = [k for k in required if k not in data]
    if missing:
        print(f"FAIL {args.path}: report missing keys {missing}")
        return 1
    if args.json:
        json.dump(data, sys.stdout, indent=2)
        print()
    elif args.validate:
        print(f"valid run report: {args.path} "
              f"({data['tasks']['done']} tasks done)")
    else:
        print(RunReport(data).format())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
