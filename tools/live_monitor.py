"""Tail the HealthMonitor JSONL metrics stream as a live per-site table
(DESIGN.md §13).

The stream (schema ``repro.metrics_stream/v1``) is produced by
``HealthMonitor.attach_sink("run.jsonl")`` — one JSON object per cadence
with per-site health state, windowed error rates, queue depths, and
straggler/revocation counters.  This tool renders the latest line as a
table and, with ``--follow``, keeps polling the file so a run can be
watched while it executes::

    python tools/live_monitor.py run.jsonl             # follow (default)
    python tools/live_monitor.py run.jsonl --once      # render last line
    python tools/live_monitor.py run.jsonl --interval 0.5

Lines that fail to parse are skipped with a warning on stderr (a writer
may be mid-line); `tools/trace_view.py <file>.jsonl --validate` is the
strict schema check.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

_STATE_MARK = {"healthy": " ", "degraded": "~", "drained": "!",
               "blacklisted": "X"}


def render_table(snap: dict) -> str:
    """Render one metrics-stream record as a fixed-width per-site table."""
    lines = [
        f"t={snap.get('t', 0.0):10.2f}s   "
        f"backlog={snap.get('backlog', 0):<6} "
        f"inflight={snap.get('inflight', 0):<6} "
        f"tracked={snap.get('tracked', 0):<6} "
        f"stragglers={snap.get('stragglers', 0):<4} "
        f"revoked={snap.get('revoked', 0):<5} "
        f"transitions={snap.get('transitions', 0)}",
        f"{'':1} {'site':<12} {'state':<12} {'err%':>6} {'n':>6} "
        f"{'tasks/s':>8} {'ewma_s':>8} {'p95_s':>8} {'util':>6} "
        f"{'queue':>6} {'strag':>5} {'rvk':>5} {'susp_s':>7}",
    ]
    for name, s in sorted(snap.get("sites", {}).items()):
        mark = _STATE_MARK.get(s.get("state", ""), "?")
        lines.append(
            f"{mark} {name:<12} {s.get('state', '?'):<12} "
            f"{100.0 * s.get('error_rate', 0.0):>6.1f} "
            f"{s.get('window_completions', 0):>6} "
            f"{s.get('tasks_per_s', 0.0):>8.2f} "
            f"{s.get('latency_ewma_s', 0.0):>8.2f} "
            f"{s.get('latency_p95_s', 0.0):>8.2f} "
            f"{100.0 * s.get('utilization', 0.0):>5.0f}% "
            f"{s.get('queue', 0):>6} "
            f"{s.get('stragglers', 0):>5} "
            f"{s.get('revoked', 0):>5} "
            f"{s.get('suspended_for_s', 0.0):>7.1f}")
    alerts = snap.get("alerts")
    if alerts:
        parts = [f"{k}: {v.get('count', 0)} in {v.get('window_s', 0):g}s"
                 for k, v in sorted(alerts.items())]
        lines.append("  alerts: " + ", ".join(parts))
    return "\n".join(lines)


def _parse_lines(chunk: str) -> list[dict]:
    """Parse complete JSONL lines from `chunk`, skipping malformed ones."""
    snaps = []
    for ln in chunk.splitlines():
        ln = ln.strip()
        if not ln:
            continue
        try:
            obj = json.loads(ln)
        except ValueError:
            print(f"live_monitor: skipping malformed line: {ln[:60]}...",
                  file=sys.stderr)
            continue
        if isinstance(obj, dict):
            snaps.append(obj)
    return snaps


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="live per-site health table from a metrics-stream "
                    "JSONL file")
    ap.add_argument("path", help="metrics-stream JSONL file "
                                 "(HealthMonitor.attach_sink output)")
    ap.add_argument("--once", action="store_true",
                    help="render the last valid line and exit")
    ap.add_argument("--follow", action="store_true",
                    help="poll for new lines (default unless --once)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="poll interval in seconds (default 1.0)")
    args = ap.parse_args(argv)

    if args.once:
        with open(args.path, encoding="utf-8") as f:
            snaps = _parse_lines(f.read())
        if not snaps:
            print(f"no valid metrics-stream lines in {args.path}",
                  file=sys.stderr)
            return 1
        print(render_table(snaps[-1]))
        return 0

    # follow mode: re-read from the last offset, render the newest line
    last = None
    offset = 0
    try:
        while True:
            try:
                with open(args.path, encoding="utf-8") as f:
                    f.seek(offset)
                    chunk = f.read()
                    offset = f.tell()
            except FileNotFoundError:
                chunk = ""
            snaps = _parse_lines(chunk)
            if snaps:
                last = snaps[-1]
            if last is not None:
                # clear screen + home, then the current table
                sys.stdout.write("\x1b[2J\x1b[H")
                print(render_table(last))
                sys.stdout.flush()
            time.sleep(args.interval)
    except KeyboardInterrupt:
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
