"""Quickstart: the layers of the framework in one script.

1. SwiftScript-style workflow: typed datasets, dynamic foreach, futures.
2. Real execution: the same program on actual worker threads (RealClock).
3. JAX model zoo: one forward/train step of an assigned architecture.
4. Pallas kernel vs its oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, RealClock, SimClock,
                        ThreadExecutorPool, Workflow)


def demo_workflow():
    print("== 1. Workflow: dynamic dataflow over futures ==")
    clock = SimClock()
    engine = Engine(clock)
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=16, alloc_latency=5.0)))
    engine.add_site("pod0", FalkonProvider(svc), capacity=16)
    wf = Workflow("demo", engine)

    @wf.atomic
    def square(x):
        return x * x

    @wf.atomic
    def total(xs):
        return sum(xs)

    squares = wf.foreach(list(range(10)), lambda x: square(x))
    result = total(squares)
    wf.run()
    print(f"   sum of squares = {result.get()}  "
          f"(dispatched {svc.utilization()['dispatched']} tasks, "
          f"makespan {clock.now():.2f}s virtual)")


def demo_real_execution():
    print("== 2. Real execution: same program, actual worker threads ==")
    clock = RealClock()
    pool = ThreadExecutorPool(clock)      # DRP acquires real threads
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=8, alloc_latency=0.0, alloc_chunk=8)),
        pool=pool)
    engine = Engine(clock)
    engine.add_site("pod0", FalkonProvider(svc), capacity=8)
    wf = Workflow("real", engine)

    @wf.atomic
    def square(x):
        return x * x

    @wf.atomic
    def total(xs):
        return sum(xs)

    result = total(wf.foreach(list(range(10)), lambda x: square(x)))
    wf.run()
    svc.shutdown()
    print(f"   sum of squares = {result.get()}  "
          f"({pool.tasks_run} bodies on {len(svc.executors)} real workers, "
          f"{clock.now() * 1e3:.1f} ms wall)")


def demo_model():
    print("== 3. Model zoo: one train step of qwen2-1.5b (reduced) ==")
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.models.params import init_tree
    from repro.optim import adamw
    from repro.train.steps import make_train_step

    cfg = registry.smoke_config("qwen2-1.5b")
    params = init_tree(T.build_descriptors(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    step = jax.jit(make_train_step(cfg, adamw.Hyper(lr=1e-3)))
    params, opt, metrics = step(params, adamw.init(params), batch,
                                jnp.zeros((), jnp.int32))
    print(f"   loss={float(metrics['loss']):.3f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")


def demo_kernel():
    print("== 4. Pallas flash-attention kernel (interpret mode on CPU) ==")
    from repro.kernels import ops, ref
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 128, 64))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 128, 64))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 128, 64))
    out = ops.flash_attention(q, k, v, causal=True, window=64,
                              block_q=64, block_k=64)
    exp = ref.ref_attention(q, k, v, causal=True, window=64)
    err = float(jnp.max(jnp.abs(out - exp)))
    print(f"   kernel vs oracle max err = {err:.2e}")


if __name__ == "__main__":
    demo_workflow()
    demo_real_execution()
    demo_model()
    demo_kernel()
    print("quickstart OK")
