"""The paper's Montage mosaic workflow (Figs 2/3) with real JAX compute:
the overlap table is COMPUTED at runtime, written as a '|'-delimited file,
mapped back in with CSVMapper, and the mDiffFit stage fans out over it —
the dynamic-workflow-structure case that static-DAG systems cannot express.

Run:  PYTHONPATH=src python examples/montage_workflow.py [--images N]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CSVMapper, Dataset, Engine, INT, RealClock, STRING,
                        Struct, Workflow)

TILE = 16
DiffStruct = Struct("DiffStruct", (
    ("cntr1", INT), ("cntr2", INT), ("plus", STRING), ("minus", STRING),
    ("diff", STRING)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=16)
    args = ap.parse_args()
    n = args.images

    engine = Engine(RealClock())
    engine.local_site(concurrency=4)
    wf = Workflow("montage", engine)
    rng = np.random.default_rng(1)
    raw = [jnp.asarray(rng.standard_normal((TILE, TILE)).astype(np.float32))
           + 0.3 * i for i in range(n)]

    @wf.atomic
    def mProjectPP(img):
        # reproject into the common frame (here: a fixed linear warp)
        return jnp.flipud(img) * 0.98 + 0.01

    @wf.atomic
    def mOverlaps(imgs, workdir):
        # images overlap if adjacent: structure ONLY known at runtime
        path = os.path.join(workdir, "diffs.tbl")
        with open(path, "w") as f:
            f.write("cntr1|cntr2|plus|minus|diff\n")
            for i in range(len(imgs) - 1):
                f.write(f"{i}|{i+1}|p_{i}.fits|p_{i+1}.fits|"
                        f"diff.{i:06d}.{i+1:06d}.fits\n")
        return Dataset(CSVMapper(path, header=True, hdelim="|",
                                 types=DiffStruct), "diffs")

    @wf.atomic
    def mDiffFit(rec, imgs):
        a, b = imgs[rec["cntr1"]], imgs[rec["cntr2"]]
        d = a - b
        return jnp.array([d.mean(), d.std()])

    @wf.atomic
    def mBgModel(fits):
        return jnp.stack(fits).mean(axis=0)

    @wf.atomic
    def mBackground(img, model):
        return img - model[0]

    @wf.atomic
    def mAdd(imgs):
        return jnp.stack(imgs).mean(axis=0)

    with tempfile.TemporaryDirectory() as workdir:
        projected = wf.gather([mProjectPP(im) for im in raw])
        tbl = mOverlaps(projected, workdir)
        # dynamic fan-out: row count is a RUNTIME property of tbl
        fits = wf.foreach(tbl, lambda rec: mDiffFit(rec, projected))
        model = mBgModel(fits)
        rectified = wf.foreach(projected,
                               lambda im: mBackground(im, model))
        # conditional co-add strategy on runtime size (paper §3.6)
        big = engine.submit("is_big", lambda ims: len(ims) > 8, [rectified])

        def coadd_subregions():
            sub = 4
            def part(i):
                return wf.when(rectified, lambda i=i: mAdd(
                    rectified.get()[i::sub]))
            parts = wf.gather([part(i) for i in range(sub)])
            return wf.when(parts, lambda: mAdd(parts.get()))

        mosaic = wf.when(big, coadd_subregions,
                         lambda: mAdd(rectified.get()))
        wf.run()

    m = mosaic.get()
    print(f"montage: {n} images, mosaic shape {m.shape}, "
          f"mean {float(m.mean()):+.4f}")
    print(f"engine: {engine.stats()}")
    n_diff = len(engine.vdc.by_task("mDiffFit"))
    print(f"dynamic expansion created {n_diff} mDiffFit tasks at runtime")
    assert n_diff == n - 1


if __name__ == "__main__":
    main()
