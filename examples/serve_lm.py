"""Serving example: prefill + batched greedy decode of an assigned arch
(reduced config), with the KV-cache machinery the decode_32k / long_500k
dry-run cells exercise at production scale.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen2-1.5b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import transformer as T
from repro.models.params import init_tree
from repro.train.steps import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=registry.ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch)
    params = init_tree(T.build_descriptors(cfg), jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    enc = jax.random.normal(jax.random.PRNGKey(2),
                            (B, cfg.enc_frames, cfg.d_model),
                            jnp.float32) if cfg.enc_dec else None

    # --- prefill: build caches sized for the full generation -------------
    total = P + args.new_tokens
    pf = make_prefill_step(cfg)
    batch = {"tokens": prompts}
    if enc is not None:
        batch["enc_feats"] = enc
    t0 = time.monotonic()
    logits, caches = pf(params, batch)
    # grow global caches to `total` (prefill sizes them to the prompt)
    caches = jax.tree_util.tree_map(
        lambda x: _grow(x, P, total), caches)
    t_prefill = time.monotonic() - t0

    # --- batched greedy decode -------------------------------------------
    sv = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.monotonic()
    for i in range(args.new_tokens - 1):
        tok, caches = sv(params, caches, tok, jnp.asarray(P + i, jnp.int32))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.monotonic() - t0
    gen = jnp.concatenate(out, axis=1)

    print(f"arch={args.arch} prefill({B}x{P})={t_prefill*1e3:.0f}ms, "
          f"decode {args.new_tokens - 1} steps = {t_decode*1e3:.0f}ms "
          f"({t_decode/(args.new_tokens-1)*1e3:.1f} ms/tok)")
    print("generated token ids (first sequence):",
          [int(t) for t in gen[0][:12]])


def _grow(x, cur_len, total):
    """Pad sequence-dim-2 caches (k/v/c_kv/k_rope stacked as (reps,B,T,...))
    from prompt length to the full generation length."""
    if hasattr(x, "ndim") and x.ndim >= 3 and x.shape[2] == cur_len:
        pad = [(0, 0)] * x.ndim
        pad[2] = (0, total - cur_len)
        if x.dtype == jnp.int32:  # ring position slots: invalid marker
            return jnp.pad(x, pad, constant_values=-1)
        return jnp.pad(x, pad)
    return x


if __name__ == "__main__":
    main()
