"""Data-aware workflow quickstart: declaring file inputs so Falkon's data
layer (DESIGN.md §7, paper §6 "data diffusion") can serve repeated reads
from executor-local caches and steer tasks to the executors holding them.

A foreach over molecules re-reads a shared parameter database plus a
per-molecule archive.  Declared via `inputs=`, the data layer stages each
file from the shared store once, caches it on the staging executor, and
routes subsequent tasks for the same file there — compare the cache
hit-rate and staged bytes against the locality-blind baseline (a zero-
capacity cache: same staging cost model, nothing retained).

Run:  PYTHONPATH=src python examples/data_aware_workflow.py
"""
from repro.core import (DataLayer, DRPConfig, Engine, FalkonConfig,
                        FalkonProvider, FalkonService, SharedStore, SimClock,
                        StagingCostModel, Workflow)

MOLECULES = 24
REREADS = 16            # tasks per molecule (all read the same archive)
EXECUTORS = 8


def run_workflow(cache_mb: float):
    clock = SimClock()
    shared = SharedStore()
    layer = DataLayer(shared, StagingCostModel(),
                      cache_capacity=cache_mb * 1e6, policy="lru")
    service = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=EXECUTORS, alloc_latency=5.0,
                      alloc_chunk=EXECUTORS)), data_layer=layer)
    engine = Engine(clock)
    engine.add_site("pod0", FalkonProvider(service), capacity=EXECUTORS)
    wf = Workflow("data-aware", engine)

    params = shared.file("params.db", 50e6)
    archives = [shared.file(f"mol{m}.arc", 100e6) for m in range(MOLECULES)]

    @wf.atomic(duration=0.2, inputs=lambda m: (params, archives[m]))
    def analyze(m):
        return m

    results = wf.foreach(list(range(MOLECULES)),
                         lambda m: [analyze(m) for _ in range(REREADS)])
    wf.run()
    assert results.resolved
    return clock.now(), layer.metrics()


def main():
    print(f"== {MOLECULES} molecules x {REREADS} re-reads on "
          f"{EXECUTORS} executors ==")
    t_blind, m_blind = run_workflow(cache_mb=0.0)
    t_aware, m_aware = run_workflow(cache_mb=400.0)
    for label, t, m in (("locality-blind (GPFS every read)", t_blind, m_blind),
                        ("data diffusion (400 MB caches)", t_aware, m_aware)):
        print(f"   {label}:")
        print(f"     makespan {t:8.1f} virtual s | hit rate "
              f"{m['hit_rate']:5.1%} | staged {m['bytes_staged'] / 1e9:6.1f} "
              f"GB | local {m['bytes_local'] / 1e9:6.1f} GB")
    print(f"   speedup {t_blind / t_aware:.2f}x, staged bytes cut "
          f"{m_blind['bytes_staged'] / max(1.0, m_aware['bytes_staged']):.0f}x")


if __name__ == "__main__":
    main()
