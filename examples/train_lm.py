"""End-to-end driver: train a ~100M-parameter LM through the workflow engine.

The trainer runs every unit of work (data staging, train steps, evals,
checkpoints) as engine tasks linked by futures; checkpoints form a
data-availability restart log, so killing and re-running this script resumes
where it left off.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps N] [--tiny]
"""
import argparse
import dataclasses
import os

from repro.configs import registry
from repro.configs.base import LayerSpec, ModelConfig
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig

# ~100M-parameter dense LM (qwen-family reduced depth/width)
DENSE = LayerSpec(mixer="attn", ffn="dense")
CONFIG_100M = ModelConfig(
    name="lm-100m",
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=32000,
    blocks=(((DENSE,), 8),),
    tie_embeddings=True,
    compute_dtype="float32",   # CPU execution
    loss_chunk=128,
    attn_q_block=128,
    attn_kv_block=128,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true",
                    help="2-layer 10M model for a fast demo")
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = CONFIG_100M
    if args.tiny:
        cfg = dataclasses.replace(cfg, name="lm-10m", d_model=256, d_ff=1024,
                                  blocks=(((DENSE,), 2),), vocab=8000)
    n = cfg.param_count()
    print(f"model {cfg.name}: {n/1e6:.1f}M params, "
          f"{cfg.n_layers} layers")

    hp = adamw.Hyper(lr=3e-4, warmup=20, total_steps=args.steps,
                     weight_decay=0.1, clip=1.0)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq, seed=17)
    tr = Trainer(cfg, hp, dcfg, args.workdir,
                 TrainerConfig(total_steps=args.steps, ckpt_every=50,
                               eval_every=25, log_every=10))
    hist = tr.fit()
    train_rows = [h for h in hist if "loss" in h]
    for h in train_rows[:: max(1, len(train_rows) // 10)]:
        print(f"  step {h['step']:4d} loss={h['loss']:.4f} "
              f"({h['step_time']*1e3:.0f} ms/step)")
    evals = [h for h in hist if "eval_loss" in h]
    if evals:
        print(f"  eval: first={evals[0]['eval_loss']:.4f} "
              f"last={evals[-1]['eval_loss']:.4f}")
    first, last = train_rows[0]["loss"], train_rows[-1]["loss"]
    print(f"train loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    print(f"engine: {tr.engine_stats}")


if __name__ == "__main__":
    main()
