"""Federated workflow quickstart (DESIGN.md §8): one workflow sharded
across a 4-shard `FederatedEngine` — each shard a full engine with its own
Falkon service — under a deliberately *skewed* partitioner (70% of task
keys land on shard 0).

Without work stealing, shard 0 becomes the makespan while the other three
pods idle.  With the `WorkStealer`, idle shards migrate steal-half batches
of shard 0's pending-ready backlog and every pod stays busy; the sharded
data layer's cross-shard directory prices the archives those stolen tasks
must re-stage in their new shard.

The second experiment swaps in the affinity-aware `inputs_partitioner`:
tasks are routed by their declared `DataObject` inputs instead of by task
key, so every task reading the same molecule archive lands on one shard —
that shard caches the archive once, instead of all four shards staging
their own replica from the shared store.

Run:  PYTHONPATH=src python examples/federated_workflow.py
"""
from repro.core import (DRPConfig, FalkonConfig, FalkonProvider,
                        FalkonService, FederatedEngine, ShardedDataLayer,
                        SimClock, Workflow, hash_partitioner,
                        inputs_partitioner, skewed_partitioner)

SHARDS = 4
EXECUTORS = 16          # per shard
MOLECULES = 48
TASKS = 3_000
ROUNDS = 3


def run_campaign(steal: bool, partitioner=None):
    clock = SimClock()
    sdl = ShardedDataLayer(SHARDS, cache_capacity=400e6, park_patience=8.0)
    fed = FederatedEngine(SHARDS, clock=clock,
                          partitioner=partitioner or skewed_partitioner(0.7),
                          data_layer=sdl, steal=steal)
    services = []
    for i, eng in enumerate(fed.shards):
        svc = FalkonService(clock, FalkonConfig(
            drp=DRPConfig(max_executors=EXECUTORS, alloc_latency=5.0,
                          alloc_chunk=EXECUTORS)),
            data_layer=sdl.layer(i))
        eng.add_site(f"pod{i}", FalkonProvider(svc), capacity=EXECUTORS,
                     data_layer=sdl.layer(i))
        services.append(svc)

    wf = Workflow("federated", fed)
    archives = [sdl.shared.file(f"mol{m}.arc", 100e6)
                for m in range(MOLECULES)]

    @wf.atomic(duration=1.0, inputs=lambda m, *_: (archives[m],))
    def analyze(m, *_barrier):
        return m

    barrier = None
    per_round = TASKS // ROUNDS
    for _ in range(ROUNDS):
        futs = [analyze(j % MOLECULES) if barrier is None
                else analyze(j % MOLECULES, barrier)
                for j in range(per_round)]
        barrier = wf.gather(futs)
    fed.run()
    assert barrier.resolved
    return clock.now(), fed, services


def main():
    print(f"== skewed fan-out: {TASKS} tasks, 70% keyed to shard 0, "
          f"{SHARDS} shards x {EXECUTORS} executors ==")
    for steal in (False, True):
        span, fed, services = run_campaign(steal)
        per_shard = fed.stats()["per_shard_completed"]
        label = "work stealing ON " if steal else "work stealing OFF"
        print(f"   {label}: makespan {span:8.1f} virtual s")
        for i, (svc, done) in enumerate(zip(services, per_shard)):
            busy = sum(e.busy_time for e in svc.executors)
            frac = busy / (EXECUTORS * max(span - 5.0, 1e-9))
            print(f"     shard {i}: {done:5d} tasks "
                  f"({done / span:6.1f} tasks/s), busy {frac:5.1%}")
        if steal:
            st = fed.metrics()["stealer"]
            print(f"     steals: {st['steals']} batches, "
                  f"{st['tasks_stolen']} tasks migrated, "
                  f"~{st['restage_bytes_est'] / 1e9:.1f} GB re-staged "
                  f"in new shards")

    print(f"\n== partitioning by declared inputs (affinity-aware) ==")
    for name, part in (("hash by task key ", hash_partitioner),
                       ("by declared input", inputs_partitioner)):
        span, fed, services = run_campaign(steal=True, partitioner=part)
        data = fed.metrics()["data"]
        print(f"   {name}: makespan {span:8.1f} virtual s, "
              f"staged {data['bytes_staged'] / 1e9:6.1f} GB from shared "
              f"store, cache hit rate "
              f"{data['hits'] / max(1, data['hits'] + data['misses']):5.1%}")


if __name__ == "__main__":
    main()
