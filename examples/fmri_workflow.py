"""The paper's fMRI spatial-normalization workflow (Fig 1) with real JAX
compute bodies: reorient (axis permutation), alignlinear (least-squares
affine fit), reslice (grid resample) over synthetic brain volumes mapped
from the filesystem via XDTM.

Run:  PYTHONPATH=src python examples/fmri_workflow.py [--volumes N]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Dataset, Engine, FileSystemMapper, RealClock,
                        Workflow)


def make_dataset(root: str, prefix: str, n: int, shape=(8, 8, 8)):
    """Write .img/.hdr volume pairs (the paper's physical representation)."""
    rng = np.random.default_rng(0)
    for i in range(n):
        vol = rng.standard_normal(shape).astype(np.float32)
        vol.tofile(os.path.join(root, f"{prefix}_{i:03d}.img"))
        with open(os.path.join(root, f"{prefix}_{i:03d}.hdr"), "w") as f:
            f.write(f"shape={shape}\ndtype=float32\n")
    return Dataset(FileSystemMapper(root, prefix), prefix)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--volumes", type=int, default=24)
    args = ap.parse_args()

    shape = (8, 8, 8)
    engine = Engine(RealClock())
    engine.local_site(concurrency=4)
    wf = Workflow("fmri", engine)

    def load(vol):
        return jnp.asarray(np.fromfile(vol["img"].path,
                                       dtype=np.float32).reshape(shape))

    @wf.atomic
    def reorient(vol, axes):
        x = load(vol) if isinstance(vol, dict) else vol
        return jnp.transpose(x, axes)

    @wf.atomic
    def alignlinear(ref, x):
        # least-squares scalar affine fit x ~ a*ref + b (an "air" parameter)
        A = jnp.stack([ref.ravel(), jnp.ones(ref.size)], axis=1)
        coef, *_ = jnp.linalg.lstsq(A, x.ravel(), rcond=None)
        return coef

    @wf.atomic
    def reslice(x, air):
        return x * air[0] + air[1]

    def reorientRun(run, axes):  # compound procedure (paper lines 13-18)
        return wf.foreach(run, lambda v: reorient(v, axes))

    with tempfile.TemporaryDirectory() as root:
        bold1 = make_dataset(root, "bold1", args.volumes, shape)
        yr = reorientRun(bold1, (1, 0, 2))
        xr = wf.foreach(yr, lambda v: reorient(v, (1, 0, 2)))

        # align every volume to the first; then reslice (paper lines 19-25)
        def align_and_reslice(vols):
            ref = vols[0]
            airs = [alignlinear(ref, v) for v in vols]
            return wf.gather([reslice(v, a) for v, a in zip(vols, airs)])

        done = wf.foreach(xr, lambda v: v)  # materialize collection future
        out = wf.when(engine.submit("nonempty", lambda vs: len(vs) > 0,
                                    [done]),
                      lambda: align_and_reslice(done.get()))
        wf.run()

    resliced = out.get()
    print(f"fMRI workflow: {args.volumes} volumes -> {len(resliced)} "
          f"resliced volumes, engine stats: {engine.stats()}")
    vdc = engine.vdc.summary()
    print(f"provenance: {vdc['invocations']} invocations recorded, "
          f"{vdc['failed']} failures")
    assert len(resliced) == args.volumes


if __name__ == "__main__":
    main()
