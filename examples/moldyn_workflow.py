"""The paper's MolDyn free-energy workflow (§5.4.3) — 1 + 84N jobs — with
small JAX compute bodies standing in for CHARMM/Antechamber/WHAM, executed
through Falkon with dynamic resource provisioning and a restart log.

Run:  PYTHONPATH=src python examples/moldyn_workflow.py [--molecules N]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, RealClock, RestartLog, Workflow)

N_CHARMM = 17  # scaled from the paper's 68 parallel CHARMM jobs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--molecules", type=int, default=8)
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    workdir = args.workdir or tempfile.mkdtemp(prefix="moldyn_")
    clock = RealClock()
    engine = Engine(clock, restart_log=RestartLog(
        os.path.join(workdir, "restart.log")))
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=8, alloc_latency=0.0)))
    engine.add_site("cluster", FalkonProvider(svc), capacity=8)
    wf = Workflow("moldyn", engine)

    @wf.atomic(durable=True)
    def annotate(mol_id):
        rng = np.random.default_rng(mol_id)
        return float(rng.standard_normal())  # "charges"

    @wf.atomic
    def antechamber(charge, mol_id):
        # per-molecule topology from the shared charge annotation
        return [float(charge) * (k + 1) + 0.1 * mol_id for k in range(4)]

    @wf.atomic
    def charmm_equilibrate(topo):
        x = jnp.asarray(topo)
        return [float(v) for v in jnp.tanh(x)]

    @wf.atomic
    def charmm_pert(state, lam):
        x = jnp.asarray(state)
        e = float(jnp.sum(jnp.exp(-lam * x ** 2)))
        return e

    @wf.atomic(durable=True)
    def wham(energies, mol_id):
        e = jnp.asarray(energies)
        # free energy estimate from the perturbation energies
        return float(-jnp.log(jnp.mean(jnp.exp(-e / e.std()))))

    def molecule(mol_id, charges):
        topo = antechamber(charges, mol_id)
        eq = charmm_equilibrate(topo)
        lams = [0.1 + 0.05 * k for k in range(N_CHARMM)]
        energies = wf.gather([charmm_pert(eq, lam) for lam in lams])
        return wham(energies, mol_id)

    charges = annotate(0)  # stage 1: once for all molecules
    results = wf.gather([molecule(m, charges)
                         for m in range(args.molecules)])
    wf.run()

    energies = results.get()
    print(f"moldyn: {args.molecules} molecules -> free energies "
          f"{[f'{e:.3f}' for e in energies[:5]]}...")
    u = svc.utilization()
    print(f"falkon: {u['dispatched']} tasks dispatched, "
          f"efficiency {u['efficiency']:.1%}, "
          f"restored from restart log: {engine.stats()['restored_from_log']}")
    print(f"(re-run this script with --workdir {workdir} to see the "
          f"restart log skip the durable stages)")


if __name__ == "__main__":
    main()
