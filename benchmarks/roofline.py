"""§Roofline: three-term roofline table from the dry-run artifacts.

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun) and
emits the per-(arch x shape x mesh) table: compute/memory/collective terms in
seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS useful ratio, and the
roofline-bound MFU.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, save_json

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def load_cells(mesh: str | None = "16x16") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh is None or rec.get("mesh") == mesh:
            cells.append(rec)
    return cells


def table(mesh="16x16") -> list[dict]:
    rows = []
    for rec in load_cells(mesh):
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "kind": rec["kind"],
            "compute_s": r["compute_term_s"],
            "memory_s": r["memory_term_s"],
            "collective_s": r["collective_term_s"],
            "dominant": r["dominant"],
            "useful_ratio": r.get("useful_flops_ratio"),
            "mfu_bound": r.get("mfu_bound"),
            "hbm_gb_per_dev": (rec["memory"]["argument_bytes"] or 0) / 2**30,
        })
    return rows


def run() -> list[dict]:
    rows = table("16x16")
    save_json("roofline_table", rows)
    if not rows:
        return [{"name": "roofline.table", "us_per_call": 0.0,
                 "derived": "no dry-run artifacts found — run "
                            "python -m repro.launch.dryrun --all first"}]
    n_dom = {}
    for r in rows:
        n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    worst = min((r for r in rows if r["mfu_bound"]),
                key=lambda r: r["mfu_bound"])
    return [{
        "name": "roofline.table",
        "us_per_call": 0.0,
        "derived": (f"{len(rows)} cells; dominant: {n_dom}; worst "
                    f"mfu_bound={worst['mfu_bound']:.3f} "
                    f"({worst['arch']}/{worst['shape']})"),
    }]
