"""Data diffusion benchmark (paper §6 future work / Falkon follow-on).

Drives a locality-heavy MolDyn-shaped workload — iterative rounds, each a
wide stage of jobs that re-read their molecule's archive plus a shared
parameter database, with a gather barrier between rounds — through the
Falkon service with and without the data layer's executor caches:

  * ``gpfs-only`` — every input read staged from the shared filesystem
    (a `DataLayer` with zero cache capacity: identical cost model and
    contention, nothing retained, dispatch locality-blind);
  * ``diffuse``   — executor-local caches + cache-aware dispatch (tasks
    routed to holders of their inputs, affinity queues, bounded spillover).

The sweep varies working-set size against the aggregate cache size
(`executors x cache_mb`) and reproduces the three diffusion regimes:

  - **cold**            round 1: first touch of every object;
  - **cache-bound**     working set fits: archives are staged once ever
                        (restage factor ~1, zero evictions);
  - **capacity-bound**  working set exceeds aggregate cache: per-home
                        eviction churn re-stages archives every round
                        (restage factor ~rounds, evictions > molecules).
                        Note the *hit rate* stays high in both regimes —
                        affinity routing serves the intra-round re-reads
                        from the home's cache either way — so restage
                        factor and evictions, not hit rate, are the regime
                        discriminators.

Throughput is reported in *simulated* tasks/s (staging costs are
simulated), plus wall-clock tasks/s for the engine-overhead view.
Acceptance (ISSUE 2): once the working set fits the aggregate cache,
diffusion sustains >= 2x the GPFS-only simulated tasks/s, with hit-rate
and staged-bytes reported from bounded metrics.

Usage:
  PYTHONPATH=src python -m benchmarks.data_diffusion                # sweep
  PYTHONPATH=src python -m benchmarks.data_diffusion --executors 128 \
      --rounds 4 --json
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import (DataLayer, DRPConfig, Engine, FalkonConfig,
                        FalkonProvider, FalkonService, SharedStore, SimClock,
                        StagingCostModel, Workflow)

from benchmarks.common import save_json

WIDE = 64               # jobs per molecule per round (re-read the archive)
JOB_S = 0.3             # compute seconds per job (data-intensive regime)
MOL_MB = 100.0          # molecule archive size
SHARED_MB = 50.0        # shared parameter database, read by every job


def build(rounds: int, molecules: int, executors: int, cache_mb: float,
          policy: str = "lru"):
    """Engine + Falkon + data layer for an iterative locality-heavy
    workload; working set = molecules x MOL_MB + SHARED_MB."""
    clock = SimClock()
    shared = SharedStore()
    dl = DataLayer(shared, StagingCostModel(),
                   cache_capacity=cache_mb * 1e6, policy=policy)
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=executors, alloc_latency=81.0,
                      alloc_chunk=max(1, executors // 4))), data_layer=dl)
    eng = Engine(clock, provenance="summary")
    eng.add_site("falkon", FalkonProvider(svc), capacity=executors)
    wf = Workflow("diffusion", eng)

    db = shared.file("params.db", SHARED_MB * 1e6)
    archives = [shared.file(f"mol{m}.arc", MOL_MB * 1e6)
                for m in range(molecules)]
    analyze = wf.sim_proc("analyze", duration=JOB_S,
                          inputs=lambda m, *_: (db, archives[m]))

    barrier = None
    for _ in range(rounds):
        futs = []
        for m in range(molecules):
            if barrier is None:
                futs.extend(analyze(m) for _ in range(WIDE))
            else:
                futs.extend(analyze(m, barrier) for _ in range(WIDE))
        barrier = wf.gather(futs)
    return eng, svc, dl, barrier, rounds * molecules * WIDE


def measure(rounds: int, molecules: int, executors: int, cache_mb: float,
            policy: str = "lru") -> dict:
    t0 = time.monotonic()
    eng, svc, dl, out, n = build(rounds, molecules, executors, cache_mb,
                                 policy)
    eng.run()
    wall = time.monotonic() - t0
    assert out.resolved, "workload did not complete"
    assert eng.tasks_completed == n
    makespan = eng.clock.now()
    ws_mb = molecules * MOL_MB + SHARED_MB
    m = dl.metrics()
    return {
        "tasks": n,
        "rounds": rounds,
        "molecules": molecules,
        "executors": executors,
        "policy": policy,
        "cache_mb": cache_mb,
        "working_set_mb": ws_mb,
        "ws_over_cache": round(ws_mb / max(1e-9, cache_mb * executors), 3),
        "makespan_sim_s": round(makespan, 1),
        "tasks_per_sim_s": round(n / makespan, 2),
        "tasks_per_wall_s": round(n / wall, 1),
        "hit_rate": round(m["hit_rate"], 4),
        "staged_gb": round(m["bytes_staged"] / 1e9, 2),
        "local_gb": round(m["bytes_local"] / 1e9, 2),
        # staged bytes over working-set bytes: ~1 in the cache-bound regime
        # (every object staged once, ever; plus the replicated shared db),
        # ~`rounds` when capacity-bound (re-staged every round)
        "restage_factor": round(m["bytes_staged"] / (ws_mb * 1e6), 2),
        "evictions": sum(e.cache.evictions for e in svc.executors
                         if e.cache is not None),
    }


def _molecules_for(ratio: float, executors: int, cache_mb: float) -> int:
    return max(1, round((ratio * executors * cache_mb - SHARED_MB) / MOL_MB))


def sweep(rounds: int, executors: int, cache_mb: float,
          ratios=(0.25, 0.5, 1.0, 2.0, 4.0), policy: str = "lru") \
        -> list[dict]:
    """Vary working-set size relative to the aggregate cache; ratio < 1 is
    the cache-bound regime, > 1 capacity-bound."""
    rows = []
    for r in ratios:
        row = measure(rounds, _molecules_for(r, executors, cache_mb),
                      executors, cache_mb, policy)
        row["ws_ratio"] = r
        rows.append(row)
    return rows


def gpfs_baseline(rounds: int, molecules: int, executors: int) -> dict:
    """GPFS-only staging: zero cache capacity, same cost model."""
    row = measure(rounds, molecules, executors, 0.0)
    row["policy"] = "gpfs-only"
    return row


def run() -> list[dict]:
    """benchmarks/run.py entry — bounded smoke sweep.

    Asserts the cache-hit regime is reached (CI smoke tier): hit rate
    > 0.9 once the working set fits, >= 2x GPFS-only simulated throughput,
    and a collapsed hit rate once the working set is 4x aggregate cache.
    """
    rounds, executors, cache_mb = 6, 32, 200.0
    fit_molecules = _molecules_for(0.5, executors, cache_mb)

    diffuse = measure(rounds, fit_molecules, executors, cache_mb)
    gpfs = gpfs_baseline(rounds, fit_molecules, executors)
    over = measure(rounds, _molecules_for(4.0, executors, cache_mb),
                   executors, cache_mb)
    speedup = diffuse["tasks_per_sim_s"] / gpfs["tasks_per_sim_s"]

    # distinct artifact name: the CI smoke shape differs from main()'s
    # full-sweep schema in results/data_diffusion.json
    save_json("data_diffusion_smoke", {
        "diffuse_fit": diffuse, "gpfs_only": gpfs,
        "capacity_bound": over, "speedup_vs_gpfs": round(speedup, 2),
    })

    # CI smoke gates: the cache-hit regime must actually be reached
    assert diffuse["hit_rate"] > 0.9, \
        f"cache-bound regime not reached: hit rate {diffuse['hit_rate']}"
    assert speedup >= 2.0, \
        f"diffusion speedup {speedup:.2f}x < 2x over GPFS-only staging"
    assert diffuse["evictions"] == 0 and diffuse["restage_factor"] < 2.0, \
        "cache-bound regime should stage each object once"
    assert (over["evictions"] > over["molecules"]
            and over["restage_factor"] > 2.0), \
        f"capacity-bound regime not reached: {over['restage_factor']}x"

    return [{
        "name": "data_diffusion.cache_bound",
        "us_per_call": 1e6 / diffuse["tasks_per_wall_s"],
        "derived": (f"{diffuse['tasks_per_sim_s']:.1f} sim tasks/s, "
                    f"hit rate {diffuse['hit_rate']:.2f}, "
                    f"staged {diffuse['staged_gb']:.1f} GB"),
    }, {
        "name": "data_diffusion.vs_gpfs",
        "us_per_call": 1e6 / gpfs["tasks_per_wall_s"],
        "derived": (f"{speedup:.1f}x sim tasks/s vs GPFS-only "
                    f"({diffuse['tasks_per_sim_s']:.1f} vs "
                    f"{gpfs['tasks_per_sim_s']:.1f})"),
    }, {
        "name": "data_diffusion.capacity_bound",
        "us_per_call": 1e6 / over["tasks_per_wall_s"],
        "derived": (f"hit rate {over['hit_rate']:.2f} at "
                    f"{over['ws_over_cache']:.1f}x aggregate cache "
                    f"({over['tasks_per_sim_s']:.1f} sim tasks/s)"),
    }]


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--rounds", type=int, default=3)
    p.add_argument("--executors", type=int, default=64)
    p.add_argument("--cache-mb", type=float, default=400.0)
    p.add_argument("--policy", default="lru", choices=["lru", "lfu", "size"])
    p.add_argument("--json", action="store_true")
    args = p.parse_args()

    rows = sweep(args.rounds, args.executors, args.cache_mb,
                 policy=args.policy)
    fit = next(r for r in rows if r["ws_ratio"] == 0.5)
    gpfs = gpfs_baseline(args.rounds, fit["molecules"], args.executors)
    report = {
        "sweep": rows,
        "gpfs_only": gpfs,
        "speedup_vs_gpfs": round(fit["tasks_per_sim_s"] /
                                 gpfs["tasks_per_sim_s"], 2),
    }
    save_json("data_diffusion", report)
    if args.json:
        print(json.dumps(report))
        return 0
    print(f"{'ws/cache':>9} {'tasks':>8} {'hit rate':>9} {'sim t/s':>9} "
          f"{'staged GB':>10} {'restage':>8} {'evictions':>10}")
    for r in rows:
        print(f"{r['ws_ratio']:>9.2f} {r['tasks']:>8} {r['hit_rate']:>9.3f} "
              f"{r['tasks_per_sim_s']:>9.1f} {r['staged_gb']:>10.1f} "
              f"{r['restage_factor']:>8.2f} {r['evictions']:>10}")
    print(f"gpfs-only: {gpfs['tasks_per_sim_s']:.1f} sim tasks/s "
          f"(staged {gpfs['staged_gb']:.1f} GB) -> diffusion speedup "
          f"{report['speedup_vs_gpfs']:.2f}x at ws/cache=0.5")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
