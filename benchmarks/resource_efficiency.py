"""Paper Fig 7: theoretical resource efficiency (1M tasks) at three scales
(100 / 1K / 10K processors) for dispatch throughputs from 1 to 1M tasks/s.

Closed form: with dispatch throughput r and P processors, tasks of length t:
processors stay busy iff r*t >= P; efficiency E = min(1, r*t/P) (saturation
model), matching the paper's observation that 90% efficiency needs
t >= 0.9*P/r.
"""
from __future__ import annotations

from benchmarks.common import save_json

THROUGHPUTS = [1, 10, 100, 500, 1_000, 10_000, 100_000, 1_000_000]
SCALES = [100, 1_000, 10_000]
TASK_LENGTHS = [0.2, 1.9, 20.0, 100.0, 900.0, 10_000.0]


def efficiency(r: float, P: int, t: float) -> float:
    return min(1.0, r * t / P) if t > 0 else 0.0


def min_task_len_for(target: float, r: float, P: int) -> float:
    return target * P / r


def run() -> list[dict]:
    table = {}
    for P in SCALES:
        table[P] = {r: {t: round(efficiency(r, P, t), 4)
                        for t in TASK_LENGTHS} for r in THROUGHPUTS}
    # paper's spot checks: at 500 t/s, 90% efficiency needs 0.2 s / 1.9 s /
    # 20 s tasks at 100 / 1K / 10K processors (vs 100/900/10K s at 1 t/s)
    checks = {
        "needed@1tps": {P: min_task_len_for(0.9, 1, P) for P in SCALES},
        "needed@500tps": {P: round(min_task_len_for(0.9, 500, P), 2)
                          for P in SCALES},
    }
    save_json("resource_efficiency_fig7", {"table": table, "checks": checks})
    rows = [{
        "name": "resource_efficiency.fig7",
        "us_per_call": 0.0,
        "derived": (f"90% eff task lengths @500t/s: "
                    f"{checks['needed@500tps']} (paper: 0.2/1.9/20 s); "
                    f"@1t/s: {checks['needed@1tps']} (paper: 100/900/10k s)"),
    }]
    return rows
