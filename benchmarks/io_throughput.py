"""Paper Fig 8: achievable shared-filesystem I/O throughput vs per-task I/O
size, for different dispatch rates.

Model: tasks each move `size` bytes through a GPFS-like shared FS with
aggregate bandwidth B_fs (8 I/O servers).  A dispatcher with rate r can keep
at most r*ceil(size/node_bw ...) in flight; achieved throughput =
min(B_fs, r * size) — the paper's observation that Falkon reaches ideal
throughput at ~1 MB/task while PBS/Condor need ~1 GB/task.
"""
from __future__ import annotations

from benchmarks.common import PAPER, save_json

GPFS_BW = 4e9            # aggregate shared-fs bandwidth (8 I/O servers)
SIZES = [1, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9]   # bytes per task


def achieved(rate: float, size: float) -> float:
    return min(GPFS_BW, rate * size)


def run() -> list[dict]:
    systems = {
        "falkon": PAPER["falkon_throughput"],
        "pbs": PAPER["gram_pbs_throughput"],
        "condor_6.7.2": 1.0 / PAPER["condor672_overhead"],
    }
    table = {
        name: {f"{int(s)}": achieved(r, s) / 1e9 for s in SIZES}
        for name, r in systems.items()
    }
    save_json("io_throughput_fig8", table)

    def size_to_saturate(r):
        return GPFS_BW / r

    falkon_mb = size_to_saturate(systems["falkon"]) / 1e6
    pbs_mb = size_to_saturate(systems["pbs"]) / 1e6
    rows = [{
        "name": "io_throughput.fig8",
        "us_per_call": 0.0,
        "derived": (f"saturating task-I/O size: falkon={falkon_mb:.0f}MB, "
                    f"pbs={pbs_mb:.0f}MB (paper: ~1MB vs ~1GB)"),
    }]
    return rows
