"""TPU-native clustering (DESIGN.md §2): vmap-bundling of small JAX tasks.

The paper's clustering amortizes batch-scheduler overhead; on accelerators
the analogous per-task cost is dispatch + launch of many small jitted
computations.  We measure N small matmul tasks executed (a) one device call
each through the engine and (b) fused into vmapped bundles — the measured
analogue of the paper's 2-4x clustering win.  Steady-state (compile caches
warm), inputs host-resident as real workflow task data would be.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, RealClock
from repro.core.clustering import VmapClusteringProvider
from benchmarks.common import save_json

N_TASKS = 256
DIM = 64


def small_task(x, w):
    # a "plain procedure" as a user would write it (NOT pre-jitted): each
    # per-task execution pays op-by-op dispatch; the clustering provider is
    # the layer that jits + vmaps the bundle (like the paper's clustering
    # wraps un-optimized user jobs)
    return jnp.tanh(x @ w).sum() * 0.5 + 1.0


FN = small_task


def _mk_engine(cluster: bool):
    eng = Engine(RealClock())
    if cluster:
        prov = VmapClusteringProvider(eng.clock, window=0.0,
                                      max_bundle=N_TASKS)
        eng.add_site("dev", prov, capacity=N_TASKS)
    else:
        eng.local_site(concurrency=1)
        prov = None
    return eng, prov


def _submit_all(eng, xs, w):
    t0 = time.monotonic()
    outs = [eng.submit(f"t{i}", FN, [xs[i], w], vmap_key=("mm", DIM))
            for i in range(N_TASKS)]
    eng.run()
    dt = time.monotonic() - t0
    assert all(o.resolved for o in outs)
    return dt


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    xs = np.asarray(jax.random.normal(key, (N_TASKS, DIM, DIM)))
    w = jax.random.normal(key, (DIM, DIM))
    FN(xs[0], w).block_until_ready()

    # steady state: same provider (vmap jit cache warm), best of 3
    eng_c, prov = _mk_engine(True)
    _submit_all(eng_c, xs, w)  # warm the vmapped compile
    t_cluster = min(_submit_all(eng_c, xs, w) for _ in range(3))

    eng_s, _ = _mk_engine(False)
    _submit_all(eng_s, xs, w)
    t_single = min(_submit_all(eng_s, xs, w) for _ in range(3))

    speedup = t_single / t_cluster
    save_json("vmap_clustering", {
        "per_task_s": t_single, "clustered_s": t_cluster,
        "speedup": speedup, "bundles": prov.bundles_executed})
    # regression bounds (CI smoke tier): clustering must actually fuse and
    # must show a clear amortization win (the paper's clustering band is
    # 2-4x; the floor sits below it to absorb noisy shared runners)
    assert prov.fused_tasks >= N_TASKS, (
        f"only {prov.fused_tasks}/{N_TASKS} tasks fused")
    assert speedup >= 1.5, f"clustering speedup {speedup:.2f}x < 1.5x"
    return [{
        "name": "vmap_clustering.tpu_adaptation",
        "us_per_call": 1e6 * t_cluster / N_TASKS,
        "derived": (f"{N_TASKS} small tasks: per-task "
                    f"{t_single * 1e3:.0f}ms vs vmap-clustered "
                    f"{t_cluster * 1e3:.0f}ms = {speedup:.1f}x "
                    f"(paper clustering: 2-4x)"),
    }]
