"""Process-per-shard federation throughput (DESIGN.md §14).

The headline claim of the multi-process federation: the paper's
dispatcher ceiling (§4 — one Falkon service saturates at ~487 tasks/s)
is *per dispatcher*, so running N shard processes — each a full
`Engine` + `RealClock` + `ThreadExecutorPool` behind a serialized
dispatcher — multiplies aggregate real tasks/s by ~N.  Unlike the §8
in-process federation (one interpreter, one GIL), every process here
pays its own dispatch gate and runs its own worker pool, so the scaling
is wall-clock real, not simulated.

Two experiments:

  * **scaling** — the same sleep-body workload at 1/2/4 process-shards,
    each shard's dispatcher gated at ``1/CEILING`` starts/s
    (``serialize_dispatch=True``); aggregate tasks/s should scale ~Nx
    while the modeled gate, not host CPU, is the binding constraint.
    Interpreter spawn cost is excluded via `wait_ready`.
  * **skew** — a two-heavy-shard molecular workload (archives declared
    as shard `SharedStore` files, tight executor caches) run once with
    ``victim_policy="load"`` and once with ``"directory"``: the
    directory-guided stealer picks victims whose sampled in-flight
    inputs the thief already holds, so its estimated restage bytes per
    stolen task drop at equal skew.

Tiers: the default (CI smoke) run is bounded — a 2-shard scaling check
with a small task count — and **skips on single-core runners** (process
shards cannot overlap on one CPU in the smoke-sized window; the full
tier's modeled-gate workload still scales there, but takes longer than
a smoke step should).  Set ``REAL_FEDERATION_FULL=1`` for the full
1/2/4 sweep + skew experiment; that tier writes
``benchmarks/results/real_federation.json`` and asserts the >=2.8x
4-shard speedup and the directory<load restage ordering.

Knobs: ``REAL_FEDERATION_TASKS`` (tasks per shard in the scaling sweep,
default 300), ``REAL_FEDERATION_CEILING`` (serialized dispatcher
starts/s per shard, default 100.0).
"""
from __future__ import annotations

import os
import time
from zlib import crc32

from repro.core import DataObject
from repro.core.procfed import (ProcessFederation, ShardSpec, body_sleep)
from benchmarks.common import save_json

FULL = os.environ.get("REAL_FEDERATION_FULL", "") not in ("", "0")
N_PER_SHARD = int(os.environ.get("REAL_FEDERATION_TASKS", "300"))
CEILING = float(os.environ.get("REAL_FEDERATION_CEILING", "100.0"))
BODY_S = 0.001

# skew experiment shape
N_GROUPS = 8                      # molecule groups, one archive each
ARCHIVE_B = 4e6                   # bytes per archive
HEAVY_PCT = 80                    # % of a group's tasks on its home shard
ROUNDS = 3
TASKS_PER_ROUND = 240


def _spec(executors: int = 2, **kw) -> ShardSpec:
    return ShardSpec(executors=executors, serialize_dispatch=True,
                     dispatch_overhead=1.0 / CEILING, alloc_latency=1e-4,
                     **kw)


def scaling_run(shards: int, n_per_shard: int) -> dict:
    """Measure aggregate real tasks/s at `shards` process-shards."""
    fed = ProcessFederation(shards, _spec(), steal=False)
    try:
        fed.wait_ready()
        n = n_per_shard * shards
        t0 = time.monotonic()
        futs = [fed.submit("t", body_sleep, [BODY_S], key=f"t#{i}")
                for i in range(n)]
        fed.run()
        wall = time.monotonic() - t0
        ok = sum(1 for f in futs if f.done and not f.failed)
        stats = fed.stats()
    finally:
        fed.shutdown()
    assert ok == n, f"{n - ok} tasks did not complete"
    return {"shards": shards, "tasks": n, "wall_s": wall,
            "tasks_per_s": n / wall,
            "per_shard_completed": stats["per_shard_completed"]}


def _two_heavy(key: str, n: int) -> int:
    """Groups pin to shard 0 (even) / shard 1 (odd) HEAVY_PCT of the
    time; the rest spread over the remaining shards."""
    g = int(key.split("g", 1)[1].split("#", 1)[0])
    home = g % 2
    h = crc32(key.encode())
    if h % 100 < HEAVY_PCT or n <= 2:
        return home % n
    return 2 + (h // 100) % (n - 2)


def skew_run(victim_policy: str) -> dict:
    """Two-heavy workload under parent-coordinated stealing; returns the
    stealer's restage accounting for the given victim policy."""
    files = tuple((f"arch_g{g}.tar", ARCHIVE_B) for g in range(N_GROUPS))
    objs = {g: (DataObject(f"arch_g{g}.tar", ARCHIVE_B),)
            for g in range(N_GROUPS)}
    fed = ProcessFederation(
        4, _spec(cache_capacity=3 * ARCHIVE_B, shared_files=files),
        partitioner=_two_heavy, steal=True, victim_policy=victim_policy)
    try:
        fed.wait_ready()
        t0 = time.monotonic()
        k = 0
        for _ in range(ROUNDS):
            futs = []
            for _ in range(TASKS_PER_ROUND):
                g = k % N_GROUPS
                futs.append(fed.submit("sim", body_sleep, [BODY_S],
                                       key=f"sim_g{g}#{k}",
                                       inputs=objs[g]))
                k += 1
            fed.run()                      # round barrier (driver-side)
            assert all(f.done and not f.failed for f in futs)
        wall = time.monotonic() - t0
        m = fed.metrics()
    finally:
        fed.shutdown()
    st = m["stealer"]
    return {"victim_policy": victim_policy, "tasks": k, "wall_s": wall,
            "steals": st["steals"], "tasks_stolen": st["tasks_stolen"],
            "restage_bytes_est": st["restage_bytes_est"],
            "restage_per_task": (st["restage_bytes_est"]
                                 / max(1, st["tasks_stolen"]))}


def run() -> list[dict]:
    rows = []
    if not FULL and (os.cpu_count() or 1) < 2:
        # single-core smoke runner: two busy shard processes cannot
        # overlap inside a smoke-sized window; the full tier still works
        # here (modeled dispatch gate, longer run) but is opt-in
        return [{"name": "real_federation/scaling",
                 "us_per_call": float("nan"),
                 "derived": "skipped (single-core runner)"}]

    shard_counts = (1, 2, 4) if FULL else (1, 2)
    n_per_shard = N_PER_SHARD if FULL else min(N_PER_SHARD, 120)
    scaling = [scaling_run(s, n_per_shard) for s in shard_counts]
    base = scaling[0]["tasks_per_s"]
    for row in scaling:
        speedup = row["tasks_per_s"] / base
        rows.append({
            "name": f"real_federation/scaling_x{row['shards']}",
            "us_per_call": 1e6 / row["tasks_per_s"],
            "derived": (f"{row['tasks_per_s']:.0f} tasks/s real; "
                        f"{speedup:.2f}x vs 1 shard"),
        })
    speedups = {r["shards"]: r["tasks_per_s"] / base for r in scaling}
    if FULL:
        assert speedups[4] >= 2.8, \
            f"4-shard speedup {speedups[4]:.2f}x < 2.8x"
    else:
        assert speedups[2] >= 1.35, \
            f"2-shard speedup {speedups[2]:.2f}x < 1.35x"

    payload = {
        "params": {"ceiling_per_shard": CEILING, "body_s": BODY_S,
                   "tasks_per_shard": n_per_shard,
                   "cpu_count": os.cpu_count(), "full": FULL},
        "scaling": scaling,
        "speedup_vs_1shard": {str(k): v for k, v in speedups.items()},
    }

    if FULL:
        skew = {p: skew_run(p) for p in ("load", "directory")}
        payload["skew"] = skew
        assert skew["directory"]["restage_bytes_est"] \
            < skew["load"]["restage_bytes_est"], \
            ("directory-guided stealing should restage less: "
             f"{skew['directory']['restage_bytes_est']:.0f} vs "
             f"{skew['load']['restage_bytes_est']:.0f}")
        for p in ("load", "directory"):
            s = skew[p]
            rows.append({
                "name": f"real_federation/steal_{p}",
                "us_per_call": 1e6 * s["wall_s"] / s["tasks"],
                "derived": (f"{s['tasks_stolen']} stolen; "
                            f"{s['restage_bytes_est'] / 1e6:.1f} MB "
                            f"restage est"),
            })
        save_json("real_federation", payload)
    else:
        save_json("real_federation_smoke", payload)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
