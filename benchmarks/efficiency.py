"""Paper Fig 6: resource-usage efficiency vs task length on 64 processors.

E = S_p / S_i with S_i = #processors.  Measured via the sim-clock engine for
Falkon / PBS / Condor-6.7.2 provider models, plus the paper's derived
Condor-6.9.3 curve, plus OUR measured dispatch overhead replayed through the
same formula.
"""
from __future__ import annotations

from benchmarks.common import PAPER, batch_engine, falkon_engine, save_json

TASK_LENGTHS = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096,
                8192, 16384]
PROCS = 64
JOBS = 64


def efficiency_for(make_engine, task_len: float) -> float:
    eng = make_engine()
    outs = [eng.submit(f"t{i}", None, duration=float(task_len))
            for i in range(JOBS)]
    eng.run()
    assert all(o.resolved for o in outs)
    makespan = eng.clock.now()
    ideal = task_len * JOBS / PROCS
    speedup = task_len * JOBS / makespan
    return speedup / PROCS if makespan else 0.0


def run() -> list[dict]:
    systems = {
        "falkon": lambda: falkon_engine(
            executors=PROCS, alloc_latency=0.0,
            dispatch_overhead=1.0 / PAPER["falkon_throughput"])[0],
        "pbs": lambda: batch_engine(
            nodes=PROCS, submit_rate=1.0,
            sched_latency=PAPER["pbs_sched_latency"]),
        "condor_6.7.2": lambda: batch_engine(
            nodes=PROCS, submit_rate=1.0 / PAPER["condor672_overhead"],
            sched_latency=PAPER["pbs_sched_latency"]),
        "condor_6.9.3": lambda: batch_engine(
            nodes=PROCS, submit_rate=1.0 / PAPER["condor693_overhead"],
            sched_latency=0.0),
    }
    table = {}
    for name, mk in systems.items():
        table[name] = {t: round(efficiency_for(mk, t), 4)
                       for t in TASK_LENGTHS}
    save_json("efficiency_fig6", table)

    f, p = table["falkon"], table["pbs"]
    checks = {
        "falkon@1s": f[1], "falkon@8s": f[8],
        "pbs@1s": p[1], "pbs@1200s~": table["pbs"][1024],
        "condor693@100s": table["condor_6.9.3"][128],
    }
    rows = [{
        "name": "efficiency.fig6",
        "us_per_call": 1e6 / PAPER["falkon_throughput"],
        "derived": (f"falkon 1s={f[1]:.0%} (paper 95%), 8s={f[8]:.0%} "
                    f"(paper 99%); pbs 1s={p[1]:.1%} (paper <1%), "
                    f"1024s={p[1024]:.0%} (paper ~90% at 1200s)"),
    }]
    save_json("efficiency_fig6_checks", checks)
    return rows
