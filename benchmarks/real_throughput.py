"""Real-thread dispatch throughput (paper Fig 6 shape, DESIGN.md §10).

Every other throughput number in this repo is simulated; this benchmark
drives the *real* execution path: sleep(0) micro-tasks through
`FalkonService` + `ThreadExecutorPool` under `RealClock`, so each measured
tasks/s figure exercises true worker concurrency, the thread-safe post
queue, and the dispatcher's actual per-task cost.

Three sweeps:

  * **executor scaling** — tasks/s vs executor/worker count (1..16) with a
    1 ms sleeping body.  The Fig-6 shape: throughput rises with executors
    while execution is the bottleneck and flattens once the single
    dispatcher (the clock thread running the service) saturates — the
    paper's Falkon observation, measured on our own code.
  * **dispatch rate** — sleep(0) micro-tasks, so the run measures nothing
    but the dispatcher itself: queue -> idle executor -> worker hand-off ->
    posted completion, per task.
  * **serialized-dispatch ceiling** — the sleep(0) run with
    ``serialize_dispatch=True``: task starts are gated at one per
    ``dispatch_overhead`` of *real* time, so tasks/s clamps to
    ``1/dispatch_overhead`` no matter how many workers are available
    (paper §4: 487 tasks/s is a dispatcher ceiling, not an executor limit).

Knobs: ``REAL_THROUGHPUT_TASKS`` (default 2000 — a few seconds of wall
time, CI-smoke safe), ``REAL_THROUGHPUT_CEILING`` (serialized starts/s,
default 1000.0; use 487 for the paper's exact figure at ~4x the runtime).
"""
from __future__ import annotations

import os
import time

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, RealClock, ThreadExecutorPool)
from benchmarks.common import save_json

N_TASKS = int(os.environ.get("REAL_THROUGHPUT_TASKS", "2000"))
CEILING = float(os.environ.get("REAL_THROUGHPUT_CEILING", "1000.0"))
EXECUTOR_SWEEP = (1, 2, 4, 8, 16)


def real_run(executors: int, n_tasks: int, body_s: float = 0.0,
             serialize: bool = False, ceiling: float = CEILING) -> dict:
    """One measured run: n_tasks sleep(body_s) bodies on real threads."""
    clock = RealClock()
    pool = ThreadExecutorPool(clock)
    cfg = FalkonConfig(
        dispatch_overhead=1.0 / ceiling,
        serialize_dispatch=serialize,
        drp=DRPConfig(max_executors=executors, alloc_latency=0.0,
                      alloc_chunk=executors))
    svc = FalkonService(clock, cfg, pool=pool)
    eng = Engine(clock)
    eng.add_site("pod0", FalkonProvider(svc), capacity=executors)

    body = time.sleep
    outs = [eng.submit(f"t{i}", body, args=[body_s]) for i in range(n_tasks)]
    t0 = time.monotonic()
    eng.run()
    wall = time.monotonic() - t0
    svc.shutdown()
    assert all(o.resolved for o in outs), "real run did not complete"
    assert pool.tasks_run == n_tasks
    return {
        "executors": executors,
        "tasks": n_tasks,
        "body_s": body_s,
        "wall_s": wall,
        "tasks_per_s": n_tasks / wall,
        "serialize_dispatch": serialize,
        "pool": pool.metrics(),
    }


def run() -> list[dict]:
    # Fig-6 shape: 1 ms bodies — execution-bound at small pools, so
    # throughput scales with executors until dispatch saturates
    scale_tasks = max(64, N_TASKS // 4)
    scaling = [real_run(n, scale_tasks, body_s=1e-3)
               for n in EXECUTOR_SWEEP]
    # dispatcher rate: sleep(0) bodies measure the dispatch path itself
    rate = real_run(EXECUTOR_SWEEP[-1], N_TASKS)
    # serialized ceiling at the widest pool: the gate, not the workers,
    # must bound throughput.  Fewer tasks — the run takes ~tasks/ceiling s.
    gated = real_run(EXECUTOR_SWEEP[-1], max(200, N_TASKS // 4),
                     serialize=True)

    payload = {
        "scaling": scaling,
        "dispatch_rate": rate,
        "serialized": gated,
        "ceiling_cfg_tasks_per_s": CEILING,
    }
    save_json("real_throughput", payload)

    rows = []
    for r in scaling:
        rows.append({
            "name": f"real_throughput.threads_{r['executors']}",
            "us_per_call": 1e6 / r["tasks_per_s"],
            "derived": f"{r['tasks_per_s']:.0f} real tasks/s on "
                       f"{r['executors']} executors (1 ms bodies)"})
    rows.append({
        "name": "real_throughput.dispatch_rate",
        "us_per_call": 1e6 / rate["tasks_per_s"],
        "derived": f"{rate['tasks_per_s']:.0f} sleep(0) tasks/s through "
                   f"the dispatcher (paper: 487 t/s streamlined)"})
    rows.append({
        "name": "real_throughput.serialized_ceiling",
        "us_per_call": 1e6 / gated["tasks_per_s"],
        "derived": f"{gated['tasks_per_s']:.0f} tasks/s gated "
                   f"(cfg ceiling {CEILING:.0f}/s; paper: 487 t/s "
                   f"dispatcher ceiling)"})
    # sanity encoded in the output: scaling and the gate must both bite —
    # the widest pool must beat the single executor on 1 ms bodies, and
    # the serialized run cannot beat its configured ceiling
    assert scaling[-1]["tasks_per_s"] > 2.0 * scaling[0]["tasks_per_s"], \
        "real executor scaling not visible"
    assert gated["tasks_per_s"] <= CEILING * 1.05, \
        "serialized dispatch failed to gate task starts"
    rows.append({
        "name": "real_throughput.ceiling_visible",
        "us_per_call": 0.0,
        "derived": f"free-running dispatch {rate['tasks_per_s']:.0f} t/s "
                   f"vs gated {gated['tasks_per_s']:.0f} t/s"})
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.3f},{row['derived']}")
