"""Streaming (windowed) workflow expansion vs eager graph construction.

ROADMAP open item #1 / DESIGN.md §9: the eager `foreach` materializes every
body task and future up front — ~0.9 GB of RSS per million tasks — which
caps the "million-task" story well below the paper's ambitions.  Windowed
expansion (`foreach(..., window=k)`) keeps at most k body pipelines in
flight, refilled as they complete and throttled by the engine's submit-side
backpressure signal (`Engine.saturated()`), so peak memory is bounded by
the *frontier* while the executor pool stays exactly as busy.

This benchmark runs the MolDyn-shaped million-task workload
(benchmarks/million_tasks.py `build_workload`) with streaming on/off, on a
single engine and on a 4-shard federation (work stealing enabled), and
reports peak RSS, wall tasks/s, and the simulated makespan.  Each
configuration runs in its own subprocess so `ru_maxrss` (a high-water mark)
measures that configuration alone.

Acceptance gate (ISSUE 4): at 10^6 tasks streaming must show >= 5x peak-RSS
reduction at >= 0.95x simulated tasks/s, single-engine and federated; the
CI smoke tier (`run()`) enforces a scaled-down version of the same bound so
frontier-boundedness cannot silently regress.

Usage:
  PYTHONPATH=src python -m benchmarks.streaming_expansion            # full 1M
  PYTHONPATH=src python -m benchmarks.streaming_expansion --tasks 200000
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if __package__ in (None, ""):           # direct subprocess invocation
    # append so an explicitly-set PYTHONPATH keeps winning for `repro`
    sys.path.append(os.path.join(_REPO_ROOT, "src"))
    sys.path.append(_REPO_ROOT)

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, FederatedEngine, SimClock)

from benchmarks.common import run_measured
from benchmarks.million_tasks import JOB_S, build_workload

DEFAULT_WINDOW = 2048     # molecule pipelines in flight.  The window must
                          # cover the pool: during a cohort's *serial*
                          # phases each body pipeline feeds the pool just
                          # one task, so fewer than pool-capacity pipelines
                          # in flight leaves executors idle (measured: a
                          # 1024 window on a 2048-slot pool costs ~17% of
                          # simulated throughput; 2048 costs <1%).  Above
                          # that, submit-side backpressure — not the
                          # window — sets the standing frontier.


def _falkon_site(eng: Engine, executors: int, tag: str = "falkon") -> None:
    svc = FalkonService(eng.clock, FalkonConfig(
        drp=DRPConfig(max_executors=executors, alloc_latency=81.0,
                      alloc_chunk=max(1, executors // 4))))
    # pre-provision the pool: DRP grows on *visible* queue pressure, which
    # streaming expansion deliberately keeps small — letting the pool ramp
    # lazily would conflate provisioning dynamics with the expansion
    # strategy this benchmark isolates
    svc.provision(executors)
    eng.add_site(tag, FalkonProvider(svc), capacity=executors)


def make_engine(shards: int, executors: int):
    """Single `Engine` or N-shard `FederatedEngine`, total pool size
    `executors`, in bounded-memory mode (summary provenance, no traces)."""
    if shards <= 1:
        eng = Engine(SimClock(), provenance="summary")
        _falkon_site(eng, executors)
        return eng
    fed = FederatedEngine(shards, engine_kwargs={"provenance": "summary"})
    for i, shard in enumerate(fed.shards):
        _falkon_site(shard, executors // shards, tag=f"pod{i}")
    return fed


def measure_one(mode: str, tasks: int, executors: int, shards: int,
                window: int) -> dict:
    t0 = time.monotonic()
    eng = make_engine(shards, executors)
    n, out = build_workload(eng, tasks,
                            window=window if mode == "streaming" else None)
    build_s = time.monotonic() - t0
    m = run_measured(eng, out, n, sample_interval=JOB_S / 4.0)
    wall = time.monotonic() - t0
    makespan = m["makespan_sim_s"]
    row = {
        "mode": mode,
        "shards": shards,
        "tasks": n,
        "executors": executors,
        "window": window if mode == "streaming" else None,
        "wall_s": round(wall, 3),
        "build_s": round(build_s, 3),
        "run_s": round(m["run_s"], 3),
        "tasks_per_s": round(n / wall, 1),
        "makespan_sim_s": round(makespan, 1),
        "sim_tasks_per_s": round(n / makespan, 1),
        "peak_rss_mb": round(m["peak_rss_mb"], 1),
    }
    if shards > 1:
        # proxy/ownership maps must end empty: bounded by in-flight work,
        # not workflow size (DESIGN.md §8/§9)
        m = eng.metrics()
        row["cross_shard_edges"] = m["cross_shard_edges"]
        row["in_flight_owned_at_end"] = m["in_flight_owned"]
        assert m["in_flight_owned"] == 0
    return row


def measure(mode: str, tasks: int, executors: int, shards: int,
            window: int) -> dict:
    """Run one configuration in a fresh subprocess so peak RSS is that
    configuration's own high-water mark."""
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--one", mode,
         "--tasks", str(tasks), "--executors", str(executors),
         "--shards", str(shards), "--window", str(window), "--json"],
        env=dict(os.environ, PYTHONPATH=os.path.join(_REPO_ROOT, "src")),
        cwd=_REPO_ROOT, capture_output=True, text=True)
    if out.returncode != 0:
        # surface the child's diagnostics (e.g. which bound tripped) —
        # a bare CalledProcessError would bury them in captured stderr
        sys.stderr.write(out.stderr)
        raise subprocess.CalledProcessError(out.returncode, out.args,
                                            out.stdout, out.stderr)
    return json.loads(out.stdout.strip().splitlines()[-1])


def compare(tasks: int, executors: int, shards: int, window: int) -> dict:
    eager = measure("eager", tasks, executors, shards, window)
    streaming = measure("streaming", tasks, executors, shards, window)
    return {
        "shards": shards,
        "eager": eager,
        "streaming": streaming,
        "rss_reduction": round(eager["peak_rss_mb"] /
                               max(streaming["peak_rss_mb"], 1e-9), 2),
        "sim_throughput_ratio": round(streaming["sim_tasks_per_s"] /
                                      max(eager["sim_tasks_per_s"], 1e-9), 3),
    }


def run() -> list[dict]:
    """benchmarks/run.py entry (CI smoke tier): scaled-down comparison with
    the frontier-boundedness gates asserted."""
    rows = []
    for shards in (1, 4):
        c = compare(tasks=300_000, executors=1024, shards=shards,
                    window=DEFAULT_WINDOW)
        # RSS-bound gates (scaled-down from the 1M acceptance criteria of
        # >= 5x at >= 0.95x, which the full run checks — see
        # benchmarks/results/streaming_expansion.json): the streaming
        # frontier must stay bounded in absolute terms (it is scale-
        # independent: ~145 MB at 300k and at 1M) and clearly below the
        # eager graph, near parity simulated throughput (smoke scale pays
        # a relatively larger pipeline-fill tail than 1M does).
        assert c["streaming"]["peak_rss_mb"] <= 250.0, c
        assert c["rss_reduction"] >= 1.5, c
        assert c["sim_throughput_ratio"] >= 0.93, c
        rows.append({
            "name": f"streaming_expansion.{shards}shard.300k",
            "us_per_call": 1e6 * c["streaming"]["wall_s"]
            / c["streaming"]["tasks"],
            "derived": (f"rss {c['streaming']['peak_rss_mb']:.0f} vs "
                        f"{c['eager']['peak_rss_mb']:.0f} MB eager "
                        f"({c['rss_reduction']:.1f}x); sim-throughput "
                        f"ratio {c['sim_throughput_ratio']:.3f}"),
        })
    return rows


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--tasks", type=int, default=1_000_000)
    p.add_argument("--executors", type=int, default=2048,
                   help="total pool size (split across shards when "
                        "federated)")
    p.add_argument("--shards", type=int, default=None,
                   help="run only this shard count (default: 1 and 4)")
    p.add_argument("--window", type=int, default=DEFAULT_WINDOW)
    p.add_argument("--one", choices=("eager", "streaming"), default=None,
                   help="measure one mode in-process (subprocess entry)")
    p.add_argument("--json", action="store_true")
    args = p.parse_args()

    if args.one:
        row = measure_one(args.one, args.tasks, args.executors,
                          args.shards or 1, args.window)
        print(json.dumps(row))
        return 0

    shard_counts = [args.shards] if args.shards else [1, 4]
    report = {"comparisons": [compare(args.tasks, args.executors, s,
                                      args.window)
                              for s in shard_counts]}
    results = os.path.join(_REPO_ROOT, "benchmarks", "results",
                           "streaming_expansion.json")
    with open(results, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    if args.json:
        print(json.dumps(report))
        return 0
    for c in report["comparisons"]:
        e, s = c["eager"], c["streaming"]
        label = "single engine" if c["shards"] == 1 \
            else f"{c['shards']}-shard fed"
        print(f"{label:>14}: {s['tasks']:,} tasks")
        print(f"    eager     : rss {e['peak_rss_mb']:7.1f} MB, "
              f"{e['tasks_per_s']:8,.0f} tasks/s wall, "
              f"makespan {e['makespan_sim_s']:,.0f} sim-s")
        print(f"    streaming : rss {s['peak_rss_mb']:7.1f} MB, "
              f"{s['tasks_per_s']:8,.0f} tasks/s wall, "
              f"makespan {s['makespan_sim_s']:,.0f} sim-s "
              f"(window {s['window']})")
        print(f"    -> {c['rss_reduction']:.1f}x peak-RSS reduction at "
              f"{c['sim_throughput_ratio']:.3f}x simulated throughput")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
