"""Paper Fig 11: score-based load balancing across two clusters.

Paper: 480 fMRI jobs split 218 (ANL_TG) / 262 (UC_TP, faster + closer) with
~50% total-time reduction vs single-cluster execution.
"""
from __future__ import annotations

from repro.core import Engine, LocalProvider, SimClock, Workflow
from benchmarks.common import save_json

JOBS = 480
BASE = 4.0


class ClusterProvider(LocalProvider):
    """Cluster with a node pool and a relative speed factor."""

    def __init__(self, clock, nodes: int, speed: float, net_latency: float):
        super().__init__(clock, concurrency=nodes)
        self.speed = speed
        self.net_latency = net_latency

    def submit(self, task, when_done):
        task.duration = task.duration / self.speed + self.net_latency
        super().submit(task, when_done)


def run_two_sites():
    clock = SimClock()
    eng = Engine(clock)
    anl = eng.add_site("ANL_TG", ClusterProvider(clock, 62, 1.0, 0.5),
                       capacity=62)
    uctp = eng.add_site("UC_TP", ClusterProvider(clock, 120, 1.4, 0.05),
                        capacity=120)
    wf = Workflow("lb", eng)
    p = wf.sim_proc("job", duration=BASE)
    out = wf.foreach(list(range(JOBS)), p)
    wf.run()
    assert out.resolved
    return clock.now(), anl.stats.completed, uctp.stats.completed


def run_single_site():
    clock = SimClock()
    eng = Engine(clock)
    eng.add_site("ANL_TG", ClusterProvider(clock, 62, 1.0, 0.5), capacity=62)
    wf = Workflow("lb1", eng)
    p = wf.sim_proc("job", duration=BASE)
    out = wf.foreach(list(range(JOBS)), p)
    wf.run()
    assert out.resolved
    return clock.now()


def run() -> list[dict]:
    t2, n_anl, n_uctp = run_two_sites()
    t1 = run_single_site()
    reduction = (t1 - t2) / t1
    save_json("load_balance_fig11", {
        "two_site_s": t2, "single_site_s": t1,
        "anl_jobs": n_anl, "uctp_jobs": n_uctp, "reduction": reduction})
    return [{
        "name": "load_balance.fig11",
        "us_per_call": 0.0,
        "derived": (f"split ANL={n_anl}/UC_TP={n_uctp} "
                    f"(paper 218/262), time -{reduction:.0%} "
                    f"(paper ~50%)"),
    }]
