"""Kill-and-resume: fraction of a workflow re-run after SIGKILL (§15).

The paper's reliability claim (§3.5) is that a restart log bounds the
cost of a crash to the in-flight window, not the work already done.
This benchmark measures that bound on the *durable* path — the sqlite
`JobStore` + `WorkflowService` — with a real crash, not a simulated one:

  1. a child process runs an ``n``-task workflow (real threads, RealClock)
     journaling into a `JobStore`;
  2. the parent polls the store read-only until the durable done-count
     crosses ``KILL_RESUME_FRACTION`` (default t=50%), then SIGKILLs the
     child mid-commit;
  3. the parent re-opens the same store and resumes the same program:
     durably-done tasks restore from the store, only the frontier re-runs.

Every task body appends its index to a per-run **sidecar file** (O_APPEND
page-cache writes survive SIGKILL), so "which tasks actually executed" is
measured independently of the store under test.  Redundant work is the
intersection of the two runs' sidecar sets.  Correctness is byte-identity:
the resumed run's results JSON must hash equal to an uninterrupted
reference run's.

Assertions encoded in the output:
  * results byte-identical to the uninterrupted reference;
  * ``restored >= done-at-kill`` (nothing durably recorded was re-run);
  * redundant work bounded by the in-flight window (executor slots +
    journal batch + store flush lag), and at full scale
    (``n >= 50000``) by the ISSUE acceptance bound ``<= 5%`` of ``n``.

Knobs: ``KILL_RESUME_TASKS`` (default 100000; CI smoke uses 3000),
``KILL_RESUME_EXECUTORS`` (4), ``KILL_RESUME_FRACTION`` (0.5),
``KILL_RESUME_BODY_SLEEP`` (0.0005 s — keeps the kill genuinely
mid-flight at smoke sizes).
"""
from __future__ import annotations

import hashlib
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if __name__ == "__main__":                      # direct / --child invocation
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    sys.path.insert(0, _ROOT)

from repro.core import (Engine, JobStore, LocalProvider, RealClock,
                        ThreadExecutorPool, WorkflowService)

N_TASKS = int(os.environ.get("KILL_RESUME_TASKS", "100000"))
EXECUTORS = int(os.environ.get("KILL_RESUME_EXECUTORS", "4"))
KILL_FRACTION = float(os.environ.get("KILL_RESUME_FRACTION", "0.5"))
BODY_SLEEP = float(os.environ.get("KILL_RESUME_BODY_SLEEP", "0.0005"))
WF_ID = "killres"
FLUSH_INTERVAL = 0.02
JOURNAL_BATCH = 32

_SIDE_FD = -1


def _body(i: int) -> int:
    """Pure except for the sidecar append: the ground-truth 'I executed'
    record this benchmark grades the store against."""
    if BODY_SLEEP:
        time.sleep(BODY_SLEEP)
    os.write(_SIDE_FD, b"%d\n" % i)
    return (i * 2654435761) & 0xFFFFFFFF


def run_workflow(db: str, n: int, sidecar: str,
                 executors: int = EXECUTORS) -> tuple[list, int]:
    """Build + run (or resume) the n-task workflow against `db`.

    Returns ``(results, restored)``.  Identical program every call, so a
    second call against a store holding a partial run is a resume.
    """
    global _SIDE_FD
    _SIDE_FD = os.open(sidecar, os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                       0o644)
    clock = RealClock()
    pool = ThreadExecutorPool(clock, workers=executors)
    eng = Engine(clock)
    eng.add_site("local",
                 LocalProvider(clock, concurrency=executors, pool=pool),
                 capacity=executors)
    try:
        with JobStore(db, flush_interval=FLUSH_INTERVAL) as store:
            with WorkflowService(eng, store,
                                 journal_batch=JOURNAL_BATCH) as svc:
                h = svc.open(WF_ID)
                hash_task = h.wf.atomic(fn=_body, name="hash")
                out = h.seal(h.wf.gather([hash_task(i) for i in range(n)]))
                svc.run()
                return out.get(), h.restored
    finally:
        pool.shutdown()
        os.close(_SIDE_FD)
        _SIDE_FD = -1


def _child_main(argv: list[str]) -> int:
    """``--child <db> <n> <sidecar> <results_path>`` — run to completion
    and write the results JSON (the parent usually kills us first)."""
    db, n, sidecar, results_path = argv[0], int(argv[1]), argv[2], argv[3]
    results, _ = run_workflow(db, n, sidecar)
    tmp = results_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f)
    os.replace(tmp, results_path)
    return 0


def _read_sidecar(path: str) -> set:
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        return {int(line) for line in f if line.strip()}


def _spawn_child(db: str, n: int, sidecar: str,
                 results_path: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"), _ROOT,
                    env.get("PYTHONPATH")) if p)
    return subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--child",
         db, str(n), sidecar, results_path], env=env)


def measure(n: int = N_TASKS, workdir: str | None = None) -> dict:
    """The full experiment; returns the metrics payload (see module
    docstring for the assertions it encodes)."""
    workdir = workdir or tempfile.mkdtemp(prefix="kill_resume_")
    db_ref = os.path.join(workdir, "ref.db")
    db_kill = os.path.join(workdir, "kill.db")
    side_ref = os.path.join(workdir, "ref.side")
    side1 = os.path.join(workdir, "run1.side")
    side2 = os.path.join(workdir, "run2.side")
    ref_results = os.path.join(workdir, "ref.results.json")

    # -- uninterrupted reference (subprocess: same environment as run 1)
    ref = _spawn_child(db_ref, n, side_ref, ref_results)
    if ref.wait(timeout=1800) != 0:
        raise RuntimeError("reference run failed")
    with open(ref_results, "rb") as f:
        ref_bytes = f.read()
    ref_sha = hashlib.sha256(ref_bytes).hexdigest()

    # -- run 1: kill at the durable t=KILL_FRACTION mark
    target = int(n * KILL_FRACTION)
    child = _spawn_child(db_kill, n, side1,
                         os.path.join(workdir, "unused.results.json"))
    t0 = time.monotonic()
    done_at_kill = 0
    try:
        deadline = t0 + 1800.0
        while time.monotonic() < deadline:
            if child.poll() is not None:
                raise RuntimeError(
                    f"child finished (rc={child.returncode}) before the "
                    f"kill threshold {target} — raise KILL_RESUME_BODY_SLEEP")
            try:
                done_at_kill = JobStore.peek(db_kill, WF_ID)["done"]
            except Exception:
                done_at_kill = 0        # store not created/visible yet
            if done_at_kill >= target:
                break
            # peek parses the store's un-folded log tail (it grows until a
            # barrier folds it), so poll gently — a hot poll loop would
            # also steal CPU from the child on small hosts
            time.sleep(0.02)
        else:
            raise RuntimeError("kill threshold never reached")
    finally:
        child.send_signal(signal.SIGKILL)
        child.wait()
    elapsed = time.monotonic() - t0
    rate = done_at_kill / max(elapsed, 1e-9)

    # -- run 2: resume in-process from the surviving store
    t1 = time.monotonic()
    results, restored = run_workflow(db_kill, n, side2)
    resume_wall = time.monotonic() - t1
    resumed_bytes = json.dumps(results).encode()
    resumed_sha = hashlib.sha256(resumed_bytes).hexdigest()

    executed1 = _read_sidecar(side1)
    executed2 = _read_sidecar(side2)
    redundant = len(executed1 & executed2)
    # the only work a crash may legitimately repeat: tasks executed but
    # not yet durably committed — executor slots + the journal's row
    # buffer + the store's flush-interval lag at the observed rate
    window = EXECUTORS + JOURNAL_BATCH + int(FLUSH_INTERVAL * rate) + 1
    payload = {
        "n_tasks": n,
        "executors": EXECUTORS,
        "kill_fraction": KILL_FRACTION,
        "done_at_kill": done_at_kill,
        "rate_at_kill_tasks_per_s": rate,
        "restored": restored,
        "executed_run1": len(executed1),
        "executed_run2": len(executed2),
        "redundant_tasks": redundant,
        "redundant_fraction": redundant / n,
        "inflight_window": window,
        "resume_wall_s": resume_wall,
        "byte_identical": resumed_sha == ref_sha,
        "sha256": resumed_sha,
    }
    assert payload["byte_identical"], \
        f"resumed results diverged from reference ({resumed_sha} != {ref_sha})"
    assert restored >= done_at_kill, \
        f"durably-done work re-ran: restored {restored} < {done_at_kill}"
    assert executed1 | executed2 >= set(range(n)), "tasks never executed"
    assert redundant <= 4 * window, \
        f"redundant {redundant} exceeds 4x in-flight window {window}"
    if n >= 50000:
        assert redundant <= 0.05 * n, \
            f"redundant fraction {redundant / n:.3f} exceeds 5%"
    return payload


def run() -> list[dict]:
    from benchmarks.common import save_json
    payload = measure()
    save_json("kill_resume", payload)
    wall = payload["resume_wall_s"]
    return [{
        "name": "kill_resume.redundant_fraction",
        "us_per_call": 1e6 * wall / max(payload["n_tasks"], 1),
        "derived": (
            f"{payload['redundant_tasks']} of {payload['n_tasks']} tasks "
            f"re-ran ({100 * payload['redundant_fraction']:.2f}%) after "
            f"SIGKILL at {payload['done_at_kill']} durable; "
            f"restored {payload['restored']}; byte-identical"),
    }]


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        sys.exit(_child_main(sys.argv[2:]))
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.3f},{row['derived']}")
