"""Million-task scheduler benchmark (MolDyn shape: wide fan-out + gather).

The paper's headline scale claim is hundreds of thousands of parallel
computations (MolDyn: 244 molecules = 20,497 jobs; Falkon microbenchmarks
queue 1.5 M tasks over 54k executors).  This benchmark drives the layered
scheduler through a synthetic MolDyn-shaped workflow — per molecule 3 serial
prep jobs -> 68 independent wide jobs -> gather -> 13 serial post jobs — at
1,000,000 tasks on `SimClock`, under both the Falkon provider and the
simulated batch-scheduler provider, and reports wall-clock, tasks/s, peak
RSS, and the simulated makespan.

The engine runs in its bounded-memory configuration (``provenance=
"summary"``, Falkon ``trace=False``): no per-task log growth, so memory is
set by the dataflow graph itself, not by run length.

Self-measured baseline comparison: ``--baseline <git-rev>`` materializes the
repo at that revision (git archive) into a temp dir and re-runs this same
workload against the old `repro` package in a subprocess (the benchmark
feature-detects `trace=`/`provenance=`, so it runs unmodified against the
seed engine).  The acceptance gate for the scheduler refactor is >= 10x the
pre-refactor tasks/s at 100k tasks at the paper-scale executor pool
(the seed engine's per-completion DRP sweep made per-task cost O(pool
size); see DESIGN.md §5).

Usage:
  PYTHONPATH=src python -m benchmarks.million_tasks                 # 1M tasks
  PYTHONPATH=src python -m benchmarks.million_tasks --tasks 100000 \
      --baseline HEAD~1                                             # compare
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import subprocess
import sys
import tempfile
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if __package__ in (None, ""):           # direct / subprocess invocation:
    # *append* so an explicit PYTHONPATH (the --baseline subprocess points
    # it at an archived old tree) keeps winning for `repro`
    sys.path.append(os.path.join(_REPO_ROOT, "src"))
    sys.path.append(_REPO_ROOT)

from repro.core import (BatchSchedulerProvider, DRPConfig, Engine,
                        FalkonConfig, FalkonProvider, FalkonService,
                        SimClock, Workflow)

from benchmarks.common import run_measured

SERIAL_PRE, WIDE, SERIAL_POST = 3, 68, 13
JOBS_PER_MOL = SERIAL_PRE + WIDE + SERIAL_POST      # 84, as in MolDyn
JOB_S = 168.0                                       # ~paper job duration


def build_workload(eng, n_tasks: int, job_s: float = JOB_S,
                   window: int | None = None):
    """Submit a MolDyn-shaped workflow of ~n_tasks tasks; returns
    (exact task count, final output future).  `eng` is anything with the
    engine submission surface (an `Engine` or a `FederatedEngine`);
    benchmarks/federation.py reuses this builder with short jobs so the
    federated-vs-single comparison runs the identical workload shape.

    ``window=None`` materializes the whole graph up front (the seed
    behavior: memory is O(task count)).  ``window=k`` expands through a
    streaming `foreach` (DESIGN.md §9): at most k molecule pipelines are
    in flight at once — refilled as molecules complete, throttled further
    by the engine's submit-side backpressure — each pipeline grows its
    wide and post stages via `then` continuations only as the previous
    stage resolves, and per-molecule results are counted, not retained,
    so memory is bounded by the *runnable* frontier, not the graph."""
    wf = Workflow("million", eng)
    molecules = max(1, round((n_tasks - 1) / JOBS_PER_MOL))
    shared = eng.submit("annotate", None, duration=job_s)

    def eager_molecule(_m):
        f = shared
        for _ in range(SERIAL_PRE):
            f = eng.submit("prep", None, [f], duration=job_s)
        wide = [eng.submit("charmm", None, [f], duration=job_s)
                for _ in range(WIDE)]
        g = wf.gather(wide)
        for _ in range(SERIAL_POST):
            g = eng.submit("post", None, [g], duration=job_s)
        return g

    def streaming_molecule(_m):
        f = shared
        for _ in range(SERIAL_PRE):
            f = eng.submit("prep", None, [f], duration=job_s)

        def fan_out(_v, pre=f):
            wide = [eng.submit("charmm", None, [pre], duration=job_s)
                    for _ in range(WIDE)]
            g = wf.gather(wide, keep_results=False)
            for _ in range(SERIAL_POST):
                g = eng.submit("post", None, [g], duration=job_s)
            return g

        return wf.then(f, fan_out)

    if window is None:
        finals = [eager_molecule(m) for m in range(molecules)]
        out = wf.gather(finals)
    else:
        out = wf.foreach(range(molecules), streaming_molecule,
                         window=window, keep_results=False)
    return 1 + molecules * JOBS_PER_MOL, out


def _supports(callable_, param: str) -> bool:
    try:
        return param in inspect.signature(callable_).parameters
    except (TypeError, ValueError):
        return False


def make_engine(provider: str, executors: int):
    """Engine in bounded-memory mode where the installed repro supports it
    (feature-detected so the same code measures the seed engine)."""
    clock = SimClock()
    ekw = {"provenance": "summary"} if _supports(Engine, "provenance") else {}
    eng = Engine(clock, **ekw)
    if provider == "falkon":
        fkw = {"trace": False} if _supports(FalkonService, "trace") else {}
        svc = FalkonService(clock, FalkonConfig(
            drp=DRPConfig(max_executors=executors, alloc_latency=81.0,
                          alloc_chunk=max(1, executors // 4))), **fkw)
        eng.add_site("falkon", FalkonProvider(svc), capacity=executors)
    elif provider == "batch":
        eng.add_site("batch",
                     BatchSchedulerProvider(eng.clock, nodes=executors,
                                            submit_rate=2.0,
                                            sched_latency=60.0),
                     capacity=executors)
    else:
        raise ValueError(f"unknown provider {provider!r}")
    return eng


def measure(provider: str, n_tasks: int, executors: int,
            window: int | None = None) -> dict:
    t0 = time.monotonic()
    eng = make_engine(provider, executors)
    n, out = build_workload(eng, n_tasks, window=window)
    build_s = time.monotonic() - t0
    m = run_measured(eng, out, n, sample_interval=JOB_S / 4.0)
    wall = time.monotonic() - t0
    return {
        "provider": provider,
        "tasks": n,
        "executors": executors,
        "window": window,
        "wall_s": round(wall, 3),
        "build_s": round(build_s, 3),
        "run_s": round(m["run_s"], 3),
        "tasks_per_s": round(n / wall, 1),
        "makespan_sim_s": round(m["makespan_sim_s"], 1),
        "peak_rss_mb": round(m["peak_rss_mb"], 1),
    }


def measure_baseline(rev: str, provider: str, n_tasks: int,
                     executors: int) -> dict:
    """Run the same workload against the repo tree at `rev` (subprocess with
    PYTHONPATH pointed at the archived src/)."""
    with tempfile.TemporaryDirectory(prefix="sched-baseline-") as tmp:
        tar = os.path.join(tmp, "tree.tar")
        with open(tar, "wb") as f:
            subprocess.run(["git", "archive", rev], cwd=_REPO_ROOT,
                           stdout=f, check=True)
        subprocess.run(["tar", "-xf", tar, "-C", tmp], check=True)
        env = dict(os.environ, PYTHONPATH=os.path.join(tmp, "src"))
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--tasks",
             str(n_tasks), "--providers", provider, "--executors",
             str(executors), "--json"],
            env=env, cwd=_REPO_ROOT, capture_output=True, text=True,
            check=True)
        row = json.loads(out.stdout.strip().splitlines()[-1])["rows"][0]
        row["rev"] = rev
        return row


def run() -> list[dict]:
    """benchmarks/run.py entry: small smoke-scale run of both providers."""
    rows = []
    for provider in ("falkon", "batch"):
        r = measure(provider, n_tasks=20_000, executors=512)
        rows.append({
            "name": f"million_tasks.{provider}.20k",
            "us_per_call": 1e6 * r["wall_s"] / r["tasks"],
            "derived": (f"{r['tasks_per_s']:.0f} tasks/s, "
                        f"rss {r['peak_rss_mb']:.0f} MB, "
                        f"makespan {r['makespan_sim_s']:.0f} sim-s"),
        })
    return rows


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--tasks", type=int, default=1_000_000)
    p.add_argument("--providers", default="falkon,batch")
    p.add_argument("--executors", type=int, default=2048,
                   help="pool size (paper runs Falkon up to 54k executors)")
    p.add_argument("--window", type=int, default=None,
                   help="streaming expansion: max molecule pipelines in "
                        "flight (default: eager, whole graph up front)")
    p.add_argument("--baseline", default=None, metavar="GIT_REV",
                   help="also measure the engine at this git revision on "
                        "the same workload (subprocess) and report speedup")
    p.add_argument("--baseline-tasks", type=int, default=100_000,
                   help="task count for the --baseline comparison")
    p.add_argument("--json", action="store_true",
                   help="emit one JSON object on stdout (machine readable)")
    args = p.parse_args()

    providers = [s.strip() for s in args.providers.split(",") if s.strip()]
    rows = [measure(prov, args.tasks, args.executors, window=args.window)
            for prov in providers]
    report = {"rows": rows}

    if args.baseline:
        comparisons = []
        for prov in providers:
            new = measure(prov, args.baseline_tasks, args.executors)
            old = measure_baseline(args.baseline, prov, args.baseline_tasks,
                                   args.executors)
            comparisons.append({
                "provider": prov,
                "tasks": args.baseline_tasks,
                "new_tasks_per_s": new["tasks_per_s"],
                "old_tasks_per_s": old["tasks_per_s"],
                "speedup": round(new["tasks_per_s"] /
                                 max(old["tasks_per_s"], 1e-9), 2),
                "new_rss_mb": new["peak_rss_mb"],
                "old_rss_mb": old["peak_rss_mb"],
                "baseline_rev": args.baseline,
            })
        report["baseline"] = comparisons

    if args.json:
        print(json.dumps(report))
        return 0
    for r in rows:
        print(f"{r['provider']:>7}: {r['tasks']:,} tasks in "
              f"{r['wall_s']:.1f}s wall ({r['tasks_per_s']:,.0f} tasks/s), "
              f"peak RSS {r['peak_rss_mb']:.0f} MB, "
              f"sim makespan {r['makespan_sim_s']:,.0f} s "
              f"({r['executors']} executors)")
    for c in report.get("baseline", []):
        print(f"{c['provider']:>7}: vs {c['baseline_rev']} at "
              f"{c['tasks']:,} tasks: {c['new_tasks_per_s']:,.0f} vs "
              f"{c['old_tasks_per_s']:,.0f} tasks/s "
              f"-> {c['speedup']:.1f}x; RSS {c['new_rss_mb']:.0f} vs "
              f"{c['old_rss_mb']:.0f} MB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
