"""Paper Fig 12: end-to-end sleep(0) throughput.

Paper: Falkon-direct 120 t/s (older code; 487 with current), Swift+Falkon
56 t/s (LAN), GRAM+PBS ~2 t/s -> 23x improvement via Falkon.
We measure (a) our engine's REAL in-process dispatch rate through the full
Swift path (site selection + provenance + futures), (b) direct Falkon-service
dispatch, and (c) the simulated GRAM+PBS rate.
"""
from __future__ import annotations

import time

from repro.core import Engine, RealClock, SimClock
from repro.core.falkon import FalkonConfig, DRPConfig, FalkonService
from benchmarks.common import PAPER, batch_engine, save_json

N = 20_000


def swift_path_throughput() -> float:
    eng = Engine(RealClock())
    eng.local_site(concurrency=64)
    t0 = time.monotonic()
    outs = [eng.submit(f"t{i}", None) for i in range(N)]
    eng.run()
    dt = time.monotonic() - t0
    assert all(o.resolved for o in outs)
    return N / dt


def falkon_direct_throughput() -> float:
    """Bypass the engine: submit straight to the service (paper's
    'Falkon client -> Falkon service' measurement)."""
    clock = RealClock()
    svc = FalkonService(clock, FalkonConfig(
        dispatch_overhead=0.0,
        drp=DRPConfig(max_executors=64, alloc_latency=0.0)))
    svc.provision(64)
    clock.run()  # let provisioning land
    done = [0]

    class _T:
        __slots__ = ("fn", "args", "duration", "sim_value", "submit_time",
                     "start_time", "host", "_falkon_done", "fault_check")

        def __init__(self):
            self.fn = None
            self.args = []
            self.duration = 0.0
            self.sim_value = None
            self.fault_check = None

    t0 = time.monotonic()
    for _ in range(N):
        svc.submit(_T(), lambda ok, v, e: done.__setitem__(0, done[0] + 1))
    clock.run()
    dt = time.monotonic() - t0
    assert done[0] == N
    return N / dt


def gram_pbs_throughput_sim() -> float:
    eng = batch_engine(nodes=64, submit_rate=PAPER["gram_pbs_throughput"],
                       sched_latency=0.0)
    outs = [eng.submit(f"t{i}", None, duration=0.0) for i in range(2000)]
    eng.run()
    assert all(o.resolved for o in outs)
    return 2000 / eng.clock.now()


def run() -> list[dict]:
    t_swift = swift_path_throughput()
    t_direct = falkon_direct_throughput()
    t_pbs = gram_pbs_throughput_sim()
    save_json("throughput_fig12", {
        "swift_falkon_tps": t_swift, "falkon_direct_tps": t_direct,
        "gram_pbs_tps": t_pbs, "improvement": t_swift / t_pbs})
    return [{
        "name": "throughput.fig12",
        "us_per_call": 1e6 / t_swift,
        "derived": (f"swift+falkon={t_swift:.0f} t/s, "
                    f"falkon-direct={t_direct:.0f} t/s, gram+pbs={t_pbs:.1f} "
                    f"t/s -> {t_swift / t_pbs:.0f}x (paper: 56 vs 2 = 23x; "
                    f"direct > engine as in paper)"),
    }]
