"""Observability overhead smoke + sample-trace generator (DESIGN.md §12).

Two claims are gated here:

  * **Overhead**: a traced 10^5-task run (engine lifecycle hooks with
    default 1-in-16 sampling) stays within 5% of untraced throughput —
    the hot-path contract is one ``is not None`` test per hook with no
    tracer, and a counter bump plus O(1) critical-path update per
    non-sampled task with one.  Measured interleaved best-of-N so the
    assertion is robust to CI timer noise; ``OBS_OVERHEAD_TASKS`` scales
    the task count (default 100,000).
  * **Boundedness**: the traced run's span store, event logs, and stage
    table all stay within their caps regardless of task count.

The module also regenerates ``results/sample_trace.json`` — a small
fully-sampled fMRI run on a traced Falkon pool, exported as Chrome
trace-event JSON and schema-checked with `tools.trace_view`.  The file is
committed, the simulation is deterministic, and CI re-validates the
committed copy, so the sample in the repo is always loadable in
``chrome://tracing`` / Perfetto.
"""
from __future__ import annotations

import gc
import json
import os
import time

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, SimClock, Tracer, build_report)

from benchmarks.common import (RESULTS_DIR, attach_observability,
                               falkon_engine, fmri_workflow, save_json)
from benchmarks.million_tasks import build_workload


def _measure_once(n_tasks: int, traced: bool) -> tuple[float, object]:
    """One untimed-build + timed-run of the MolDyn-shaped workload;
    returns (run wall seconds, tracer or None)."""
    eng, svc = falkon_engine(executors=512, alloc_latency=81.0,
                             engine_kwargs={"provenance": "summary"})
    tracer = None
    if traced:
        tracer, _registry = attach_observability(eng, services=[svc])
    n, out = build_workload(eng, n_tasks, job_s=168.0)
    # the comparison measures the tracing hooks, not collector scheduling:
    # without this, the previous run's graph teardown lands as cycle-GC
    # pauses inside whichever timed region allocates next (±15% noise)
    gc.collect()
    gc.disable()
    t0 = time.monotonic()
    try:
        eng.run()
        wall = time.monotonic() - t0
    finally:
        gc.enable()
    assert out.resolved and eng.tasks_completed == n
    if traced:
        assert tracer.tasks_seen == n and tracer.tasks_done == n
    return wall, tracer


def measure_overhead(n_tasks: int, repeats: int = 4) -> dict:
    """Paired traced-vs-untraced comparison, `repeats` rounds.

    Machine noise here (CPU frequency, cache pressure from the growing
    heap) is several times the effect being measured, but it drifts
    slowly — so each round runs both modes back to back and takes their
    *ratio*, which cancels the shared drift; the in-round ordering bias
    alternates sign round to round.  The gate uses the minimum round
    ratio: deterministic hook cost is a floor under every round, so the
    cleanest round is the accurate one (the classic min-wall estimator,
    applied to ratios)."""
    best = {False: float("inf"), True: float("inf")}
    tracer = None
    rounds = []
    for rep in range(repeats):
        order = (False, True) if rep % 2 == 0 else (True, False)
        walls = {}
        for traced in order:
            walls[traced], tr = _measure_once(n_tasks, traced)
            if walls[traced] < best[traced]:
                best[traced] = walls[traced]
            if tr is not None:
                tracer = tr
        rounds.append(walls[True] / walls[False] - 1.0)

    # boundedness: caps hold no matter the task count
    snap = tracer.snapshot()
    assert snap["sampled_spans"] <= tracer.max_spans
    assert all(len(lg) <= lg.cap for lg in tracer.events.values())
    assert all(len(lg) <= lg.cap for lg in tracer.logs.values())
    assert tracer.tasks_seen == tracer.tasks_done

    return {
        "tasks": n_tasks,
        "untraced_s": round(best[False], 3),
        "traced_s": round(best[True], 3),
        "overhead_pct": round(100.0 * min(rounds), 2),
        "round_overheads_pct": [round(100.0 * r, 2) for r in rounds],
        "sampled_spans": snap["sampled_spans"],
        "sample_stride": snap["sample_stride"],
        "max_spans": tracer.max_spans,
    }


def build_sample_trace(volumes: int = 16) -> tuple[dict, dict]:
    """Run a small fully-sampled fMRI workflow on a traced Falkon pool and
    return ``(chrome_trace_dict, report_dict)``.  Deterministic: the same
    call always produces byte-identical JSON."""
    clock = SimClock()
    tracer = Tracer(sample_every=1, max_spans=2048)
    svc = FalkonService(clock, FalkonConfig(
        dispatch_overhead=1.0 / 487.0,
        drp=DRPConfig(max_executors=8, alloc_latency=5.0, alloc_chunk=4)),
        trace=True, tracer=tracer)
    eng = Engine(clock, tracer=tracer)
    eng.add_site("falkon", FalkonProvider(svc), capacity=8)
    wf, out = fmri_workflow(eng, volumes)
    wf.run()
    assert out.resolved
    trace = tracer.export_chrome_trace()
    report = build_report(tracer, makespan=clock.now()).to_dict()
    return trace, report


def write_sample_trace(path: str | None = None) -> str:
    from tools.trace_view import validate_chrome_trace

    trace, _report = build_sample_trace()
    errors = validate_chrome_trace(trace)
    assert not errors, errors
    path = path or os.path.join(RESULTS_DIR, "sample_trace.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(trace, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def run() -> list[dict]:
    n_tasks = int(os.environ.get("OBS_OVERHEAD_TASKS", "100000"))
    r = measure_overhead(n_tasks)
    # acceptance gate: <= 5% throughput cost (best paired round)
    assert r["overhead_pct"] <= 5.0, r

    sample_path = write_sample_trace()
    trace, report = build_sample_trace()
    save_json("observability_report", report)

    rows = [{
        "name": f"observability.overhead.{n_tasks // 1000}k",
        "us_per_call": 1e6 * r["traced_s"] / r["tasks"],
        "derived": (f"{r['overhead_pct']:+.1f}% traced vs untraced "
                    f"({r['sampled_spans']} spans kept, "
                    f"stride {r['sample_stride']})"),
    }, {
        "name": "observability.sample_trace",
        "us_per_call": 0.0,
        "derived": (f"{len(trace['traceEvents'])} events -> "
                    f"{os.path.basename(sample_path)}; "
                    f"{report['tasks']['done']} tasks, "
                    f"cp ratio {report['critical_path_ratio']:.2f}"),
    }]
    save_json("observability_overhead", r)
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']}: {row['derived']}")
