"""Observability overhead smoke + sample-trace generator (DESIGN.md §12).

Two claims are gated here:

  * **Overhead**: a traced 10^5-task run (engine lifecycle hooks with
    default 1-in-16 sampling) stays within 5% of untraced throughput —
    the hot-path contract is one ``is not None`` test per hook with no
    tracer, and a counter bump plus O(1) critical-path update per
    non-sampled task with one.  The same gate covers a tracer + health
    monitor run (DESIGN.md §13: one dict probe, strided turnaround
    sampling, counter-delta error windows off the completion path) and a
    *journaled* run (DESIGN.md §15: a sqlite-backed `JobStore` journal on
    the same hooks — terminal durability buffers one row per completion
    and hands batches to a background writer thread, so the clock thread
    never touches sqlite).
    Measured best-of-N across fresh interpreters so the assertion is
    robust to per-process layout bias as well as timer noise;
    ``OBS_OVERHEAD_TASKS`` scales the task count (default 100,000).
  * **Boundedness**: the traced run's span store, event logs, and stage
    table all stay within their caps regardless of task count.

The module also regenerates ``results/sample_trace.json`` — a small
fully-sampled fMRI run on a traced Falkon pool, exported as Chrome
trace-event JSON and schema-checked with `tools.trace_view`.  The file is
committed, the simulation is deterministic, and CI re-validates the
committed copy, so the sample in the repo is always loadable in
``chrome://tracing`` / Perfetto.
"""
from __future__ import annotations

import gc
import json
import os
import time

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, SimClock, Tracer, build_report)

from benchmarks.common import (RESULTS_DIR, attach_observability,
                               falkon_engine, fmri_workflow, save_json)
from benchmarks.million_tasks import build_workload

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _measure_once(n_tasks: int, traced: bool, monitored: bool = False,
                  journaled: bool = False) -> tuple[float, object]:
    """One untimed-build + timed-run of the MolDyn-shaped workload;
    returns (run wall seconds, tracer or None).  With ``monitored`` a
    `HealthMonitor` watches the engine and service on top of the tracer
    (no sink, no faults — the hot-path hook cost is what's measured).
    With ``journaled`` the engine journals into a throwaway `JobStore`
    (terminal durability, default batch) — the timed region covers the
    hooks and batch hand-offs; the background writer's fsyncs overlap
    the run and are drained outside the timer."""
    import tempfile

    eng, svc = falkon_engine(executors=512, alloc_latency=81.0,
                             engine_kwargs={"provenance": "summary"})
    tracer = None
    if traced:
        tracer, _registry = attach_observability(eng, services=[svc])
    if monitored:
        from repro.core import HealthMonitor
        hm = HealthMonitor(eng.clock, tracer=tracer)
        hm.watch(eng)
        hm.watch_service(svc)
    store = store_dir = None
    if journaled:
        from repro.core import JobStore
        store_dir = tempfile.mkdtemp(prefix="obs_journal_")
        store = JobStore(os.path.join(store_dir, "journal.db"))
        eng.journal = store.journal(default_wf="bench")
    n, out = build_workload(eng, n_tasks, job_s=168.0)
    # the comparison measures the tracing hooks, not collector scheduling:
    # without this, the previous run's graph teardown lands as cycle-GC
    # pauses inside whichever timed region allocates next (±15% noise)
    gc.collect()
    gc.disable()
    t0 = time.monotonic()
    try:
        eng.run()
        wall = time.monotonic() - t0
    finally:
        gc.enable()
    assert out.resolved and eng.tasks_completed == n
    if traced:
        assert tracer.tasks_seen == n and tracer.tasks_done == n
    if journaled:
        import shutil
        eng.journal.flush()
        store.sync()
        assert JobStore.peek(store.path, "bench")["done"] == n
        store.close()
        shutil.rmtree(store_dir, ignore_errors=True)
    return wall, tracer


_MODES = (("off", False, False, False), ("traced", True, False, False),
          ("monitored", True, True, False),
          ("journaled", False, False, True))


def _measure_subprocess(n_tasks: int, rounds: int, flip: bool) -> None:
    """``--measure`` child entry point: run all four modes back to back
    `rounds` times in this fresh interpreter and print one JSON line
    mapping each mode to its best wall."""
    best = {name: float("inf") for name, *_ in _MODES}
    for rep in range(rounds):
        order = _MODES if (rep % 2 == 0) != flip else _MODES[::-1]
        for name, traced, monitored, journaled in order:
            wall, _tr = _measure_once(n_tasks, traced, monitored, journaled)
            best[name] = min(best[name], wall)
    print(json.dumps({m: round(w, 6) for m, w in best.items()}))


def measure_overhead(n_tasks: int, procs: int = 6,
                     rounds: int = 2) -> dict:
    """Min paired ratio across fresh interpreters.

    Two noise sources here each dwarf the few-% effect being gated, and
    they need different cures.  Machine speed is bursty over tens of
    seconds, so modes are only comparable when run back to back — each
    subprocess runs all three modes paired (alternating order to cancel
    in-pair drift) and contributes one ratio per comparison.  Code/heap
    layout and the hash seed are fixed per interpreter and their bias is
    *mode-specific* — one process can run the monitored loop 10-15% slow
    across every in-process round — so ratios from a single process are
    one draw of that bias; `procs` fresh interpreters redraw it, and the
    gate takes the minimum paired ratio.  Deterministic hook cost is a
    floor under every draw, so the cleanest draw is the accurate one
    (the classic min-wall estimator, applied to paired ratios)."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([
        os.path.join(_ROOT, "src"), _ROOT,
        env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    ratios: dict[str, list] = {"traced": [], "monitored": [],
                               "journaled": []}
    walls: dict[str, list] = {name: [] for name, *_ in _MODES}
    for k in range(procs):
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.observability",
             "--measure", str(n_tasks), str(rounds), str(k % 2)],
            capture_output=True, text=True, env=env, cwd=_ROOT,
            check=True)
        best = json.loads(out.stdout.strip().splitlines()[-1])
        for name in walls:
            walls[name].append(best[name])
        ratios["traced"].append(best["traced"] / best["off"] - 1.0)
        ratios["monitored"].append(best["monitored"] / best["off"] - 1.0)
        ratios["journaled"].append(best["journaled"] / best["off"] - 1.0)

    # boundedness: caps hold no matter the task count (one in-process
    # traced run just for the snapshot — its wall is not part of the gate)
    _wall, tracer = _measure_once(n_tasks, traced=True)
    snap = tracer.snapshot()
    assert snap["sampled_spans"] <= tracer.max_spans
    assert all(len(lg) <= lg.cap for lg in tracer.events.values())
    assert all(len(lg) <= lg.cap for lg in tracer.logs.values())
    assert tracer.tasks_seen == tracer.tasks_done

    return {
        "tasks": n_tasks,
        "untraced_s": round(min(walls["off"]), 3),
        "traced_s": round(min(walls["traced"]), 3),
        "monitored_s": round(min(walls["monitored"]), 3),
        "journaled_s": round(min(walls["journaled"]), 3),
        "overhead_pct": round(100.0 * min(ratios["traced"]), 2),
        "monitored_overhead_pct": round(
            100.0 * min(ratios["monitored"]), 2),
        "journaled_overhead_pct": round(
            100.0 * min(ratios["journaled"]), 2),
        "proc_overheads_pct": [round(100.0 * r, 2)
                               for r in ratios["traced"]],
        "proc_monitored_pct": [round(100.0 * r, 2)
                               for r in ratios["monitored"]],
        "proc_journaled_pct": [round(100.0 * r, 2)
                               for r in ratios["journaled"]],
        "sampled_spans": snap["sampled_spans"],
        "sample_stride": snap["sample_stride"],
        "max_spans": tracer.max_spans,
    }


def build_sample_trace(volumes: int = 16) -> tuple[dict, dict]:
    """Run a small fully-sampled fMRI workflow on a traced Falkon pool and
    return ``(chrome_trace_dict, report_dict)``.  Deterministic: the same
    call always produces byte-identical JSON."""
    clock = SimClock()
    tracer = Tracer(sample_every=1, max_spans=2048)
    svc = FalkonService(clock, FalkonConfig(
        dispatch_overhead=1.0 / 487.0,
        drp=DRPConfig(max_executors=8, alloc_latency=5.0, alloc_chunk=4)),
        trace=True, tracer=tracer)
    eng = Engine(clock, tracer=tracer)
    eng.add_site("falkon", FalkonProvider(svc), capacity=8)
    wf, out = fmri_workflow(eng, volumes)
    wf.run()
    assert out.resolved
    trace = tracer.export_chrome_trace()
    report = build_report(tracer, makespan=clock.now()).to_dict()
    return trace, report


def write_sample_trace(path: str | None = None) -> str:
    from tools.trace_view import validate_chrome_trace

    trace, _report = build_sample_trace()
    errors = validate_chrome_trace(trace)
    assert not errors, errors
    path = path or os.path.join(RESULTS_DIR, "sample_trace.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(trace, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def run() -> list[dict]:
    n_tasks = int(os.environ.get("OBS_OVERHEAD_TASKS", "100000"))
    r = measure_overhead(n_tasks)
    # acceptance gates: <= 5% throughput cost (best paired round) for the
    # tracer alone, for tracer + health monitor (DESIGN.md §13), and for
    # the JobStore journal (DESIGN.md §15)
    assert r["overhead_pct"] <= 5.0, r
    assert r["monitored_overhead_pct"] <= 5.0, r
    assert r["journaled_overhead_pct"] <= 5.0, r

    sample_path = write_sample_trace()
    trace, report = build_sample_trace()
    save_json("observability_report", report)

    rows = [{
        "name": f"observability.overhead.{n_tasks // 1000}k",
        "us_per_call": 1e6 * r["traced_s"] / r["tasks"],
        "derived": (f"{r['overhead_pct']:+.1f}% traced, "
                    f"{r['monitored_overhead_pct']:+.1f}% monitored, "
                    f"{r['journaled_overhead_pct']:+.1f}% journaled vs "
                    f"untraced ({r['sampled_spans']} spans kept, "
                    f"stride {r['sample_stride']})"),
    }, {
        "name": "observability.sample_trace",
        "us_per_call": 0.0,
        "derived": (f"{len(trace['traceEvents'])} events -> "
                    f"{os.path.basename(sample_path)}; "
                    f"{report['tasks']['done']} tasks, "
                    f"cp ratio {report['critical_path_ratio']:.2f}"),
    }]
    save_json("observability_overhead", r)
    return rows


if __name__ == "__main__":
    import sys

    if len(sys.argv) > 1 and sys.argv[1] == "--measure":
        _measure_subprocess(int(sys.argv[2]), int(sys.argv[3]),
                            sys.argv[4] == "1")
    else:
        for row in run():
            print(f"{row['name']}: {row['derived']}")
