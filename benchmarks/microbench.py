"""Paper §4 microbenchmarks: dispatch throughput, executor scaling, queue
depth.  Paper claims: 487 tasks/s, 54,000 executors, 1.5 M queued tasks."""
from __future__ import annotations

import time

from repro.core import Engine, RealClock, SimClock
from benchmarks.common import falkon_engine, save_json


def measured_dispatch_throughput(n_tasks: int = 20_000) -> float:
    """Real-clock tasks/s through the full engine (sleep-0 tasks)."""
    eng = Engine(RealClock())
    eng.local_site(concurrency=64)
    t0 = time.monotonic()
    outs = [eng.submit(f"t{i}", None) for i in range(n_tasks)]
    eng.run()
    dt = time.monotonic() - t0
    assert all(o.resolved for o in outs)
    return n_tasks / dt


def executor_scaling(n_executors: int = 54_000, n_tasks: int = 100_000):
    """Sim: the service manages a 54k-executor pool (paper's scale)."""
    eng, svc = falkon_engine(executors=n_executors, alloc_latency=0.0)
    svc.provision(n_executors)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(n_tasks)]
    eng.run()
    assert all(o.resolved for o in outs)
    return {"executors": len(svc.executors) + 0,
            "dispatched": svc.utilization()["dispatched"]}


def queue_depth(n_tasks: int = 1_500_000):
    """Sim: 1.5 M tasks queued (paper's scale) without provisioning."""
    eng, svc = falkon_engine(executors=0, alloc_latency=0.0)
    for i in range(n_tasks):
        eng.submit(f"t{i}", None, duration=0.0)
    # tasks are queued (no executors); peak queue is the claim
    return svc.peak_queue


def run() -> list[dict]:
    thr = measured_dispatch_throughput()
    scal = executor_scaling()
    depth = queue_depth(200_000)  # scaled: 200k queued in-memory here
    rows = [
        {"name": "microbench.dispatch_throughput",
         "us_per_call": 1e6 / thr,
         "derived": f"{thr:.0f} tasks/s (paper: 487 t/s streamlined)"},
        {"name": "microbench.executor_scaling",
         "us_per_call": 0.0,
         "derived": f"{scal['executors']} executors managed "
                    f"(paper: 54,000)"},
        {"name": "microbench.queue_depth",
         "us_per_call": 0.0,
         "derived": f"{depth} tasks queued (paper: 1.5M; scaled run)"},
    ]
    save_json("microbench", rows)
    return rows
