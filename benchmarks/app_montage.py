"""Paper Fig 14: Montage astronomy workflow (M16 3x3 deg mosaic).

~440 input images, ~2,200 overlap pairs; twelve stages with the dynamic
mDiffFit fan-out determined at runtime from the mOverlaps output table
(the paper's signature dynamic-workflow case).  Three execution modes:
  * swift+falkon (16 executors)
  * swift+gram+clustering (16 bundles)
  * "MPI" — per-stage barrier execution with zero dispatch overhead, the
    paper's hand-coded baseline (mAdd parallelized, as in the MPI code)
Paper: Falkon ~= MPI (5% faster excluding final mAdd); clustering slower.
"""
from __future__ import annotations

import os
import tempfile

from repro.core import (CSVMapper, Dataset, Engine, INT, STRING, SimClock,
                        Struct, Workflow)
from benchmarks.common import batch_engine, falkon_engine, save_json

N_IMAGES = 440
N_OVERLAPS = 2200
NODES = 16

# stage -> (per-task duration s, parallelism source)
DUR = {
    "mProjectPP": 6.0, "mDiffFit": 2.0, "mConcatFit": 25.0,
    "mBgModel": 40.0, "mBackground": 1.5, "mImgtbl": 15.0,
    "mAddSub": 30.0, "mAddFinal": 180.0, "mShrink": 10.0, "mJPEG": 5.0,
}

DiffRec = Struct("DiffStruct", (("cntr1", INT), ("cntr2", INT),
                                ("plus", STRING), ("minus", STRING),
                                ("diff", STRING)))


def montage(eng, mpi_mode: bool, workdir: str) -> float:
    wf = Workflow("montage", eng)

    def proc(name, dur=None):
        return wf.sim_proc(name, duration=dur or DUR[name])

    # 1. project every raw image
    projected = wf.foreach(list(range(N_IMAGES)), proc("mProjectPP"))

    # 2. compute the overlap table — its CONTENT defines the next stage
    def write_overlaps(_projected):
        path = os.path.join(workdir, "diffs.tbl")
        with open(path, "w") as f:
            f.write("cntr1|cntr2|plus|minus|diff\n")
            for i in range(N_OVERLAPS):
                a, b = i % N_IMAGES, (i * 7 + 1) % N_IMAGES
                f.write(f"{a}|{b}|p_{a}.fits|p_{b}.fits|"
                        f"diff.{a:06d}.{b:06d}.fits\n")
        return Dataset(CSVMapper(path, header=True, hdelim="|",
                                 types=DiffRec), "diffs")

    tbl = eng.submit("mOverlaps", write_overlaps, [projected], duration=20.0)

    # 3. dynamic fan-out over the runtime-computed table (paper Fig 3)
    diffs = wf.foreach(tbl, lambda rec: proc("mDiffFit")(rec["diff"]))

    fit = proc("mConcatFit")(diffs)
    bg_model = proc("mBgModel")(fit)
    rectified = wf.foreach(list(range(N_IMAGES)),
                           lambda i: proc("mBackground")(i, bg_model))
    imgtbl = proc("mImgtbl")(rectified)

    # 4. conditional sub-region co-add (runtime decision on mosaic size)
    n_sub = 8
    subs = wf.foreach(list(range(n_sub)), lambda i: proc("mAddSub")(i, imgtbl))
    # final mAdd: parallelized only in the MPI version (paper note)
    if mpi_mode:
        final = wf.foreach(list(range(NODES)),
                           lambda i: proc("mAddFinal", DUR["mAddFinal"]
                                          / NODES)(i, subs))
    else:
        final = proc("mAddFinal")(subs)
    shrunk = proc("mShrink")(final)
    out = proc("mJPEG")(shrunk)
    wf.run()
    assert out.resolved
    return eng.clock.now()


def run() -> list[dict]:
    with tempfile.TemporaryDirectory() as d:
        eng, _ = falkon_engine(executors=NODES, alloc_latency=81.0)
        t_falkon = montage(eng, False, d)

        eng = batch_engine(nodes=NODES, submit_rate=0.5, sched_latency=60.0,
                           clustering=True, bundle=N_OVERLAPS // NODES // 8,
                           window=2.0)
        t_cluster = montage(eng, False, d)

        # MPI baseline: no dispatch overhead, per-stage barriers inherent
        eng, _ = falkon_engine(executors=NODES, alloc_latency=0.0,
                               dispatch_overhead=0.0)
        t_mpi = montage(eng, True, d)

    # paper: "if we omit the final mAdd phase, Swift over Falkon is ~5%
    # faster than MPI" (mAdd is parallelized only in the MPI code)
    ratio_excl = (t_falkon - DUR["mAddFinal"]) / \
        (t_mpi - DUR["mAddFinal"] / NODES)
    save_json("app_montage_fig14", {
        "falkon_s": t_falkon, "gram_clustering_s": t_cluster, "mpi_s": t_mpi,
        "falkon_vs_mpi_excl_madd": ratio_excl})
    return [{
        "name": "app_montage.fig14",
        "us_per_call": 0.0,
        "derived": (f"falkon={t_falkon:.0f}s vs mpi={t_mpi:.0f}s "
                    f"(ratio {t_falkon / t_mpi:.2f}; excl final mAdd "
                    f"{ratio_excl:.2f} — paper: ~0.95), "
                    f"clustering={t_cluster:.0f}s (slower, as in paper)"),
    }]
