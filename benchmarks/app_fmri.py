"""Paper Fig 13: fMRI workflow execution time, 120-480 volumes.

Providers: GRAM+PBS (throttled submission), GRAM+PBS with clustering
(paper: up to 4x better), Falkon with 8 executors (paper: further 40-70%
cut; up to ~90% total reduction vs plain GRAM+PBS).
"""
from __future__ import annotations

from benchmarks.common import (PAPER, batch_engine, falkon_engine,
                               fmri_workflow, save_json)

VOLUME_SETS = [120, 240, 360, 480]


def run_provider(kind: str, volumes: int) -> float:
    if kind == "falkon":
        eng, _ = falkon_engine(executors=8,
                               alloc_latency=PAPER["gram_alloc_latency"])
    elif kind == "gram_clustering":
        eng = batch_engine(nodes=8, submit_rate=PAPER["gram_throttle"],
                           sched_latency=60.0, clustering=True,
                           bundle=volumes // 8, window=2.0)
    else:  # gram
        eng = batch_engine(nodes=8, submit_rate=PAPER["gram_throttle"],
                           sched_latency=60.0)
    wf, out = fmri_workflow(eng, volumes)
    wf.run()
    assert out.resolved
    return eng.clock.now()


def run() -> list[dict]:
    table = {}
    for v in VOLUME_SETS:
        table[v] = {k: run_provider(k, v)
                    for k in ("gram", "gram_clustering", "falkon")}
    save_json("app_fmri_fig13", table)
    v = 480
    t = table[v]
    red = 1 - t["falkon"] / t["gram"]
    clu = t["gram"] / t["gram_clustering"]
    return [{
        "name": "app_fmri.fig13",
        "us_per_call": 0.0,
        "derived": (f"{v} vols: gram={t['gram']:.0f}s, "
                    f"clustering={t['gram_clustering']:.0f}s "
                    f"({clu:.1f}x), falkon={t['falkon']:.0f}s "
                    f"(-{red:.0%}; paper: clustering up to 4x, "
                    f"falkon up to 90% reduction)"),
    }]
