"""Paper Table 1 + §3.7: workflow code-size reduction.

We count non-blank, non-comment lines of (a) our DSL workflow definitions
(examples/*.py core sections) and (b) mechanically generated explicit-DAG
scripts for the same workflows (the paper's "Generator" encoding: one line
per task + one per dependency), mirroring the SwiftScript-vs-Script/Generator
comparison.  Also reproduces the Montage claim (92-line SwiftScript vs 950-
line MPI / ~1200-line Pegasus C generator).
"""
from __future__ import annotations

import inspect

from benchmarks.common import save_json


def dsl_fmri_source() -> str:
    return '''
type Volume { Image img; Header hdr; }
def reorient(v, direction): ...
def alignlinear(ref, v): ...
def reslice(v, air): ...
run = Dataset(FileSystemMapper(location, "bold1"))
yr = wf.foreach(run, lambda v: reorient(v, "y"))
xr = wf.foreach(yr, lambda v: reorient(v, "x"))
air = wf.foreach(xr, lambda v: alignlinear(xr.get()[0], v))
out = wf.foreach(zip(xr, air), lambda p: reslice(*p))
'''


def generated_fmri_script(volumes: int) -> str:
    """The paper's 'Generator' encoding: explicit task + dependency lines."""
    lines = []
    for v in range(volumes):
        lines.append(f"task reorient_y_{v} = run('reorient', 'bold1_{v}.img',"
                     f" 'bold1_{v}.hdr', 'y', 'n')")
    for v in range(volumes):
        lines.append(f"task reorient_x_{v} = run('reorient', out of "
                     f"reorient_y_{v}, 'x', 'n')")
        lines.append(f"depends reorient_x_{v} <- reorient_y_{v}")
    for v in range(volumes):
        lines.append(f"task align_{v} = run('alignlinear', ref, out of "
                     f"reorient_x_{v}, 12, 1000, 1000)")
        lines.append(f"depends align_{v} <- reorient_x_{v}")
    for v in range(volumes):
        lines.append(f"task reslice_{v} = run('reslice', out of align_{v})")
        lines.append(f"depends reslice_{v} <- align_{v}")
    lines.append("run_all()")
    return "\n".join(lines)


def loc(text: str) -> int:
    return sum(1 for ln in text.splitlines()
               if ln.strip() and not ln.strip().startswith(("#", "//")))


def example_loc(path: str) -> int:
    try:
        with open(path) as f:
            return loc(f.read())
    except FileNotFoundError:
        return -1


def run() -> list[dict]:
    import os
    ex = os.path.join(os.path.dirname(__file__), "..", "examples")
    table = {
        "fmri": {
            "dsl_loc": loc(dsl_fmri_source()),
            "generator_loc_120vol": loc(generated_fmri_script(120)),
            "paper": {"AIRSN_swift": 37, "AIRSN_generator": 400,
                      "FEAT_swift": 13, "FEAT_generator": 191},
        },
        "examples": {
            "fmri_workflow.py": example_loc(
                os.path.join(ex, "fmri_workflow.py")),
            "montage_workflow.py": example_loc(
                os.path.join(ex, "montage_workflow.py")),
            "moldyn_workflow.py": example_loc(
                os.path.join(ex, "moldyn_workflow.py")),
        },
        "montage_paper": {"swiftscript": 92, "mpi_cpp": 950,
                          "pegasus_generator_c": 1200},
    }
    save_json("code_size_table1", table)
    f = table["fmri"]
    ratio = f["generator_loc_120vol"] / max(1, f["dsl_loc"])
    return [{
        "name": "code_size.table1",
        "us_per_call": 0.0,
        "derived": (f"fMRI: DSL {f['dsl_loc']} LOC vs generated "
                    f"{f['generator_loc_120vol']} LOC ({ratio:.0f}x; paper "
                    f"AIRSN 37 vs ~400 = 11x)"),
    }]
