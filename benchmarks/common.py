"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import resource
import time

from repro.core import (BatchSchedulerProvider, ClusteringProvider, DRPConfig,
                        Engine, FalkonConfig, FalkonProvider, FalkonService,
                        MetricsRegistry, SimClock, Tracer, Workflow,
                        build_report)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


class PeakRssTracker:
    """Track a measurement's peak RSS by sampling /proc/self/statm.

    `ru_maxrss` is unusable as a per-measurement statistic: it is a
    lifetime high-water mark (earlier suite work poisons it) and the
    counter survives fork+exec, so even a fresh subprocess inherits its
    parent's peak (measured on this kernel; the VmHWM reset via
    /proc/self/clear_refs is also unavailable in sandboxes).  Sampling
    *current* RSS — at allocation-heavy milestones plus a clock-driven
    cadence during the run (`attach`) — bounds the true peak tightly for
    smoothly-allocating workloads.  Falls back to `ru_maxrss` where
    /proc is absent.
    """

    def __init__(self):
        self.peak_mb = 0.0
        self._page_mb = os.sysconf("SC_PAGE_SIZE") / (1024.0 * 1024.0)

    def sample(self) -> float:
        try:
            with open("/proc/self/statm") as f:
                mb = int(f.read().split()[1]) * self._page_mb
        except (OSError, ValueError, IndexError):
            mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
        if mb > self.peak_mb:
            self.peak_mb = mb
        return mb

    def attach(self, clock, done_future, interval: float) -> None:
        """Sample every `interval` simulated seconds until `done_future`
        resolves.  Sampler events mutate no scheduler state, so runs
        replay identically with or without tracking (report the makespan
        from the output future's resolution time, not `clock.now()` —
        the final pending sampler event outlives the workload)."""

        def sampler():
            self.sample()
            if not done_future.done:
                clock.schedule(interval, sampler)

        clock.schedule(0.0, sampler)


def attach_observability(eng, services=(), sample_every: int = 16,
                         **tracer_kw):
    """Attach one `Tracer` + `MetricsRegistry` to a built engine (or
    `FederatedEngine`) and its services — the standard benchmark wiring
    for DESIGN.md §12.  Every component shares the single tracer, so
    lifecycle spans, DRP allocations, staging bytes, and mailbox flushes
    land in one deterministic stream; the registry snapshots each
    component's bounded metrics into the run report.

    Call *after* sites/services are constructed and *before* submitting
    work.  Returns ``(tracer, registry)``; pass both to `run_measured`
    (or call `build_report` yourself) to get the standard report schema.
    """
    tracer = Tracer(sample_every=sample_every, **tracer_kw)
    registry = MetricsRegistry()
    shards = getattr(eng, "shards", None)
    if shards is not None:             # duck-typed FederatedEngine
        eng.tracer = tracer
        for sh in shards:
            sh.tracer = tracer
        for mb in eng.mailboxes:
            mb.tracer = tracer
        registry.register("federation", eng)
    else:
        eng.tracer = tracer
        registry.register("engine", eng)
    for i, svc in enumerate(services):
        svc.tracer = tracer
        if getattr(svc, "data_layer", None) is not None:
            svc.data_layer.tracer = tracer
        if getattr(svc, "pool", None) is not None:
            svc.pool.tracer = tracer
        name = getattr(svc, "name", f"svc{i}")
        if name in registry.names():
            name = f"{name}#{i}"
        registry.register(name, svc)
    registry.register("tracer", tracer)
    return tracer, registry


def run_measured(eng, out, expected_tasks: int,
                 sample_interval: float, tracer=None, registry=None) -> dict:
    """Run a built workload to completion with peak-RSS tracking.

    One copy of the measurement protocol for the scale benchmarks: sample
    RSS now (an eagerly-built graph is fully live at this point), track it
    on a clock cadence, capture the makespan at `out`'s resolution (not
    `clock.now()` — the final pending sampler event outlives the
    workload), and assert completion.  With a `tracer` attached
    (`attach_observability`), the result additionally carries the
    standard run report (schema ``repro.run_report/v1``) under
    ``"report"``.
    """
    tracker = PeakRssTracker()
    tracker.sample()
    done_at: list = []
    out.on_done(lambda _f: done_at.append(eng.clock.now()))
    tracker.attach(eng.clock, out, interval=sample_interval)
    t1 = time.monotonic()
    eng.run()
    run_s = time.monotonic() - t1
    assert out.resolved, "workflow did not complete"
    assert eng.tasks_completed == expected_tasks
    tracker.sample()
    res = {
        "run_s": run_s,
        "makespan_sim_s": done_at[0],
        "peak_rss_mb": tracker.peak_mb,
    }
    if tracer is not None:
        res["report"] = build_report(tracer, registry,
                                     makespan=done_at[0]).to_dict()
    return res

# paper-calibrated provider parameters (see DESIGN.md §6)
PAPER = {
    "falkon_throughput": 487.0,        # tasks/s (§4 microbenchmark)
    "falkon_old_throughput": 120.0,    # tasks/s (Fig 12, older code base)
    "gram_pbs_throughput": 2.0,        # jobs/s (Fig 12)
    "gram_throttle": 0.2,              # jobs/s (§5.4.3 MolDyn: 1/5 js)
    "pbs_sched_latency": 133.0,        # s; fits Fig 6 (90% at 1200 s tasks)
    "condor672_overhead": 2.0,         # s/task (0.5 jobs/s measured)
    "condor693_overhead": 0.0909,      # s/task (derived, §4)
    "gram_alloc_latency": 81.0,        # s (Fig 15 first-job queue time)
}


def falkon_engine(clock=None, executors=64, alloc_latency=81.0,
                  dispatch_overhead=1.0 / 487.0, engine_kwargs=None):
    clock = clock or SimClock()
    eng = Engine(clock, **(engine_kwargs or {}))
    svc = FalkonService(clock, FalkonConfig(
        dispatch_overhead=dispatch_overhead,
        drp=DRPConfig(max_executors=executors, alloc_latency=alloc_latency,
                      alloc_chunk=executors)))
    eng.add_site("falkon", FalkonProvider(svc), capacity=executors)
    return eng, svc


def batch_engine(clock=None, nodes=64, submit_rate=1.0, sched_latency=None,
                 clustering=False, bundle=8, window=1.0):
    clock = clock or SimClock()
    eng = Engine(clock)
    prov = BatchSchedulerProvider(clock, nodes=nodes, submit_rate=submit_rate,
                                  sched_latency=sched_latency
                                  if sched_latency is not None
                                  else PAPER["pbs_sched_latency"])
    if clustering:
        prov = ClusteringProvider(clock, prov, window=window,
                                  bundle_size=bundle)
    eng.add_site("batch", prov, capacity=nodes)
    return eng


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path


def fmri_workflow(eng, volumes: int, stage_durations=(3.0, 3.0, 5.0, 4.0)):
    """The paper's 4-stage fMRI pipeline (reorient x2, alignlinear, reslice)."""
    wf = Workflow("fmri", eng)
    names = ["reorient_y", "reorient_x", "alignlinear", "reslice"]
    procs = [wf.sim_proc(n, duration=d)
             for n, d in zip(names, stage_durations)]
    out = wf.foreach(list(range(volumes)), procs[0])
    for p in procs[1:]:
        out = wf.foreach(out, p)
    return wf, out
