"""Paper Fig 10: pipelining effect on the 4-stage, 120-volume fMRI workflow.

Paper: 21% execution-time reduction with pipelining enabled.  Stage
durations carry deterministic per-volume jitter (real fMRI stage times vary),
executed on 64 executors so cross-stage overlap has room to help.

The pipelined run also carries the observability layer (DESIGN.md §12):
a full-sampling `Tracer` feeds `build_report`, whose per-stage breakdown
*is* the Fig-10 view — run seconds per stage plus the queue-wait the
barrier variant pays and the pipelined one doesn't — and lands in
``results/pipelining_fig10.json`` under ``"report"``.
"""
from __future__ import annotations

from repro.core import Workflow, build_report
from benchmarks.common import attach_observability, falkon_engine, save_json

VOLUMES = 120
STAGES = [("reorient_y", 3.0), ("reorient_x", 3.0),
          ("alignlinear", 6.0), ("reslice", 4.0)]


def _dur(stage_idx: int, v: int, base: float) -> float:
    return base * (0.5 + ((v * (stage_idx + 3)) % 7) / 4.0)


def run_mode(pipelined: bool, observe: bool = False):
    eng, svc = falkon_engine(executors=64, alloc_latency=0.0)
    tracer = registry = None
    if observe:
        # sample_every=1: 480 tasks — record every span, exact breakdown
        tracer, registry = attach_observability(eng, services=[svc],
                                                sample_every=1)
    wf = Workflow("fmri", eng)

    # task names are the *stage* names (per-volume identity lives in the
    # auto-generated task key), so the tracer's per-stage aggregation
    # yields exactly four rows, not one per volume
    if pipelined:
        def chain(v):
            f = None
            for i, (name, base) in enumerate(STAGES):
                args = [f] if f is not None else []
                f = eng.submit(name, None, args, duration=_dur(i, v, base))
            return f

        out = wf.gather([chain(v) for v in range(VOLUMES)])
    else:
        cur = [None] * VOLUMES
        barrier = None
        for i, (name, base) in enumerate(STAGES):
            nxt = []
            for v in range(VOLUMES):
                args = [x for x in (cur[v], barrier) if x is not None]
                nxt.append(eng.submit(name, None, args,
                                      duration=_dur(i, v, base)))
            cur = nxt
            barrier = wf.gather(cur)   # stage barrier
        out = barrier
    wf.run()
    assert out.resolved
    makespan = eng.clock.now()
    report = None
    if observe:
        report = build_report(tracer, registry, makespan=makespan).to_dict()
    return makespan, report


def run() -> list[dict]:
    t_barrier, rep_barrier = run_mode(False, observe=True)
    t_pipe, rep_pipe = run_mode(True, observe=True)
    reduction = (t_barrier - t_pipe) / t_barrier

    # the report reproduces the Fig-10 story: identical per-stage run
    # seconds (same bodies), with the barrier variant's extra makespan
    # visible as queue wait and a longer critical path ratio
    stage_names = {name for name, _ in STAGES}
    for rep in (rep_barrier, rep_pipe):
        assert set(rep["stages"]) == stage_names, rep["stages"].keys()
        assert rep["tasks"]["done"] == VOLUMES * len(STAGES)
    for name in stage_names:
        run_b = rep_barrier["stages"][name]["run_s_est"]
        run_p = rep_pipe["stages"][name]["run_s_est"]
        assert abs(run_b - run_p) < 1e-6 * max(1.0, run_b), (name, run_b,
                                                             run_p)

    save_json("pipelining_fig10", {
        "barrier_s": t_barrier, "pipelined_s": t_pipe,
        "reduction": reduction,
        "report": rep_pipe, "report_barrier": rep_barrier})
    return [{
        "name": "pipelining.fig10",
        "us_per_call": 0.0,
        "derived": (f"{reduction:.0%} reduction "
                    f"({t_barrier:.0f}s -> {t_pipe:.0f}s; paper: 21%)"),
    }]
