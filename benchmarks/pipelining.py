"""Paper Fig 10: pipelining effect on the 4-stage, 120-volume fMRI workflow.

Paper: 21% execution-time reduction with pipelining enabled.  Stage
durations carry deterministic per-volume jitter (real fMRI stage times vary),
executed on 64 executors so cross-stage overlap has room to help.
"""
from __future__ import annotations

from repro.core import Workflow
from benchmarks.common import falkon_engine, save_json

VOLUMES = 120
STAGES = [("reorient_y", 3.0), ("reorient_x", 3.0),
          ("alignlinear", 6.0), ("reslice", 4.0)]


def _dur(stage_idx: int, v: int, base: float) -> float:
    return base * (0.5 + ((v * (stage_idx + 3)) % 7) / 4.0)


def run_mode(pipelined: bool) -> float:
    eng, _ = falkon_engine(executors=64, alloc_latency=0.0)
    wf = Workflow("fmri", eng)

    if pipelined:
        def chain(v):
            f = None
            for i, (name, base) in enumerate(STAGES):
                args = [f] if f is not None else []
                f = eng.submit(f"{name}-{v}", None, args,
                               duration=_dur(i, v, base))
            return f

        out = wf.gather([chain(v) for v in range(VOLUMES)])
    else:
        cur = [None] * VOLUMES
        barrier = None
        for i, (name, base) in enumerate(STAGES):
            nxt = []
            for v in range(VOLUMES):
                args = [x for x in (cur[v], barrier) if x is not None]
                nxt.append(eng.submit(f"{name}-{v}", None, args,
                                      duration=_dur(i, v, base)))
            cur = nxt
            barrier = wf.gather(cur)   # stage barrier
        out = barrier
    wf.run()
    assert out.resolved
    return eng.clock.now()


def run() -> list[dict]:
    t_barrier = run_mode(False)
    t_pipe = run_mode(True)
    reduction = (t_barrier - t_pipe) / t_barrier
    save_json("pipelining_fig10", {
        "barrier_s": t_barrier, "pipelined_s": t_pipe,
        "reduction": reduction})
    return [{
        "name": "pipelining.fig10",
        "us_per_call": 0.0,
        "derived": (f"{reduction:.0%} reduction "
                    f"({t_barrier:.0f}s -> {t_pipe:.0f}s; paper: 21%)"),
    }]
