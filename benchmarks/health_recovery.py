"""Headline health-recovery benchmark (DESIGN.md §13): a monitored run
routes around a failing site and recovers most of the lost throughput.

Scenario: 4 Falkon sites x 64 executors run a flat bag of N tasks
(``HEALTH_RECOVERY_TASKS``, default 20,000).  At t = 50% of the ideal
makespan one site starts failing half its tasks *slowly* (each failure
occupies its executor for `FAIL_LATENCY` seconds — the fail-slow mode
that actually hurts: fast failures just retry, slow ones clog executors
and strand queued work behind them).  Three runs:

  * **blind**     — no monitor.  The balancer's score decay sheds some
    load, but the failing site keeps winning a share of placements, its
    queue traps tasks behind slow failures, and Falkon host suspension
    thrashes (suspend / probe / fail) until the end of the run.
  * **monitored** — a `HealthMonitor` watches the same workload: windowed
    error rate degrades -> drains (suspending the site and revoking its
    queued tasks back to the engine, which re-places them on healthy
    sites without charging retries) -> blacklists after the failed
    probe.  The JSONL metrics stream lands in
    ``results/health_recovery_stream.jsonl``
    (watch live with ``python tools/live_monitor.py <file> --follow``).
  * **monitored replay** — same seed, second run: the health transition
    log must be byte-identical (the SimClock determinism contract).

Gates (the acceptance criteria for DESIGN.md §13):

  * recovery ratio — monitored tasks/s over the degraded interval (fault
    onset -> that run's own last completion) >= 1.5x the blind run's;
  * the failing site is blacklisted within one rolling window of onset;
  * the two monitored runs' transition logs are byte-identical;
  * the emitted stream validates against ``repro.metrics_stream/v1``.
"""
from __future__ import annotations

import os
import time

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, FaultInjector, HealthConfig,
                        HealthMonitor, RetryPolicy, SimClock, Tracer)

from benchmarks.common import RESULTS_DIR, save_json

JOB_S = 4.0          # per-task simulated duration
N_SITES = 4
CAP = 64             # executors per site
FAIL_SITE = "site3"
FAIL_P = 0.5
FAIL_LATENCY = 2 * JOB_S   # a failure holds its executor this long
SEED = 11

# Tuned for the scenario.  The budget is tight: failures are fail-slow,
# so the first failed attempt only *lands* in the windowed stats at
# onset + FAIL_LATENCY (8 s), and the whole degrade -> drain -> probe ->
# blacklist ladder must fit in the remaining 12 s.  The healthy sites run
# at zero error, so the thresholds can sit low without false drains; the
# short drain backoff makes the (failed) probe — and with it the second
# consecutive drain, which blacklists — follow within a tick or two.
MONITOR_CFG = HealthConfig(
    window=20.0, buckets=10, min_samples=8,
    degrade_error_rate=0.04, drain_error_rate=0.10,
    blacklist_error_rate=0.30, recover_error_rate=0.05,
    drain_backoff=2.0, backoff_factor=2.0, blacklist_backoff=100000.0,
    blacklist_after_drains=2, revoke_on_drain=True, emit_interval=5.0)


def fault_onset(n: int) -> float:
    """Fault start: 50% of the ideal (all-sites-healthy) makespan."""
    return 0.5 * n * JOB_S / (N_SITES * CAP)


def run_once(n: int, monitored: bool, stream_path: str | None = None) -> dict:
    clock = SimClock()
    tracer = Tracer(sample_every=64)
    t_fault = fault_onset(n)
    inj = FaultInjector(seed=SEED, clock=clock)
    inj.fail_site_window(FAIL_SITE, FAIL_P, start=t_fault,
                         latency=FAIL_LATENCY)
    eng = Engine(clock, tracer=tracer, fault_injector=inj,
                 retry_policy=RetryPolicy(max_retries=8, backoff=1.0),
                 provenance="summary")
    services = []
    for i in range(N_SITES):
        # host_suspend_time models paper-era per-host blacklisting (same
        # order as the DRP idle timeout): after 2 consecutive failures a
        # host sits out 300 s.  Under a site-wide intermittent fault this
        # is the failure mode the monitor exists for — hosts die off one
        # by one while the service keeps accepting work, so queued tasks
        # are trapped behind suspended hosts until the drain revokes them.
        svc = FalkonService(clock, FalkonConfig(
            host_suspend_time=300.0,
            drp=DRPConfig(max_executors=CAP, alloc_latency=0.0,
                          alloc_chunk=CAP)), name=f"site{i}")
        svc.provision(CAP)
        eng.add_site(f"site{i}", FalkonProvider(svc), capacity=CAP)
        services.append(svc)
    hm = None
    if monitored:
        hm = HealthMonitor(clock, MONITOR_CFG, tracer=tracer)
        hm.watch(eng)
        for svc in services:
            hm.watch_service(svc)
        if stream_path:
            hm.attach_sink(stream_path)

    # per-completion timestamps (successes only) — the makespan comes from
    # the last resolution, not clock.now(), which runs past the workload
    # on monitor probe/poke events
    done_t: list[float] = []
    failed = [0]

    def record(fut, _append=done_t.append, _clock=clock):
        if fut.resolved:
            _append(_clock.now())
        else:
            failed[0] += 1

    t0 = time.monotonic()
    for i in range(n):
        eng.submit(f"t{i}", None, duration=JOB_S).on_done(record)
    eng.run()
    wall = time.monotonic() - t0
    if hm is not None:
        hm.emit_line()          # final stream line at end of run
        hm.close()

    makespan = max(done_t)
    post = sum(1 for t in done_t if t >= t_fault)
    degraded_s = makespan - t_fault
    res = {
        "monitored": monitored,
        "tasks": n,
        "completed": len(done_t),
        "failed_permanently": failed[0],
        "t_fault": round(t_fault, 3),
        "makespan_s": round(makespan, 3),
        "degraded_interval_s": round(degraded_s, 3),
        "post_fault_tasks": post,
        "post_fault_tasks_per_s": round(post / degraded_s, 3),
        "revoked": eng.stats().get("revoked", 0),
        "wall_s": round(wall, 3),
    }
    if hm is not None:
        res["transition_log"] = hm.transition_log_json()
        res["transitions"] = list(hm.transitions)
        res["states"] = hm.states()
        res["stream_lines"] = hm.lines_emitted
    return res


def run() -> list[dict]:
    n = int(os.environ.get("HEALTH_RECOVERY_TASKS", "20000"))
    stream_path = os.path.join(RESULTS_DIR, "health_recovery_stream.jsonl")
    os.makedirs(RESULTS_DIR, exist_ok=True)

    blind = run_once(n, monitored=False)
    mon = run_once(n, monitored=True, stream_path=stream_path)
    replay = run_once(n, monitored=True)

    # determinism: same seed, same workload -> byte-identical health log
    assert mon["transition_log"] == replay["transition_log"], \
        "monitored replay diverged"

    # reaction time: the failing site must be blacklisted within one
    # rolling window of fault onset
    t_fault = mon["t_fault"]
    bl = [tr["t"] for tr in mon["transitions"]
          if tr["site"] == FAIL_SITE and tr["to"] == "blacklisted"]
    assert bl, f"{FAIL_SITE} never blacklisted: {mon['transitions']}"
    reaction_s = bl[0] - t_fault
    assert reaction_s <= MONITOR_CFG.window, \
        f"blacklist took {reaction_s:.1f}s (> window {MONITOR_CFG.window}s)"
    assert mon["states"][FAIL_SITE] == "blacklisted"

    # recovery: monitored throughput over the degraded interval
    ratio = (mon["post_fault_tasks_per_s"]
             / blind["post_fault_tasks_per_s"])
    assert ratio >= 1.5, \
        f"recovery ratio {ratio:.2f}x < 1.5x (mon={mon}, blind={blind})"

    # the emitted stream is schema-valid
    from tools.trace_view import validate_metrics_stream
    with open(stream_path, encoding="utf-8") as f:
        errors = validate_metrics_stream(f.readlines())
    assert not errors, errors

    payload = {
        "tasks": n,
        "t_fault_s": t_fault,
        "fail_site": FAIL_SITE,
        "fail_p": FAIL_P,
        "fail_latency_s": FAIL_LATENCY,
        "recovery_ratio": round(ratio, 3),
        "blacklist_reaction_s": round(reaction_s, 3),
        "window_s": MONITOR_CFG.window,
        "blind": {k: v for k, v in blind.items() if k != "transitions"},
        "monitored": {k: v for k, v in mon.items()
                      if k not in ("transitions", "transition_log")},
        "transitions": mon["transitions"],
        "stream_path": os.path.basename(stream_path),
    }
    save_json("health_recovery", payload)

    return [{
        "name": f"health_recovery.{n // 1000}k",
        "us_per_call": 1e6 * mon["wall_s"] / n,
        "derived": (f"{ratio:.2f}x recovery (mon "
                    f"{mon['post_fault_tasks_per_s']:.1f} t/s vs blind "
                    f"{blind['post_fault_tasks_per_s']:.1f} t/s); "
                    f"blacklisted {FAIL_SITE} in {reaction_s:.1f}s; "
                    f"{mon['revoked']} revoked; "
                    f"{mon['stream_lines']} stream lines"),
    }]


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']}: {row['derived']}")
