"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring
for the paper claim it reproduces).  Detailed JSON lands in
benchmarks/results/.
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "benchmarks.microbench",           # §4: 487 t/s, 54k executors, queue
    "benchmarks.efficiency",           # Fig 6
    "benchmarks.resource_efficiency",  # Fig 7
    "benchmarks.io_throughput",        # Fig 8
    "benchmarks.scalability",          # Fig 9
    "benchmarks.pipelining",           # Fig 10
    "benchmarks.load_balance",         # Fig 11
    "benchmarks.throughput",           # Fig 12
    "benchmarks.app_fmri",             # Fig 13
    "benchmarks.app_montage",          # Fig 14
    "benchmarks.app_moldyn",           # Fig 17/18
    "benchmarks.code_size",            # Table 1
    "benchmarks.vmap_clustering",      # TPU adaptation of clustering
    "benchmarks.roofline",             # §Roofline (from dry-run artifacts)
]


def main() -> int:
    import importlib

    print("name,us_per_call,derived")
    failed = 0
    for modname in MODULES:
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run()
            for r in rows:
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']:.3f},{derived}",
                      flush=True)
        except Exception:
            failed += 1
            print(f"{modname},nan,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
        sys.stderr.write(f"# {modname}: {time.time() - t0:.1f}s\n")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
