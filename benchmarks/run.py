"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring
for the paper claim it reproduces).  Detailed JSON lands in
benchmarks/results/.
"""
from __future__ import annotations

import os
import sys
import time
import traceback

# allow `python benchmarks/run.py` from anywhere (not just -m with
# PYTHONPATH set): make both the repo root and src/ importable
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

MODULES = [
    "benchmarks.microbench",           # §4: 487 t/s, 54k executors, queue
    "benchmarks.efficiency",           # Fig 6
    "benchmarks.resource_efficiency",  # Fig 7
    "benchmarks.io_throughput",        # Fig 8
    "benchmarks.scalability",          # Fig 9
    "benchmarks.pipelining",           # Fig 10
    "benchmarks.load_balance",         # Fig 11
    "benchmarks.throughput",           # Fig 12
    "benchmarks.app_fmri",             # Fig 13
    "benchmarks.app_montage",          # Fig 14
    "benchmarks.app_moldyn",           # Fig 17/18
    "benchmarks.code_size",            # Table 1
    "benchmarks.vmap_clustering",      # TPU adaptation of clustering
    "benchmarks.device_batching",      # §11: device-batched executor pool
    "benchmarks.roofline",             # §Roofline (from dry-run artifacts)
    "benchmarks.million_tasks",        # scheduler scale (smoke-sized here)
    "benchmarks.data_diffusion",       # §6: cache-aware data layer
    "benchmarks.federation",           # §8: multi-engine federation
    "benchmarks.streaming_expansion",  # §9: windowed graph construction
    "benchmarks.real_throughput",      # §10: real threads, Fig-6 shape
    "benchmarks.observability",        # §12: tracing overhead + sample trace
    "benchmarks.health_recovery",      # §13: monitored recovery vs blind
    "benchmarks.real_federation",      # §14: process-per-shard dispatchers
    "benchmarks.kill_resume",          # §15: SIGKILL + resume re-run bound
]


def main() -> int:
    import argparse
    import importlib

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run "
                         "(e.g. --only microbench,million_tasks); "
                         "used by the CI smoke tier")
    args = ap.parse_args()
    modules = MODULES
    if args.only:
        wanted = {w.strip() for w in args.only.split(",") if w.strip()}
        modules = [m for m in MODULES if m.split(".")[-1] in wanted]
        missing = wanted - {m.split(".")[-1] for m in modules}
        if missing:
            sys.stderr.write(f"unknown benchmark modules: {missing}\n")
            return 2

    print("name,us_per_call,derived")
    failed = 0
    for modname in modules:
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run()
            for r in rows:
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']:.3f},{derived}",
                      flush=True)
        except Exception:
            failed += 1
            print(f"{modname},nan,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
        sys.stderr.write(f"# {modname}: {time.time() - t0:.1f}s\n")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
