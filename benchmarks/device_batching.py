"""Device-batched executors (DESIGN.md §11): tasks/s vs bundle size.

The paper's Fig 6 shows per-job batch-scheduler overhead amortizing away as
clustering widens; the accelerator analogue is per-task dispatch + launch
amortizing into one jitted+vmapped device call per bundle.  We sweep the
`DeviceExecutorPool`'s `max_bundle` over the same task stream — submitted
through the full Engine -> Falkon -> pool stack, not a raw loop — and
measure end-to-end tasks/s plus the fraction of wall time spent inside
device execution (`pool.device_s / wall`).

The task body is a deliberately *small* multi-op procedure, written the way
a user writes one (NOT pre-jitted): at bundle size 1 every task pays
op-by-op dispatch (the overhead under study), while bundles fuse K tasks
into one launch.  The curve is Fig-6 shaped: throughput climbs steeply,
then flattens once dispatch is amortized.

Acceptance targets asserted here (CI runs this in the smoke tier):
  * >= 5x tasks/s at the largest bundle size vs per-task dispatch;
  * >= 80% of wall time inside device execution at the peak-throughput
    bundled configuration (bundled runs are device-bound, not
    dispatcher-bound).

Env knobs for CI sizing: DEVICE_BATCH_TASKS (default 256),
DEVICE_BATCH_ROWS / DEVICE_BATCH_DIM (per-task work shape).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DRPConfig, DeviceExecutorPool, Engine, FalkonConfig,
                        FalkonProvider, FalkonService, RealClock)
from repro.launch.hlo_cost import DurationPredictor
from benchmarks.common import save_json

N_TASKS = int(os.environ.get("DEVICE_BATCH_TASKS", "256"))
ROWS = int(os.environ.get("DEVICE_BATCH_ROWS", "16"))
DIM = int(os.environ.get("DEVICE_BATCH_DIM", "192"))
BUNDLE_SIZES = [1, 4, 16, 64, 256]
REPS = 3


def small_task(x, w):
    # a small MolDyn-style step as a user writes it: two contractions plus
    # a chain of elementwise ops.  Unjitted, each op is its own dispatch
    # (~tens of us on CPU backend) — the cost the pool's vmap fusion
    # amortizes; fused, the matmuls dominate, keeping bundles device-bound
    h = jnp.tanh(x @ w)
    for _ in range(18):
        h = h * jax.nn.sigmoid(h) + 0.5
        h = jnp.abs(h) ** 0.5 - jnp.cos(h)
    return jnp.sum(h @ w.T, axis=-1)


def _stack(max_bundle: int):
    clock = RealClock()
    pool = DeviceExecutorPool(clock, max_bundle=max_bundle)
    cfg = FalkonConfig(drp=DRPConfig(
        min_executors=N_TASKS, max_executors=N_TASKS,
        alloc_latency=0.0, alloc_chunk=N_TASKS))
    svc = FalkonService(clock, cfg, pool=pool)
    svc.provision(N_TASKS)
    eng = Engine(clock)
    eng.add_site("dev", FalkonProvider(svc), capacity=N_TASKS)
    return eng, svc, pool


def _measure(max_bundle: int, xs, w) -> dict:
    eng, svc, pool = _stack(max_bundle)

    def one():
        d0 = pool.device_s
        t0 = time.monotonic()
        futs = [eng.submit(f"t{i}", small_task, [xs[i], w], vmap_key="mm")
                for i in range(N_TASKS)]
        eng.run()
        wall = time.monotonic() - t0
        assert all(f.resolved for f in futs)
        return wall, pool.device_s - d0

    one()                                   # warm the vmapped jit cache
    wall, dev = min(one() for _ in range(REPS))   # steady state, best of 3
    svc.shutdown()
    return {
        "bundle": max_bundle,
        "wall_s": wall,
        "tasks_per_s": N_TASKS / wall,
        "device_s": dev,
        "device_frac": dev / wall,
        "bundles_run": pool.bundles_run,
        "fused_tasks": pool.fused_tasks,
    }


def run() -> list[dict]:
    xs = np.asarray(jax.random.normal(jax.random.PRNGKey(0),
                                      (N_TASKS, ROWS, DIM)), np.float32)
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (DIM, DIM)),
                   np.float32)
    rows = [_measure(b, xs, w) for b in BUNDLE_SIZES if b <= N_TASKS]

    base = rows[0]
    top = rows[-1]
    best = max(rows[1:], key=lambda r: r["tasks_per_s"])
    speedup = top["tasks_per_s"] / base["tasks_per_s"]
    # what the scheduler believed, for the same body/shapes: the priced
    # duration that steers the duration-aware balancer (DESIGN.md §11)
    predicted = DurationPredictor().predict_duration(small_task, [xs[0], w])

    save_json("device_batching", {
        "tasks": N_TASKS, "rows": ROWS, "dim": DIM,
        "sweep": rows,
        "speedup_largest_vs_single": speedup,
        "best_bundled_device_frac": best["device_frac"],
        "predicted_task_s": predicted,
    })

    # regression bounds (the PR's acceptance criteria — CI smoke tier)
    assert speedup >= 5.0, (
        f"bundled speedup {speedup:.2f}x < 5x at bundle={top['bundle']}")
    assert best["device_frac"] >= 0.8, (
        f"device fraction {best['device_frac']:.2f} < 0.8 "
        f"at bundle={best['bundle']}")

    return [{
        "name": "device_batching.amortization",
        "us_per_call": 1e6 * top["wall_s"] / N_TASKS,
        "derived": (f"{N_TASKS} tiny tasks: bundle=1 "
                    f"{base['tasks_per_s']:.0f} t/s -> "
                    f"bundle={top['bundle']} {top['tasks_per_s']:.0f} t/s "
                    f"= {speedup:.1f}x, device frac "
                    f"{best['device_frac']:.2f} (Fig-6-shaped amortization)"),
    }]


if __name__ == "__main__":
    for row in run():
        print(row["derived"])
