"""Paper §5.4.3 / Fig 17-18: MolDyn chemistry workflow, 244 molecules.

Structure: 1 + 84N jobs; per-molecule DAG = 3 serial prep jobs -> 68
independent CHARMM jobs -> 13 post jobs; ~235.4 CPU-minutes per molecule.
Falkon with DRP (up to 216 processors): paper measured 99.8% efficiency,
15,091 s makespan, 206.9x speedup.  GRAM/PBS (submission throttled to 0.2
jobs/s, one processor usable per dual-proc node): 25.3x speedup on a 50-
molecule subset.
"""
from __future__ import annotations

from repro.core import Engine, SimClock, Workflow
from benchmarks.common import PAPER, batch_engine, falkon_engine, save_json

SERIAL_PRE, PARALLEL, SERIAL_POST = 3, 68, 13
CPU_MIN_PER_MOL = 235.4


def _durations():
    total_s = CPU_MIN_PER_MOL * 60.0
    n_jobs = SERIAL_PRE + PARALLEL + SERIAL_POST
    base = total_s / n_jobs
    return base  # ~168 s/job (paper: "typical job duration ~200 s")


def moldyn(eng, molecules: int) -> tuple[float, float]:
    wf = Workflow("moldyn", eng)
    base = _durations()
    prep0 = eng.submit("annotate", None, duration=base)  # stage 1, shared
    finals = []
    for m in range(molecules):
        f = prep0
        for i in range(SERIAL_PRE):
            f = eng.submit(f"prep{m}.{i}", None, [f], duration=base)
        par = [eng.submit(f"charmm{m}.{j}", None, [f], duration=base)
               for j in range(PARALLEL)]
        g = wf.gather(par)
        for i in range(SERIAL_POST):
            g = eng.submit(f"post{m}.{i}", None, [g], duration=base)
        finals.append(g)
    out = wf.gather(finals)
    wf.run()
    assert out.resolved
    cpu_time = (1 + 84 * molecules) * base
    return eng.clock.now(), cpu_time


def run() -> list[dict]:
    # Falkon with DRP up to 216 processors
    eng, svc = falkon_engine(executors=216,
                             alloc_latency=PAPER["gram_alloc_latency"])
    makespan_f, cpu_f = moldyn(eng, 244)
    speedup_f = cpu_f / makespan_f
    util = svc.utilization()

    # GRAM/PBS: 0.2 jobs/s gateway, 100 usable processors (200 procs,
    # 1 per dual-proc node by site policy), 50 molecules (paper could not
    # complete 244 over GRAM/PBS)
    eng = batch_engine(nodes=100, submit_rate=PAPER["gram_throttle"],
                       sched_latency=60.0)
    makespan_p, cpu_p = moldyn(eng, 50)
    speedup_p = cpu_p / makespan_p

    save_json("app_moldyn_fig17", {
        "falkon": {"molecules": 244, "makespan_s": makespan_f,
                   "speedup": speedup_f, "peak_executors": util["executors"],
                   "efficiency": util["efficiency"],
                   "dispatched": util["dispatched"]},
        "gram_pbs": {"molecules": 50, "makespan_s": makespan_p,
                     "speedup": speedup_p},
    })
    return [{
        "name": "app_moldyn.fig17",
        "us_per_call": 0.0,
        "derived": (f"falkon 244mol: {makespan_f:.0f}s, speedup "
                    f"{speedup_f:.1f}x, eff {util['efficiency']:.1%} "
                    f"(paper: 15091s, 206.9x, 99.8%); gram/pbs 50mol: "
                    f"{speedup_p:.1f}x (paper: 25.3x)"),
    }]
