"""Federation benchmark (DESIGN.md §8): shard one workflow across N
engines with work stealing and a sharded data layer.

Two experiments, both deterministic under `SimClock`:

**Dispatch scaling** — a 1M-task MolDyn-shaped workflow (3 serial prep ->
68-wide fan-out -> gather -> 13 serial post per molecule) of *short* jobs,
the regime where the paper's 487 tasks/s dispatcher ceiling (§4,
`FalkonConfig(serialize_dispatch=True)`) binds before the executor pool
does.  A single engine saturates its one dispatcher; a 4-shard
`FederatedEngine` (same total executor count, one Falkon service per
shard) runs 4 dispatchers.  Acceptance (ISSUE 3): >= 1.5x the single
engine's aggregate *simulated* tasks/s at 4 shards.

**Skewed partition + work stealing** — the same federation fed through a
`skewed_partitioner` (70% of keys on shard 0) on a locality-heavy
workload (per-molecule archives via a `ShardedDataLayer`), with stealing
on vs off.  Work stealing must hold the per-shard idle fraction bounded
(every shard stays busy, not just the heavy one) and the steal-induced
restage bytes are reported from the stealer's bounded `StreamStat`
metrics — no per-task metric growth at any scale.

Usage:
  PYTHONPATH=src python -m benchmarks.federation                # 1M tasks
  PYTHONPATH=src python -m benchmarks.federation --tasks 100000 --shards 8
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, FederatedEngine, ShardedDataLayer,
                        SimClock, Workflow, skewed_partitioner)

from benchmarks.common import save_json
from benchmarks.million_tasks import build_workload as build_moldyn

JOB_S = 0.1          # short-job regime: dispatcher-bound, not pool-bound
SKEW_JOB_S = 1.0     # skew experiment: compute-bound so idle time shows
MOL_MB = 100.0


def build_workload(eng, n_tasks: int):
    """The MolDyn shape from benchmarks/million_tasks.py (one builder, so
    the federated-vs-single comparison cannot drift), with short jobs."""
    return build_moldyn(eng, n_tasks, job_s=JOB_S)


def _falkon(clock, executors: int, alloc_latency: float, data_layer=None):
    return FalkonService(clock, FalkonConfig(
        serialize_dispatch=True,
        drp=DRPConfig(max_executors=executors, alloc_latency=alloc_latency,
                      alloc_chunk=executors)), data_layer=data_layer)


def measure_single(n_tasks: int, executors: int,
                   alloc_latency: float) -> dict:
    t0 = time.monotonic()
    clock = SimClock()
    eng = Engine(clock, provenance="summary")
    eng.add_site("falkon", FalkonProvider(_falkon(clock, executors,
                                                  alloc_latency)),
                 capacity=executors)
    n, out = build_workload(eng, n_tasks)
    eng.run()
    wall = time.monotonic() - t0
    assert out.resolved and eng.tasks_completed == n
    span = clock.now()
    return {
        "config": "single-engine",
        "tasks": n,
        "executors": executors,
        "makespan_sim_s": round(span, 1),
        "tasks_per_sim_s": round(n / span, 1),
        "tasks_per_wall_s": round(n / wall, 1),
    }


def measure_federated(n_tasks: int, shards: int, executors_per_shard: int,
                      alloc_latency: float) -> dict:
    t0 = time.monotonic()
    clock = SimClock()
    fed = FederatedEngine(shards, clock=clock,
                          engine_kwargs={"provenance": "summary"})
    for i, eng in enumerate(fed.shards):
        eng.add_site(f"falkon{i}",
                     FalkonProvider(_falkon(clock, executors_per_shard,
                                            alloc_latency)),
                     capacity=executors_per_shard)
    n, out = build_workload(fed, n_tasks)
    fed.run()
    wall = time.monotonic() - t0
    assert out.resolved and fed.tasks_completed == n
    span = clock.now()
    m = fed.metrics()
    return {
        "config": f"federated-{shards}x{executors_per_shard}",
        "tasks": n,
        "shards": shards,
        "executors": shards * executors_per_shard,
        "makespan_sim_s": round(span, 1),
        "tasks_per_sim_s": round(n / span, 1),
        "tasks_per_wall_s": round(n / wall, 1),
        "per_shard_completed": fed.stats()["per_shard_completed"],
        "cross_shard_edges": fed.cross_shard_edges,
        "mailbox_messages": sum(mb["messages"] for mb in m["mailboxes"]),
        "mailbox_flushes": sum(mb["flushes"] for mb in m["mailboxes"]),
        "tasks_stolen": m["stealer"]["tasks_stolen"],
    }


# ---------------------------------------------------------------------------
# skewed partition + work stealing
# ---------------------------------------------------------------------------

def measure_skew(n_tasks: int, shards: int, executors_per_shard: int,
                 steal: bool, heavy_frac: float = 0.7, rounds: int = 4,
                 alloc_latency: float = 5.0) -> dict:
    """Locality-heavy rounds under a skewed partitioner.  Round 1 warms the
    heavy shard's caches; later rounds re-skew, so steals migrate tasks
    whose inputs live in the victim shard — the restage bytes the
    `ShardedDataLayer` directory prices."""
    clock = SimClock()
    # park_patience=8: compute-heavy 1 s jobs replicate their archives
    # across the pool instead of queueing ~20 deep behind one holder (the
    # wait-vs-stage test, DESIGN.md §7) — the idle-fraction bound below
    # measures partitioner skew, not affinity serialization.  The 200 MB
    # caches keep the 256-archive working set larger than any one shard's
    # aggregate cache, so stolen tasks keep paying real restage bytes
    # instead of the working set fully replicating in round 1.
    sdl = ShardedDataLayer(shards, cache_capacity=200e6, park_patience=8.0)
    fed = FederatedEngine(shards, clock=clock,
                          partitioner=skewed_partitioner(heavy_frac),
                          data_layer=sdl, steal=steal,
                          engine_kwargs={"provenance": "summary"})
    svcs = []
    for i, eng in enumerate(fed.shards):
        svc = _falkon(clock, executors_per_shard, alloc_latency,
                      data_layer=sdl.layer(i))
        svc.cfg.serialize_dispatch = False      # compute-bound experiment
        eng.add_site(f"falkon{i}", FalkonProvider(svc),
                     capacity=executors_per_shard,
                     data_layer=sdl.layer(i))
        svcs.append(svc)
    wf = Workflow("skew", fed)
    molecules = 256
    archives = [sdl.shared.file(f"mol{m}.arc", MOL_MB * 1e6)
                for m in range(molecules)]
    analyze = wf.sim_proc("analyze", duration=SKEW_JOB_S,
                          inputs=lambda m, *_: (archives[m],))
    per_round = max(molecules, n_tasks // rounds)
    n = 0
    barrier = None
    for _ in range(rounds):
        futs = []
        for j in range(per_round):
            m = j % molecules
            futs.append(analyze(m) if barrier is None
                        else analyze(m, barrier))
        n += len(futs)
        barrier = wf.gather(futs)
    fed.run()
    assert barrier.resolved and fed.tasks_completed == n
    span = clock.now()
    # per-shard busy fraction over the executable window (post-allocation):
    # the idle-fraction bound work stealing must hold
    busy = [sum(e.busy_time for e in svc.executors) for svc in svcs]
    window = max(span - alloc_latency, 1e-9)
    busy_frac = [round(b / (executors_per_shard * window), 3) for b in busy]
    met = fed.metrics()
    row = {
        "config": f"skew{heavy_frac:.0%}-{'steal' if steal else 'nosteal'}",
        "tasks": n,
        "rounds": rounds,
        "shards": shards,
        "makespan_sim_s": round(span, 1),
        "tasks_per_sim_s": round(n / span, 1),
        "per_shard_completed": fed.stats()["per_shard_completed"],
        "busy_frac": busy_frac,
        "min_busy_frac": min(busy_frac),
        "max_idle_frac": round(1.0 - min(busy_frac), 3),
    }
    if steal:
        st = met["stealer"]
        row.update({
            "steals": st["steals"],
            "tasks_stolen": st["tasks_stolen"],
            "restage_gb_est": round(st["restage_bytes_est"] / 1e9, 3),
            # bounded StreamStat summaries — constant-size at any task count
            "steal_batch": st["batch"],
            "restage_per_batch": st["restage_per_batch"],
        })
    return row


# ---------------------------------------------------------------------------

def run() -> list[dict]:
    """benchmarks/run.py entry — CI smoke tier.

    Gates the ISSUE-3 acceptance at smoke scale: >= 1.5x aggregate
    simulated tasks/s at 4 shards, bounded per-shard idle fraction under a
    skewed partition with stealing, and bounded steal metrics."""
    shards, per_shard, n = 4, 128, 20_000
    fed = measure_federated(n, shards, per_shard, alloc_latency=5.0)
    single = measure_single(n, shards * per_shard, alloc_latency=5.0)
    speedup = fed["tasks_per_sim_s"] / single["tasks_per_sim_s"]

    skew_steal = measure_skew(8_000, 4, 32, steal=True)
    skew_nosteal = measure_skew(8_000, 4, 32, steal=False)

    save_json("federation_smoke", {
        "federated": fed, "single": single,
        "speedup_vs_single": round(speedup, 2),
        "skew_steal": skew_steal, "skew_nosteal": skew_nosteal,
    })

    assert speedup >= 1.5, \
        f"federation speedup {speedup:.2f}x < 1.5x over one engine"
    assert fed["tasks"] == single["tasks"]
    # work stealing must bound the idle fraction the skew creates
    assert skew_steal["tasks_stolen"] > 0
    assert skew_steal["min_busy_frac"] >= 0.7, \
        f"stealing left a shard idle: busy {skew_steal['busy_frac']}"
    assert skew_nosteal["min_busy_frac"] < 0.5, \
        "skew experiment not skewed enough to exercise stealing"
    assert skew_steal["makespan_sim_s"] < skew_nosteal["makespan_sim_s"]
    # steal metrics are bounded reservoirs, not per-task logs
    # a fixed-size summary dict, not a per-task log: the reservoir
    # keeps at most `cap` samples however many batches were stolen
    assert "p95" in skew_steal["steal_batch"]
    assert skew_steal["steal_batch"]["samples_kept"] <= 512
    assert skew_steal["restage_gb_est"] > 0.0

    return [{
        "name": "federation.4shards.20k",
        "us_per_call": 1e6 / fed["tasks_per_wall_s"],
        "derived": (f"{speedup:.1f}x sim tasks/s vs single engine "
                    f"({fed['tasks_per_sim_s']:.0f} vs "
                    f"{single['tasks_per_sim_s']:.0f}); "
                    f"{fed['cross_shard_edges']} cross-shard edges"),
    }, {
        "name": "federation.skew.steal",
        "us_per_call": 1e6 * skew_steal["makespan_sim_s"] /
        skew_steal["tasks"],
        "derived": (f"min busy frac {skew_steal['min_busy_frac']:.2f} "
                    f"(vs {skew_nosteal['min_busy_frac']:.2f} unstolen); "
                    f"{skew_steal['tasks_stolen']} tasks stolen; "
                    f"restaged {skew_steal['restage_gb_est']:.2f} GB"),
    }]


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--tasks", type=int, default=1_000_000)
    p.add_argument("--shards", type=int, default=4)
    p.add_argument("--executors-per-shard", type=int, default=512)
    p.add_argument("--alloc-latency", type=float, default=81.0)
    p.add_argument("--skew-tasks", type=int, default=20_000)
    p.add_argument("--json", action="store_true")
    args = p.parse_args()

    fed = measure_federated(args.tasks, args.shards,
                            args.executors_per_shard, args.alloc_latency)
    single = measure_single(args.tasks,
                            args.shards * args.executors_per_shard,
                            args.alloc_latency)
    speedup = fed["tasks_per_sim_s"] / single["tasks_per_sim_s"]
    skew_steal = measure_skew(args.skew_tasks, args.shards, 32, steal=True)
    skew_nosteal = measure_skew(args.skew_tasks, args.shards, 32,
                                steal=False)
    report = {
        "federated": fed, "single": single,
        "speedup_vs_single": round(speedup, 2),
        "skew_steal": skew_steal, "skew_nosteal": skew_nosteal,
    }
    save_json("federation", report)
    if args.json:
        print(json.dumps(report))
        return 0
    for r in (fed, single):
        print(f"{r['config']:>22}: {r['tasks']:,} tasks, makespan "
              f"{r['makespan_sim_s']:,.0f} sim-s -> "
              f"{r['tasks_per_sim_s']:,.0f} sim tasks/s "
              f"({r['tasks_per_wall_s']:,.0f} wall tasks/s)")
    print(f"federation speedup: {speedup:.2f}x aggregate sim tasks/s "
          f"at {args.shards} shards")
    for r in (skew_steal, skew_nosteal):
        print(f"{r['config']:>22}: makespan {r['makespan_sim_s']:,.0f} "
              f"sim-s, busy {r['busy_frac']}, "
              f"stolen {r.get('tasks_stolen', 0)}")
    print(f"steal restage: {skew_steal['restage_gb_est']:.2f} GB est "
          f"over {skew_steal['steals']} batches")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
