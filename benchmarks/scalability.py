"""Paper Fig 9: engine scalability — tasks representable per unit memory.

Paper: Karajan ~800 B/lightweight-thread (40k threads in 32 MB); Swift
~3.2 KB/node (4k nodes in 32 MB, 160k in 1 GB).  We measure the real
per-task + per-future footprint of our engine with tracemalloc.
"""
from __future__ import annotations

import tracemalloc

from repro.core import Engine, SimClock


def bytes_per_task(n: int = 50_000) -> float:
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=1)
    gate = eng.submit("gate", None, duration=1e12)  # never resolves in test
    tracemalloc.start()
    before, _ = tracemalloc.get_traced_memory()
    outs = [eng.submit(f"t{i}", None, args=[gate], duration=1.0)
            for i in range(n)]
    after, _ = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert len(outs) == n
    return (after - before) / n


def run() -> list[dict]:
    bpt = bytes_per_task()
    per_32mb = int(32 * 2 ** 20 / bpt)
    per_1gb = int(2 ** 30 / bpt)
    rows = [{
        "name": "scalability.fig9",
        "us_per_call": 0.0,
        "derived": (f"{bpt:.0f} B/task -> {per_32mb} tasks/32MB, "
                    f"{per_1gb} tasks/1GB (paper: Swift 3.2KB/node -> "
                    f"4k/32MB, 160k/1GB; Karajan 800B/thread)"),
    }]
    from benchmarks.common import save_json
    save_json("scalability_fig9", {"bytes_per_task": bpt,
                                   "tasks_per_32MB": per_32mb,
                                   "tasks_per_1GB": per_1gb})
    return rows
