"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.

[hf:stabilityai/stablelm-2-1_6b; hf]
"""
from repro.configs.base import LayerSpec, ModelConfig

DENSE = LayerSpec(mixer="attn", ffn="dense")

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    blocks=(((DENSE,), 40),),
    tie_embeddings=False,
)
