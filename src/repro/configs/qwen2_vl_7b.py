"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (3-section rotary: temporal/height/width), dynamic-resolution vision
frontend is a STUB — `input_specs()` supplies the token stream (precomputed
patch embeddings are merged upstream).  [arXiv:2409.12191; hf]
"""
from repro.configs.base import LayerSpec, ModelConfig

DENSE = LayerSpec(mixer="attn", ffn="dense")

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    blocks=(((DENSE,), 28),),
    qkv_bias=True,
    tie_embeddings=False,
    mrope_sections=(16, 24, 24),   # half-dims per section; sums to head_dim/2
    rope_theta=1_000_000.0,
)
