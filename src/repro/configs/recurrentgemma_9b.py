"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window=2048.
[arXiv:2402.19427; unverified]
38 = 12 x (rglru, rglru, local-attn) + 2 rglru remainder.
"""
from repro.configs.base import LayerSpec, ModelConfig, RGLRUConfig

RGLRU = LayerSpec(mixer="rglru")
LOCAL = LayerSpec(mixer="attn", window=2048)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab=256000,
    blocks=(((RGLRU, RGLRU, LOCAL), 12), ((RGLRU,), 2)),
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
    rglru=RGLRUConfig(lru_width=4096, d_conv=4, c=8.0, chunk=256),
)
