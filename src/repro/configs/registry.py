"""Architecture registry + reduced (smoke) config factory."""
from __future__ import annotations

import dataclasses

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig, RGLRUConfig, SSMConfig

ARCH_NAMES = [
    "recurrentgemma-9b",
    "deepseek-v2-236b",
    "granite-moe-3b-a800m",
    "qwen1.5-0.5b",
    "stablelm-12b",
    "qwen2-1.5b",
    "gemma3-27b",
    "qwen2-vl-7b",
    "whisper-large-v3",
    "falcon-mamba-7b",
]

_MODULES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "stablelm-12b": "stablelm_12b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma3-27b": "gemma3_27b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-large-v3": "whisper_large_v3",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced(cfg: ModelConfig, seq_friendly: bool = True) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving family structure.

    Keeps the layer-pattern shape (every block group survives with 1 repeat)
    so the scan/remainder machinery is exercised, but layers become tiny.
    """
    blocks = tuple((pattern, 1) for pattern, _ in cfg.blocks)
    n_heads = min(cfg.n_heads, 4)
    n_kv = min(cfg.n_kv_heads, n_heads)
    d_head = 16
    d_model = 64
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 8),
            top_k=min(cfg.moe.top_k, 2), expert_ff=32, group_size=64,
            n_shared=min(cfg.moe.n_shared, 1),
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=8, dt_rank=8, chunk=16)
    rglru = None
    if cfg.rglru is not None:
        rglru = dataclasses.replace(cfg.rglru, lru_width=64, chunk=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_head,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        blocks=blocks,
        moe=moe,
        ssm=ssm,
        rglru=rglru,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_frames=8 if cfg.enc_dec else cfg.enc_frames,
        mla_q_lora=32 if cfg.mla_q_lora else 0,
        mla_kv_lora=32 if cfg.mla_kv_lora else 0,
        mla_rope_dim=8 if cfg.mla_kv_lora else 64,
        mla_nope_dim=16 if cfg.mla_kv_lora else 128,
        mla_v_dim=16 if cfg.mla_kv_lora else 128,
        mrope_sections=(2, 3, 3) if cfg.mrope_sections else (),
        loss_chunk=64,
        attn_q_block=32,
        attn_kv_block=32,
        # XLA:CPU cannot *execute* some bf16 dot layouts (DotThunk); smoke
        # tests run f32 on CPU.  Full configs keep bf16 compute (TPU target).
        compute_dtype="float32",
    )


def smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))
