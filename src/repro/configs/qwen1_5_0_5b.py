"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H d_ff=2816 vocab=151936, QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import LayerSpec, ModelConfig

DENSE = LayerSpec(mixer="attn", ffn="dense")

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    blocks=(((DENSE,), 24),),
    qkv_bias=True,
    tie_embeddings=True,
)
