"""granite-moe-3b-a800m [moe] — 40 experts, top-8.

32L d_model=1536 24H (GQA kv=8) expert d_ff=512 vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

MOE = LayerSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    blocks=(((MOE,), 32),),
    tie_embeddings=True,
    moe=MoEConfig(
        n_experts=40,
        top_k=8,
        n_shared=0,
        expert_ff=512,
        capacity_factor=1.25,
        group_size=2048,
    ),
)
