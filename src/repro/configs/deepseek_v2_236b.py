"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + fine-grained MoE.

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400, 2 shared + 160 routed
top-6.  Layer 0 is dense (d_ff=12288) per the published model.
[arXiv:2405.04434; hf]
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

MLA_DENSE = LayerSpec(mixer="mla", ffn="dense")
MLA_MOE = LayerSpec(mixer="mla", ffn="moe")

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=12288,                    # dense layer-0 hidden
    vocab=102400,
    blocks=(((MLA_DENSE,), 1), ((MLA_MOE,), 59)),
    tie_embeddings=False,
    mla_q_lora=1536,
    mla_kv_lora=512,
    mla_rope_dim=64,
    mla_nope_dim=128,
    mla_v_dim=128,
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        n_shared=2,
        expert_ff=1536,
        capacity_factor=1.25,
        group_size=2048,
    ),
)
