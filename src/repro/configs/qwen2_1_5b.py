"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

GQA + QKV bias.  [arXiv:2407.10671; hf]
"""
from repro.configs.base import LayerSpec, ModelConfig

DENSE = LayerSpec(mixer="attn", ffn="dense")

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    blocks=(((DENSE,), 28),),
    qkv_bias=True,
    tie_embeddings=True,
)
