"""Model / run configuration system.

`ModelConfig` is a frozen dataclass covering every assigned architecture
family (dense / GQA / MLA / MoE / SSM / RG-LRU hybrid / enc-dec).  The layer
stack is described by `blocks`: a list of (pattern, repeats) where pattern is
a tuple of `LayerSpec`s.  Each (pattern, repeats) group is compiled once and
`lax.scan`ned `repeats` times with stacked parameters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer's static structure."""
    mixer: str = "attn"        # attn | mla | mamba | rglru
    window: int = 0            # 0 = global attention; >0 = local window
    ffn: str = "dense"         # dense | moe | none
    cross_attn: bool = False   # decoder cross-attention (enc-dec)
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0
    expert_ff: int = 0          # per-expert hidden dim
    capacity_factor: float = 1.25
    group_size: int = 2048      # tokens per dispatch group
    aux_loss_weight: float = 0.01
    dispatch: str = "scatter"   # scatter | index (§Perf lever: scatter moves
    #   the (B,E,c,d) buffer through a data scatter-add; index scatters only
    #   int32 slot maps and GATHERS the data — the expert buffer never
    #   becomes a partial-sum that GSPMD must all-reduce)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> ceil(d_model/16)
    chunk: int = 128            # time-chunk for the scan


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0          # 0 -> d_model
    d_conv: int = 4
    c: float = 8.0
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"       # dense | moe | hybrid | ssm | vlm | audio
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_head: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 2048
    vocab: int = 32000
    blocks: tuple[tuple[tuple[LayerSpec, ...], int], ...] = ()
    # norms / misc
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "silu"           # silu | gelu (ffn uses gated GLU unless gated=False)
    gated_ffn: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = True
    embed_scale: bool = False   # gemma-style sqrt(d) embedding scaling
    rope_theta: float = 10000.0
    pos_embed: str = "rope"     # rope | sinusoidal (whisper)
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (t,h,w) head_dim split
    # MLA (deepseek)
    mla_q_lora: int = 0
    mla_kv_lora: int = 0
    mla_rope_dim: int = 64
    mla_nope_dim: int = 128
    mla_v_dim: int = 128
    # sub-configs
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "nothing_saveable"   # nothing_saveable | dots_with_no_batch_dims | none
    loss_chunk: int = 512       # sequence chunk for cross-entropy
    attn_q_block: int = 512     # blocked-attention q tile
    attn_kv_block: int = 1024   # blocked-attention kv tile
    use_pallas: bool = False    # TPU hot path (interpret-validated on CPU)
    logits_dtype: str = "float32"
    # ---- beyond-paper perf levers (§Perf hillclimb; default = baseline) ----
    bf16_param_stack: bool = False   # cast stacked layer params to compute
    #   dtype ONCE before the layer scan: parameter loads and the per-layer
    #   gradient reductions run in bf16 instead of f32
    cotangent_dtype: str = ""        # "bfloat16": cast the loss cotangent at
    #   the unembed boundary so activation grads (and their sequence-parallel
    #   collectives) stay bf16 instead of inheriting f32 from the CE dot

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, 256)

    @property
    def n_layers(self) -> int:
        return sum(len(p) * r for p, r in self.blocks)

    def layer_specs(self) -> list[LayerSpec]:
        out: list[LayerSpec] = []
        for pattern, r in self.blocks:
            out.extend(list(pattern) * r)
        return out

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND roofline math)."""
        from repro.models.transformer import build_descriptors
        from repro.models.params import count_params
        return count_params(build_descriptors(self))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        d = self.d_model
        per_expert = 3 * d * m.expert_ff if self.gated_ffn else 2 * d * m.expert_ff
        n_moe_layers = sum(1 for s in self.layer_specs() if s.ffn == "moe")
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total - inactive


# ---------------------------------------------------------------------------
# Shape cells assigned to this paper (LM-family: seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic-capable; see DESIGN.md §4)
LONG_CONTEXT_ARCHS = {"recurrentgemma-9b", "falcon-mamba-7b", "gemma3-27b"}


def runnable_cells() -> list[tuple[str, str]]:
    from repro.configs import registry
    cells = []
    for arch in registry.ARCH_NAMES:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            cells.append((arch, shape))
    return cells
