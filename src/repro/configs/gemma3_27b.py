"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.

5 local : 1 global attention pattern, window 1024, qk-norm, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
62 = 10 x (5 local + 1 global) + 2 local remainder.
"""
from repro.configs.base import LayerSpec, ModelConfig

LOCAL = LayerSpec(mixer="attn", window=1024)
GLOBAL = LayerSpec(mixer="attn", window=0)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab=262144,
    blocks=(((LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL), 10), ((LOCAL, LOCAL), 1)),
    act="gelu",
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=1_000_000.0,
)
