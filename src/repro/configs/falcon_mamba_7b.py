"""falcon-mamba-7b [ssm] — 64L d_model=4096, attention-free mamba1, state=16.

d_inner = 2*d_model = 8192, conv width 4, dt_rank = 256, vocab 65024.
[arXiv:2410.05355; unverified]
"""
from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

MAMBA = LayerSpec(mixer="mamba", ffn="none")

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    blocks=(((MAMBA,), 64),),
    tie_embeddings=False,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256, chunk=128),
)
