"""whisper-large-v3 [audio] — enc-dec, 32+32L d_model=1280 20H d_ff=5120 vocab=51866.

Conv frontend is a STUB: `input_specs()` provides precomputed frame embeddings
(batch, 1500, d_model).  Sinusoidal positions, LayerNorm, ungated GELU FFN.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import LayerSpec, ModelConfig

DEC = LayerSpec(mixer="attn", ffn="dense", cross_attn=True)

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    blocks=(((DEC,), 32),),
    norm="layernorm",
    act="gelu",
    gated_ffn=False,
    qkv_bias=True,
    pos_embed="sinusoidal",
    tie_embeddings=True,
    enc_dec=True,
    n_enc_layers=32,
    enc_frames=1500,
)
