"""Sharded checkpointing with a data-availability manifest.

The checkpoint IS a restart log in the paper's sense (§3.12): each saved
artifact (param shard file) is a produced dataset; the manifest commits
atomically (write + rename) only after every shard is durable, so a crash
mid-checkpoint leaves the previous manifest valid.  `ShardMapper` (XDTM) maps
the logical arrays to their physical shard files.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

from repro.core.xdtm import PhysicalRef, ShardMapper


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory: str, n_shards: int = 1, keep: int = 3):
        self.directory = directory
        self.n_shards = n_shards
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def manifest_path(self, step: int) -> str:
        return os.path.join(self._step_dir(step), "MANIFEST.json")

    def save(self, step: int, state: dict) -> list[PhysicalRef]:
        """state: pytree dict (params / opt_state / meta)."""
        sdir = self._step_dir(step)
        os.makedirs(sdir, exist_ok=True)
        flat = _flatten(state)
        entries = {}
        refs = []
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            name = key.replace("/", ".")
            n_shards = self.n_shards if arr.ndim and arr.shape[0] >= \
                self.n_shards else 1
            mapper = ShardMapper(sdir, name, arr.shape, str(arr.dtype),
                                 n_shards)
            refs.extend(mapper.save(arr))
            entries[key] = {
                "name": name, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "n_shards": n_shards,
            }
        # atomic manifest commit
        fd, tmp = tempfile.mkstemp(dir=sdir, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump({"step": step, "entries": entries}, f)
        os.replace(tmp, self.manifest_path(step))
        self._gc()
        return refs

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, d, "MANIFEST.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: dict, step: int | None = None) -> tuple:
        """Returns (state, step).  template supplies the pytree structure."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        sdir = self._step_dir(step)
        with open(self.manifest_path(step)) as f:
            manifest = json.load(f)
        flat_t = _flatten(template)
        loaded = {}
        for key in flat_t:
            e = manifest["entries"][key]
            mapper = ShardMapper(sdir, e["name"], tuple(e["shape"]),
                                 e["dtype"], e["n_shards"])
            loaded[key] = mapper.load()
        # rebuild tree
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, _ in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            leaves.append(loaded[key])
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
