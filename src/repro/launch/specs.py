"""Abstract input specs + shardings for every (arch x shape x mesh) cell.

`input_specs()` returns weak-type-correct `jax.ShapeDtypeStruct` stand-ins
(with `NamedSharding`s attached) for every input of the lowered step —
no device allocation ever happens.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, SHAPES, ShapeCell
from repro.configs import registry
from repro.launch.mesh import data_axes
from repro.models import transformer as T
from repro.models.params import (ParamDesc, default_rules, resolve_spec,
                                 tree_map_desc)


# ---------------------------------------------------------------------------
# per-cell axis rules
# ---------------------------------------------------------------------------

def axis_rules_for(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                   overrides: dict[str, Any] | None = None) -> dict[str, Any]:
    multi_pod = "pod" in mesh.axis_names
    rules = default_rules(multi_pod)
    if cell.kind in ("train", "prefill"):
        # Megatron-style activation sequence sharding between layers
        rules["seq_act"] = "model"
    if cell.kind in ("prefill", "decode"):
        # KV caches: shard the sequence dim over the model axis (frees the
        # kv_heads fallback problem for 20/28-head archs and MLA's headless
        # latent cache).  long_500k (batch=1) uses the data axis instead —
        # sequence-parallel decode.
        rules["kv_seq"] = "data" if cell.name == "long_500k" else "model"
    if overrides:
        rules.update(overrides)
    return rules


def _mesh_shape(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def param_specs(cfg: ModelConfig, mesh: Mesh, rules) -> Any:
    descs = T.build_descriptors(cfg)
    ms = _mesh_shape(mesh)
    pdt = jnp.dtype(cfg.param_dtype)

    def mk(d: ParamDesc):
        dtype = d.dtype if d.dtype is not None else pdt
        # parameters keep their declared dtype except float params follow cfg
        if jnp.issubdtype(dtype, jnp.floating):
            dtype = pdt
        return _sds(d.shape, dtype, mesh, resolve_spec(d, rules, ms))

    return tree_map_desc(mk, descs)


def opt_rule_extend(spec: P, shape, ms: dict[str, int], data_axis: str) -> P:
    """ZeRO-style: additionally shard optimizer-state tensors over the data
    axis on the largest still-unsharded divisible dim."""
    used = set()
    for s in spec:
        if s is None:
            continue
        used.update((s,) if isinstance(s, str) else s)
    if data_axis in used:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = -1, -1
    for i, (dim, s) in enumerate(zip(shape, parts)):
        if s is None and dim % ms.get(data_axis, 1) == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best >= 0:
        parts[best] = data_axis
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def opt_specs(cfg: ModelConfig, mesh: Mesh, rules) -> Any:
    descs = T.build_descriptors(cfg)
    ms = _mesh_shape(mesh)
    da = "data"

    def mk(d: ParamDesc):
        spec = resolve_spec(d, rules, ms)
        spec = opt_rule_extend(spec, d.shape, ms, da)
        return _sds(d.shape, jnp.float32, mesh, spec)

    one = tree_map_desc(mk, descs)
    two = tree_map_desc(mk, descs)
    return {"m": one, "v": two}


def cache_specs(cfg: ModelConfig, mesh: Mesh, rules, batch: int, seq: int):
    descs = T.build_cache_descriptors(cfg, batch, seq)
    ms = _mesh_shape(mesh)

    def mk(d: ParamDesc):
        return _sds(d.shape, d.dtype, mesh, resolve_spec(d, rules, ms))

    return [tree_map_desc(mk, g) for g in descs]


def batch_specs(cfg: ModelConfig, mesh: Mesh, cell: ShapeCell,
                with_labels: bool):
    da = data_axes(mesh)
    dspec = da if len(da) > 1 else da[0]
    B, S = cell.global_batch, cell.seq_len
    bspec = dspec if B % _axis_size_of(mesh, da) == 0 else None
    out = {"tokens": _sds((B, S), jnp.int32, mesh, P(bspec))}
    if with_labels:
        out["labels"] = _sds((B, S), jnp.int32, mesh, P(bspec))
    if cfg.enc_dec:
        out["enc_feats"] = _sds((B, cfg.enc_frames, cfg.d_model), jnp.float32,
                                mesh, P(bspec))
    return out


def _axis_size_of(mesh, axes) -> int:
    ms = _mesh_shape(mesh)
    n = 1
    for a in axes:
        n *= ms.get(a, 1)
    return n


# ---------------------------------------------------------------------------
# full per-cell spec bundles
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    cfg: ModelConfig
    cell: ShapeCell
    rules: dict[str, Any]
    args: tuple          # abstract args for the step fn
    donate: tuple[int, ...]
    kind: str


def input_specs(arch: str, shape: str, mesh: Mesh,
                rule_overrides: dict[str, Any] | None = None,
                cfg: ModelConfig | None = None) -> CellSpec:
    cfg = cfg or registry.get_config(arch)
    cell = SHAPES[shape]
    rules = axis_rules_for(cfg, cell, mesh, rule_overrides)
    params = param_specs(cfg, mesh, rules)
    step_spec = _sds((), jnp.int32, mesh, P())

    if cell.kind == "train":
        opt = opt_specs(cfg, mesh, rules)
        batch = batch_specs(cfg, mesh, cell, with_labels=True)
        args = (params, opt, batch, step_spec)
        donate = (0, 1)
    elif cell.kind == "prefill":
        batch = batch_specs(cfg, mesh, cell, with_labels=False)
        args = (params, batch)
        donate = ()
    else:  # decode
        caches = cache_specs(cfg, mesh, rules, cell.global_batch, cell.seq_len)
        da = data_axes(mesh)
        B = cell.global_batch
        bspec = (da if len(da) > 1 else da[0]) \
            if B % _axis_size_of(mesh, da) == 0 else None
        tokens = _sds((B, 1), jnp.int32, mesh, P(bspec))
        pos_t = _sds((), jnp.int32, mesh, P())
        args = (params, caches, tokens, pos_t)
        donate = (1,)
    return CellSpec(arch=arch, shape=shape, cfg=cfg, cell=cell, rules=rules,
                    args=args, donate=donate, kind=cell.kind)
