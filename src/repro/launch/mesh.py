"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16 x 16 = 256 chips over
("data", "model"); multi-pod: 2 x 16 x 16 = 512 chips over
("pod", "data", "model").

`compat_make_mesh` papers over JAX-version differences in mesh
construction: `jax.sharding.AxisType` (and the matching `axis_types=`
parameter of `jax.make_mesh`) only exist in newer JAX releases, and very old
releases lack `jax.make_mesh` entirely.  Explicit Auto axis types only
restate the historical default, so omitting them on older JAX preserves
behavior.
"""
from __future__ import annotations

import inspect
import math

import jax
import numpy as np


def mesh_axis_types_supported() -> bool:
    """True when this JAX exposes explicit mesh axis types."""
    if getattr(jax.sharding, "AxisType", None) is None:
        return False
    make_mesh = getattr(jax, "make_mesh", None)
    if make_mesh is None:
        return False
    return "axis_types" in inspect.signature(make_mesh).parameters


def compat_make_mesh(shape: tuple, axis_names: tuple, *, devices=None):
    """`jax.make_mesh` with Auto axis types where supported, graceful
    fallback elsewhere."""
    if devices is None:
        devices = jax.devices()[:math.prod(shape)]
    make_mesh = getattr(jax, "make_mesh", None)
    if make_mesh is None:
        # ancient JAX: build the Mesh directly
        return jax.sharding.Mesh(
            np.asarray(devices).reshape(shape), axis_names)
    kwargs = {}
    if mesh_axis_types_supported():
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(shape)
    return make_mesh(shape, axis_names, devices=devices, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests/examples)."""
    n = len(jax.devices())
    return compat_make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
