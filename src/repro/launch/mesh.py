"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16 x 16 = 256 chips over
("data", "model"); multi-pod: 2 x 16 x 16 = 512 chips over
("pod", "data", "model").
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=_auto(2))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
