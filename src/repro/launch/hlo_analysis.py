"""Post-compile HLO analysis: collective byte counting + 3-term roofline.

`cost_analysis()` supplies HLO FLOPs and bytes; collective bytes are NOT in
cost_analysis, so we parse the optimized HLO text and sum per-device wire
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute with standard ring formulas.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

HW = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<lhs>[^=]*?)\s"
    r"(?P<op>all-reduce-start|all-gather-start|collective-permute-start|"
    r"reduce-scatter|all-to-all|all-reduce|all-gather|collective-permute)"
    r"(?:\.\d+)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    per_device_wire_bytes: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, wire: float):
        self.per_device_wire_bytes += wire
        k = self.by_kind.setdefault(kind, {"bytes": 0.0, "count": 0})
        k["bytes"] += wire
        k["count"] += 1
        self.count += 1


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device wire bytes (ring formulas) for every collective op."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op").replace("-start", "")
        result_bytes = _shape_bytes(m.group("lhs"))
        n = _group_size(line)
        if op == "all-gather":
            wire = result_bytes * (n - 1) / n
        elif op == "reduce-scatter":
            wire = result_bytes * (n - 1)
        elif op == "all-reduce":
            wire = result_bytes * 2 * (n - 1) / n
        elif op == "all-to-all":
            wire = result_bytes * (n - 1) / n
        else:  # collective-permute
            wire = result_bytes
        stats.add(op, wire)
    return stats


def roofline(cost: dict, coll: CollectiveStats, n_chips: int,
             model_flops: float | None = None) -> dict:
    """Three roofline terms in seconds (per step, per chip)."""
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    # cost_analysis reports per-program (per-device SPMD program) numbers
    compute_t = hlo_flops / HW["peak_flops"]
    memory_t = hlo_bytes / HW["hbm_bw"]
    coll_t = coll.per_device_wire_bytes / HW["ici_bw"]
    dominant = max(
        [("compute", compute_t), ("memory", memory_t), ("collective", coll_t)],
        key=lambda kv: kv[1])[0]
    out = {
        "hlo_flops_per_device": hlo_flops,
        "hlo_bytes_per_device": hlo_bytes,
        "collective_bytes_per_device": coll.per_device_wire_bytes,
        "collective_ops": coll.by_kind,
        "compute_term_s": compute_t,
        "memory_term_s": memory_t,
        "collective_term_s": coll_t,
        "dominant": dominant,
        "bound_time_s": max(compute_t, memory_t, coll_t),
    }
    if model_flops is not None:
        out["model_flops_total"] = model_flops
        out["model_flops_per_device"] = model_flops / n_chips
        if hlo_flops > 0:
            out["useful_flops_ratio"] = (model_flops / n_chips) / hlo_flops
        out["mfu_bound"] = (model_flops / n_chips / HW["peak_flops"]) / \
            max(compute_t, memory_t, coll_t, 1e-30)
    return out
