import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the dry-run needs 512 placeholder host devices so
`jax.make_mesh` can build the production meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, runnable_cells
from repro.configs import registry
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.distributed.sharding import AxisRules, use_axis_rules
from repro.optim import adamw
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch
    tokens per step; fwd-only shapes use 2·N·D."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch  # decode: one token per sequence


def _shardings_of(tree):
    return jax.tree_util.tree_map(lambda s: s.sharding, tree)


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             rule_overrides=None, hyper=None, cfg=None,
             constrain_grads: bool = False) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    spec = input_specs(arch, shape, mesh, rule_overrides, cfg=cfg)
    cfg = spec.cfg
    rules = AxisRules(spec.rules, mesh)

    if spec.kind == "train":
        hp = hyper or adamw.Hyper()
        gsh = _shardings_of(spec.args[1]["m"]) if constrain_grads else None
        fn = make_train_step(cfg, hp, grad_shardings=gsh)
        params_sh, opt_sh = (_shardings_of(spec.args[0]),
                             _shardings_of(spec.args[1]))
        with use_axis_rules(rules):
            out_struct = jax.eval_shape(fn, *spec.args)
        metrics_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), out_struct[2])
        out_shardings = (params_sh, opt_sh, metrics_sh)
    elif spec.kind == "prefill":
        fn = make_prefill_step(cfg)
        # pin cache out-shardings to the cell's cache specs
        from repro.launch.specs import cache_specs
        cs = cache_specs(cfg, mesh, spec.rules, spec.cell.global_batch,
                         spec.cell.seq_len)
        out_shardings = (NamedSharding(mesh, P()), _shardings_of(cs))
    else:  # decode
        fn = make_serve_step(cfg)
        tok_sh = spec.args[2].sharding
        caches_sh = _shardings_of(spec.args[1])
        out_shardings = (tok_sh, caches_sh)

    in_shardings = _shardings_of(spec.args)

    with mesh:
        with use_axis_rules(rules):
            jitted = jax.jit(fn, in_shardings=in_shardings,
                             out_shardings=out_shardings,
                             donate_argnums=spec.donate)
            lowered = jitted.lower(*spec.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-corrected cost model (XLA's cost_analysis counts while
    # bodies once; see hlo_cost.py)
    cost = hlo_cost.analyze(hlo)
    coll = hlo_analysis.CollectiveStats(
        per_device_wire_bytes=cost.coll_wire, by_kind=cost.coll_by_kind,
        count=int(sum(v["count"] for v in cost.coll_by_kind.values())))
    roof = hlo_analysis.roofline(
        {"flops": cost.flops, "bytes accessed": cost.bytes}, coll, n_chips,
        model_flops(cfg, spec.cell))
    roof["xla_cost_analysis_raw"] = {
        "flops": float(xla_cost.get("flops", 0.0)),
        "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
        "note": "while bodies counted once by XLA; corrected numbers above",
    }

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": spec.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "roofline": roof,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = runnable_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)")
                n_ok += 1
                continue
            try:
                res = run_cell(arch, shape, multi_pod=mp)
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
                r = res["roofline"]
                print(f"[ ok ] {tag}: compile={res['compile_s']}s "
                      f"dominant={r['dominant']} "
                      f"compute={r['compute_term_s']:.4f}s "
                      f"memory={r['memory_term_s']:.4f}s "
                      f"coll={r['collective_term_s']:.4f}s "
                      f"useful={r.get('useful_flops_ratio', 0):.3f}",
                      flush=True)
                n_ok += 1
            except Exception as e:
                n_fail += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                with open(os.path.join(args.out, tag + ".err"), "w") as f:
                    f.write(traceback.format_exc())
    print(f"done: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
