"""Perf-iteration profiler: per-op cost attribution from the compiled HLO.

Given a dry-run cell, prints the top collective ops and top HBM-byte ops
with while-loop multiplicities — the "profile" used by the §Perf
hypothesis -> change -> measure loop (no real-TPU timings exist here; the
lowered IR is the profile, per the assignment).
"""
from __future__ import annotations

from repro.launch import hlo_cost as H


def attribute(hlo_text: str):
    mod = H.HloModule(hlo_text)
    coll_records = []
    byte_records = []

    def walk(comp, mult):
        for op in mod.comps.get(comp, []):
            kind = op.kind
            if kind == "while":
                body = H._BODY_RE.search(op.attrs)
                cond = H._COND_RE.search(op.attrs)
                trips = mod._trip_count(op, cond.group(1) if cond else None)
                if body:
                    walk(body.group(1), mult * trips)
                continue
            if kind in ("call", "conditional"):
                m = H._CALLS_RE.search(op.attrs)
                if m:
                    walk(m.group(1), mult)
            if any(kind.startswith(c) for c in H.COLLECTIVES) \
                    and not kind.endswith("-done"):
                base = kind.replace("-start", "")
                rb = H._shape_bytes(op.result)
                n = mod._group_size(op.attrs + op.args)
                if base == "all-gather":
                    wire = rb * (n - 1) / n
                elif base == "reduce-scatter":
                    wire = rb * (n - 1)
                elif base == "all-reduce":
                    wire = rb * 2 * (n - 1) / n
                elif base == "all-to-all":
                    wire = rb * (n - 1) / n
                else:
                    wire = rb
                coll_records.append(
                    (wire * mult, mult, wire, base, comp, op.name,
                     op.result[:60], op.raw.split("metadata")[-1][:160]))
            if kind == "fusion":
                # descend for collectives inside fusions
                m = H._CALLS_RE.search(op.attrs)
                if m:
                    sub = mod.comp_cost(m.group(1), fused=True)
                    if sub.coll_wire:
                        coll_records.append(
                            (sub.coll_wire * mult, mult, sub.coll_wire,
                             "fused", comp, op.name, op.result[:60], ""))
            b = mod.op_bytes(comp, op)
            if b:
                byte_records.append((b * mult, mult, b, kind, comp, op.name))

    walk(mod.entry, 1.0)
    coll_records.sort(reverse=True)
    byte_records.sort(reverse=True)
    return coll_records, byte_records


def report(hlo_text: str, top: int = 12) -> str:
    coll, byts = attribute(hlo_text)
    lines = ["== top collectives (wire bytes x multiplicity) =="]
    for r in coll[:top]:
        lines.append(f"  {r[0]:.3e}  x{int(r[1]):<5d} per={r[2]:.2e} "
                     f"{r[3]:<14s} {r[5][:40]:42s} {r[6]}")
        if r[7]:
            lines.append(f"      {r[7]}")
    lines.append("== top HBM ops ==")
    for r in byts[:top]:
        lines.append(f"  {r[0]:.3e}  x{int(r[1]):<5d} per={r[2]:.2e} "
                     f"{r[3]:<18s} {r[5][:50]}")
    return "\n".join(lines)
