"""HLO-text cost analysis with while-loop trip-count multiplication.

XLA's `compiled.cost_analysis()` counts each `while` body exactly once, which
undercounts scanned-layer models by ~n_layers (verified empirically).  This
module walks the optimized HLO text instead:

  * flops: dot ops = 2 * result_elems * contracting_elems (descending into
    fusions); elementwise/reduce ops counted at 1 flop/element.
  * bytes: per *top-level* op (fusion boundaries): operands + result —
    approximates HBM traffic after fusion.
  * collectives: per-device wire bytes with ring formulas.
  * while loops: body cost x trip count (trip count parsed from the loop
    condition's comparison constant); nested whiles multiply.

Validated against hand-computable programs in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_HEAD_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^=]*?\)|[^=(]+?))\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_COMP_RE = re.compile(r"true_computation=%?([\w\.\-]+)")
_FALSE_COMP_RE = re.compile(r"false_computation=%?([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r"known_trip_count[^}]*?\"n\":\"(\d+)\"")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "copy-start", "copy-done",
}

_FLOP_FREE_OPS = _SKIP_BYTES_OPS | {
    "reshape", "transpose", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "gather", "scatter",
    "while", "conditional", "call", "custom-call", "fusion", "copy",
    "send", "recv", "rng", "rng-bit-generator", "convert", "reverse",
    "reduce", "sort", "map", "reduce-window", "select-and-scatter",
    "get-dimension-size", "optimization-barrier", "domain", "tan",
}


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


def _shape_bytes(text: str) -> float:
    return float(sum(_elems(dims) * _DTYPE_BYTES[dt]
                     for dt, dims in _SHAPE_RE.findall(text)))


def _shape_elems(text: str) -> float:
    return float(sum(_elems(dims) for _, dims in _SHAPE_RE.findall(text)))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_wire += other.coll_wire * mult
        for k, v in other.coll_by_kind.items():
            e = self.coll_by_kind.setdefault(k, {"bytes": 0.0, "count": 0.0})
            e["bytes"] += v["bytes"] * mult
            e["count"] += v["count"] * mult

    def add_coll(self, kind: str, wire: float):
        self.coll_wire += wire
        e = self.coll_by_kind.setdefault(kind, {"bytes": 0.0, "count": 0.0})
        e["bytes"] += wire
        e["count"] += 1


@dataclasses.dataclass
class _Op:
    name: str
    result: str
    kind: str
    args: str
    attrs: str
    raw: str = ""


_OP_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OP_KIND_RE = re.compile(r"\s*([\w\-]+)\(")


def _scan_balanced(line: str, start: int) -> int:
    """start points at '('; returns index just past the matching ')'."""
    depth = 0
    i = start
    while i < len(line):
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return i + 1
        i += 1
    return i


def _split_op(line: str):
    m = _OP_NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i < len(line) and line[i] == "(":
        # tuple result type (may contain /*index=N*/ comments with '=')
        j = _scan_balanced(line, i)
        result = line[i:j]
        i = j
    else:
        m2 = re.match(r"\S+", line[i:])
        if not m2:
            return None
        result = m2.group(0)
        i += m2.end()
    m3 = _OP_KIND_RE.match(line[i:])
    if not m3:
        return None
    kind = m3.group(1)
    args_start = i + m3.end()  # char right after '('
    args_end = _scan_balanced(line, args_start - 1)
    args = line[args_start:args_end - 1]
    attrs = line[args_end:]
    return _Op(name=name, result=result, kind=kind, args=args, attrs=attrs,
               raw=line)


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list[_Op]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._cost_cache: dict[str, Cost] = {}
        # symbol tables: comp -> {op_name: result_shape_str}
        self.symbols: dict[str, dict[str, str]] = {
            c: {op.name: op.result for op in ops}
            for c, ops in self.comps.items()
        }

    _OP_START = re.compile(r"^\s*(ROOT\s+)?%[\w\.\-]+\s*=\s*")
    _HDR_START = re.compile(r"^\s*(ENTRY\s+)?%[\w\.\-]+\s*\(")

    def _logical_lines(self, text: str):
        """Re-join wrapped HLO statements (the pretty-printer wraps long
        tuple types / operand lists across physical lines)."""
        pending: str | None = None
        for raw in text.splitlines():
            s = raw.rstrip()
            if not s:
                continue
            starts = (self._OP_START.match(s) or s.strip() == "}"
                      or (self._HDR_START.match(s) and "=" not in
                          s.split("(")[0]))
            if starts:
                if pending is not None:
                    yield pending
                pending = s
            elif pending is not None:
                pending += " " + s.strip()
            else:
                pending = s
        if pending is not None:
            yield pending

    def _parse(self, text: str):
        cur = None
        for line in self._logical_lines(text):
            s = line.rstrip()
            if s.endswith("{") and ("->" in s):
                m = _COMP_HDR_RE.match(s)
                if m:
                    cur = m.group(2)
                    self.comps[cur] = []
                    if m.group(1):
                        self.entry = cur
                    continue
            if s.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            op = _split_op(line)
            if op:
                self.comps[cur].append(op)

    # ------------------------------------------------------------------
    _SLICE_KINDS = {"dynamic-slice", "slice", "gather"}

    def _fusion_bytes(self, comp: str, op: _Op) -> float:
        """Bytes for a fusion op: operands + result, but an operand consumed
        *only* by slice-like ops inside the fused computation is charged at
        the slice-result size (scan bodies slice per-layer parameters out of
        the full stacked tensor — charging the full stack per iteration would
        overcount HBM traffic by the trip count)."""
        m = _CALLS_RE.search(op.attrs)
        sym = self.symbols.get(comp, {})
        operand_names = _OPERAND_RE.findall(op.args)
        result_bytes = _shape_bytes(op.result)
        if not m:
            return sum(_shape_bytes(sym.get(n, "")) for n in operand_names) \
                + result_bytes
        fused = m.group(1)
        fops = self.comps.get(fused, [])
        fsym = self.symbols.get(fused, {})
        params: dict[int, str] = {}
        uses: dict[str, list[_Op]] = {}
        root: _Op | None = None
        for fop in fops:
            if fop.kind == "parameter":
                try:
                    params[int(fop.args.strip())] = fop.name
                except ValueError:
                    pass
            for nm in _OPERAND_RE.findall(fop.args):
                uses.setdefault(nm, []).append(fop)
            if fop.raw.lstrip().startswith("ROOT"):
                root = fop
        total = 0.0
        for idx, name in enumerate(operand_names):
            full = _shape_bytes(sym.get(name, ""))
            pname = params.get(idx)
            consumers = uses.get(pname, []) if pname else []
            slice_like = self._SLICE_KINDS | {"dynamic-update-slice"}
            if consumers and all(c.kind in slice_like for c in consumers):
                # dus consumers alias in place (charged at the root); slices
                # read only their result size
                total += sum(_shape_bytes(c.result) for c in consumers
                             if c.kind in self._SLICE_KINDS)
            else:
                total += full
        # result side: in-place dynamic-update-slice producers are charged at
        # update size, not the whole buffer (scan grad accumulators etc.).
        by_name = {fop.name: fop for fop in fops}

        def _through_bitcast(fop: _Op | None) -> _Op | None:
            seen = 0
            while fop is not None and fop.kind in ("bitcast", "copy") \
                    and seen < 4:
                nms = _OPERAND_RE.findall(fop.args)
                fop = by_name.get(nms[0]) if nms else None
                seen += 1
            return fop

        def _elem_bytes(fop: _Op | None, fallback: float) -> float:
            fop = _through_bitcast(fop)
            if fop is not None and fop.kind == "dynamic-update-slice":
                nms = _OPERAND_RE.findall(fop.args)
                if len(nms) >= 2:
                    return 2.0 * _shape_bytes(fsym.get(nms[1], ""))
            return fallback

        if root is None:
            total += result_bytes
        elif root.kind == "tuple":
            for nm in _OPERAND_RE.findall(root.args):
                fop = by_name.get(nm)
                fb = _shape_bytes(fsym.get(nm, ""))
                total += _elem_bytes(fop, fb)
        else:
            total += _elem_bytes(root, result_bytes)
        return total

    def op_bytes(self, comp: str, op: _Op) -> float:
        """Approximate HBM traffic of one top-level op."""
        kind = op.kind
        if kind in _SKIP_BYTES_OPS or kind in ("while", "call", "conditional"):
            # ops whose called computations are walked at full cost: charging
            # the boundary too would double-count every buffer (the CPU
            # backend wraps whole programs in `call` computations)
            return 0.0
        if kind == "fusion":
            return self._fusion_bytes(comp, op)
        if kind in self._SLICE_KINDS:
            return 2.0 * _shape_bytes(op.result)
        if kind == "dynamic-update-slice":
            shapes = self._operand_shapes(comp, op)
            upd = shapes[1] if len(shapes) > 1 else op.result
            return 2.0 * _shape_bytes(upd)
        if kind in ("reshape", "transpose", "copy", "broadcast",
                    "concatenate", "pad", "reverse"):
            return 2.0 * _shape_bytes(op.result)
        return self._operand_bytes(comp, op) + _shape_bytes(op.result)

    def _operand_bytes(self, comp: str, op: _Op) -> float:
        sym = self.symbols.get(comp, {})
        total = 0.0
        for name in _OPERAND_RE.findall(op.args):
            if name in sym:
                total += _shape_bytes(sym[name])
        if total == 0.0:
            # operands may be printed without % in some formats
            for tok in re.split(r",\s*(?![^\[]*\])", op.args):
                tok = tok.strip().lstrip("%")
                base = tok.split(" ")[-1].lstrip("%")
                if base in sym:
                    total += _shape_bytes(sym[base])
                else:
                    total += _shape_bytes(tok)
        return total

    def _operand_shapes(self, comp: str, op: _Op) -> list[str]:
        sym = self.symbols.get(comp, {})
        out = []
        for name in _OPERAND_RE.findall(op.args):
            if name in sym:
                out.append(sym[name])
        if not out:
            out = [t.strip() for t in op.args.split(",")]
        return out

    def _trip_count(self, while_op: _Op, cond_comp: str | None) -> float:
        m = _TRIP_RE.search(while_op.raw)
        if m:
            return float(m.group(1))
        best = 1
        for op in self.comps.get(cond_comp or "", []):
            for mm in _CONST_INT_RE.finditer(op.raw):
                best = max(best, int(mm.group(1)))
        return float(best)

    def _group_size(self, attrs: str) -> int:
        m = _GROUPS_IOTA_RE.search(attrs)
        if m:
            return int(m.group(2))
        m = _GROUPS_BRACE_RE.search(attrs)
        if m:
            return len(m.group(1).split(","))
        return 2

    def _branch_comps(self, attrs: str) -> list[str]:
        m = _BRANCHES_RE.search(attrs)
        if m:
            return [b.strip().lstrip("%") for b in m.group(1).split(",")
                    if b.strip()]
        out = []
        for rx in (_TRUE_COMP_RE, _FALSE_COMP_RE):
            mm = rx.search(attrs)
            if mm:
                out.append(mm.group(1))
        return out

    def _dot_flops(self, comp: str, op: _Op) -> float:
        result_elems = _shape_elems(op.result)
        shapes = self._operand_shapes(comp, op)
        if not shapes:
            return 0.0
        m_sh = _SHAPE_RE.search(shapes[0])
        if not m_sh:
            return 0.0
        lhs_dims = [int(d) for d in m_sh.group(2).split(",") if d.strip()]
        contract = 1
        m = _CONTRACT_RE.search(op.attrs)
        if m:
            for idx in m.group(1).split(","):
                if idx.strip():
                    i = int(idx)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
        return 2.0 * result_elems * contract

    def comp_cost(self, name: str, *, fused: bool = False) -> Cost:
        key = name + ("#f" if fused else "")
        if key in self._cost_cache:
            return self._cost_cache[key]
        self._cost_cache[key] = Cost()  # break recursion cycles
        total = Cost()
        for op in self.comps.get(name, []):
            kind = op.kind
            if kind == "while":
                body = _BODY_RE.search(op.attrs)
                cond = _COND_RE.search(op.attrs)
                trips = self._trip_count(op, cond.group(1) if cond else None)
                if body:
                    total.add(self.comp_cost(body.group(1)), trips)
                if cond:
                    total.add(self.comp_cost(cond.group(1)), trips)
            elif kind == "conditional":
                # branches are referenced via branch_computations= (or
                # true_/false_computation=), which the calls= regex never
                # matches; walk them explicitly.  Summing all branches is an
                # upper bound (only one executes per invocation).
                for branch in self._branch_comps(op.attrs):
                    total.add(self.comp_cost(branch))
            elif kind in ("call", "fusion", "map", "reduce", "reduce-window",
                          "sort", "scatter", "select-and-scatter",
                          "custom-call"):
                m = _CALLS_RE.search(op.attrs)
                if m:
                    sub = self.comp_cost(m.group(1), fused=(kind == "fusion"))
                    if kind == "fusion":
                        total.flops += sub.flops
                        total.coll_wire += sub.coll_wire
                        for k, v in sub.coll_by_kind.items():
                            e = total.coll_by_kind.setdefault(
                                k, {"bytes": 0.0, "count": 0.0})
                            e["bytes"] += v["bytes"]
                            e["count"] += v["count"]
                    else:
                        total.add(sub)
                if kind == "reduce" and not m:
                    total.flops += self._operand_bytes(name, op) / 4.0
            elif kind == "dot":
                total.flops += self._dot_flops(name, op)
            elif kind == "convolution":
                total.flops += 2.0 * _shape_elems(op.result)
            elif any(kind.startswith(c) for c in COLLECTIVES):
                if kind.endswith("-done"):
                    continue
                base = kind.replace("-start", "")
                rb = _shape_bytes(op.result)
                n = self._group_size(op.attrs + op.args)
                if base == "all-gather":
                    wire = rb * (n - 1) / n
                elif base == "reduce-scatter":
                    wire = rb * (n - 1)
                elif base == "all-reduce":
                    wire = rb * 2 * (n - 1) / n
                elif base == "all-to-all":
                    wire = rb * (n - 1) / n
                else:
                    wire = rb
                total.add_coll(base, wire)
            elif kind not in _FLOP_FREE_OPS:
                total.flops += _shape_elems(op.result)

            if not fused and kind not in _SKIP_BYTES_OPS and kind != "while":
                total.bytes += self.op_bytes(name, op)
        self._cost_cache[key] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()


# ---------------------------------------------------------------------------
# duration prediction (DESIGN.md §11): price compute before running it
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceModel:
    """Roofline parameters used to turn an HLO `Cost` into seconds.

    Defaults are deliberately conservative CPU-backend numbers — for
    scheduling, only the *relative* pricing between tasks matters (the
    load balancer and the wait-vs-stage test compare predicted durations
    against each other and against `StagingCostModel` read times, never
    against wall time).  Calibrate for a real accelerator by passing the
    chip's peak flops / HBM bandwidth.
    """

    peak_flops: float = 5e10       # sustained flops/s
    mem_bw: float = 2e10           # bytes/s
    launch_overhead: float = 5e-5  # per-dispatch floor, s

    def seconds(self, cost: Cost) -> float:
        return self.launch_overhead + max(cost.flops / self.peak_flops,
                                          cost.bytes / self.mem_bw)


class DurationPredictor:
    """Predict a task body's duration from its optimized HLO — without
    running it (DESIGN.md §11).

    ``predict_duration(fn, args)`` abstract-evals and host-compiles `fn`
    at the arguments' shapes, walks the HLO with `analyze`, and converts
    flops/bytes to seconds through a roofline `DeviceModel`.  No device
    execution ever happens, so the call is safe on the clock thread; the
    one-time host-compile cost is amortized by a signature-keyed cache —
    every later task with the same (callable, shapes) signature is a dict
    probe.  Failures (bodies jit cannot trace) are cached as None, so a
    non-JAX task costs one failed trace, not one per task.

    Wire it into an engine so every submitted task with a callable and no
    explicit ``duration=`` is priced before dispatch::

        pred = DurationPredictor()
        eng = Engine(clock, duration_predictor=pred)
        eng.submit("mm", matmul_task, [x, w])   # duration filled by pred

    The predicted `duration` then reaches everything that prices
    simulated service time: `LoadBalancer.pick` (with
    ``duration_aware=True``, queued predicted seconds join the load
    term), the data layer's wait-vs-stage affinity test (parked
    `local_work` vs `StagingCostModel` staging estimates), and the
    backpressure/throttle machinery.
    """

    def __init__(self, device: DeviceModel | None = None):
        self.device = device or DeviceModel()
        self._cache: dict = {}
        self.compiles = 0      # signature misses that ran a host compile
        self.hits = 0          # served from the signature cache

    # -- signature ------------------------------------------------------
    def signature(self, fn, args) -> tuple:
        from repro.core.task import arg_signature, stable_fn_key
        return (stable_fn_key(fn), arg_signature(args))

    # -- prediction -----------------------------------------------------
    def predict_cost(self, fn, args) -> Cost | None:
        """Cached HLO `Cost` for calling ``fn(*args)`` (None when the body
        cannot be traced/compiled — e.g. a non-JAX callable)."""
        key = self.signature(fn, args)
        if key in self._cache:
            self.hits += 1
            return self._cache[key]
        self.compiles += 1
        try:
            import jax

            def _abstract(a):
                shape = getattr(a, "shape", None)
                dtype = getattr(a, "dtype", None)
                if shape is not None and dtype is not None:
                    return jax.ShapeDtypeStruct(tuple(shape), dtype)
                return a    # python literal: traced as a weak-typed scalar

            lowered = jax.jit(fn).lower(*[_abstract(a) for a in args])
            cost = analyze(lowered.compile().as_text())
        except BaseException:  # noqa: BLE001 — unpredictable body
            cost = None
        self._cache[key] = cost
        return cost

    def predict_duration(self, fn, args) -> float | None:
        """Predicted seconds for ``fn(*args)`` under the device model, or
        None when the body cannot be priced.  This is the `duration=`
        feed: `Engine.submit` calls it for tasks with a callable and no
        explicit duration when a predictor is attached."""
        cost = self.predict_cost(fn, args)
        if cost is None:
            return None
        return self.device.seconds(cost)

    def metrics(self) -> dict:
        return {
            "signatures": len(self._cache),
            "compiles": self.compiles,
            "hits": self.hits,
        }
