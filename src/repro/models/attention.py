"""Attention: blocked full/causal, banded local-window, GQA and MLA layers.

Memory-bounded pure-JAX implementations (these are also the oracles for the
Pallas kernels in `repro.kernels`):

* causal full attention — "super-row" decomposition: the sequence is split
  into `n_super` static row bands; band s only attends over its prefix
  (static length), with online softmax over kv blocks inside the band.
  Wasted FLOPs vs. exact causal ≈ 1/(2·n_super)  (6% at n_super=8).
* local (windowed) attention — banded gather: per q block, a static
  (window + q_block) kv slice is taken, so FLOPs are O(S·window), not O(S²).
* decode — single-query dense over the cache (global) or ring buffer (local).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamDesc
from repro.models import layers as L

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core attention math (q, k, v already per-head: (B, S, H, D))
# ---------------------------------------------------------------------------

def _dense_attn(q, k, v, mask, scale):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def _online_rows(q_band, k_band, v_band, scale, kv_block, q_start, k_start):
    """Online-softmax over kv blocks for one q band.

    q_band: (B, Sr, H, D); k/v_band: (B, P, H, D); causal mask from absolute
    positions (q_start + row, k_start + col).
    """
    B, Sr, H, D = q_band.shape
    P_len = k_band.shape[1]
    nk = P_len // kv_block
    qt = jnp.swapaxes(q_band, 1, 2)  # (B,H,Sr,D)
    kt = jnp.swapaxes(k_band, 1, 2).reshape(B, H, nk, kv_block, D)
    vt = jnp.swapaxes(v_band, 1, 2).reshape(B, H, nk, kv_block, v_band.shape[-1])
    kt = jnp.moveaxis(kt, 2, 0)  # (nk,B,H,kb,D)
    vt = jnp.moveaxis(vt, 2, 0)

    m0 = jnp.full((B, H, Sr), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, H, Sr), jnp.float32)
    a0 = jnp.zeros((B, H, Sr, v_band.shape[-1]), jnp.float32)
    rows = q_start + jnp.arange(Sr)

    def step(carry, xs):
        m, den, acc = carry
        kb, vb, j = xs
        cols = k_start + j * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bhqd,bhkd->bhqk", qt, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(cols[None, None, None, :] <= rows[None, None, :, None],
                      s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        den = den * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, den, acc), None

    (m, den, acc), _ = jax.lax.scan(step, (m0, d0, a0),
                                    (kt, vt, jnp.arange(nk)))
    o = acc / jnp.maximum(den[..., None], 1e-30)
    return jnp.swapaxes(o, 1, 2).astype(q_band.dtype)  # (B,Sr,H,Dv)


def causal_attention(q, k, v, *, scale, n_super=8, kv_block=512):
    """Exact causal attention, super-row blocked.  q,k,v: (B,S,H,D), S==T."""
    B, S, H, D = q.shape
    n_super = max(1, min(n_super, S // max(1, kv_block)))
    while S % n_super:
        n_super -= 1
    Sr = S // n_super
    kb = math.gcd(Sr, kv_block)
    outs = []
    for s in range(n_super):
        qs = jax.lax.slice_in_dim(q, s * Sr, (s + 1) * Sr, axis=1)
        ks = jax.lax.slice_in_dim(k, 0, (s + 1) * Sr, axis=1)
        vs = jax.lax.slice_in_dim(v, 0, (s + 1) * Sr, axis=1)
        outs.append(_online_rows(qs, ks, vs, scale, kb, s * Sr, 0))
    return jnp.concatenate(outs, axis=1)


def bidir_attention(q, k, v, *, scale, kv_block=1024):
    """Full bidirectional attention (encoder / cross)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    if T <= 2 * kv_block or T % kv_block:
        mask = jnp.ones((1, 1, S, T), bool)
        return _dense_attn(q, k, v, mask, scale)
    # online over kv blocks, no causal mask -> set rows high so mask passes
    return _online_rows(q, k, v, scale, kv_block, q_start=T, k_start=0)


def local_attention(q, k, v, *, scale, window, q_block=512):
    """Banded causal attention: key ∈ (query - window, query]."""
    B, S, H, D = q.shape
    Dv = v.shape[-1]
    qb = max(1, math.gcd(S, q_block))
    nq = S // qb
    W = window
    kp = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))

    def row(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * qb, qb, axis=1)
        ks = jax.lax.dynamic_slice_in_dim(kp, i * qb, W + qb, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, i * qb, W + qb, axis=1)
        r = jnp.arange(qb)[:, None]
        j = jnp.arange(W + qb)[None, :]
        valid = (j > r) & (j <= W + r) & (i * qb - W + j >= 0)
        s = jnp.einsum("bqhd,bkhd->bhqk", qs, ks,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(vs.dtype), vs,
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    if nq == 1:
        return row(0)
    outs = jax.lax.map(row, jnp.arange(nq))  # (nq, B, qb, H, Dv)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, Dv)


def decode_attention(q, k_cache, v_cache, mask, scale):
    """q: (B, 1, H, D); cache: (B, T, H, D); mask: (B, T) or (T,) bool."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if mask.ndim == 1:
        mask = mask[None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (params + cache)
# ---------------------------------------------------------------------------

def attn_descs(cfg: ModelConfig, cross: bool = False):
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    descs = {
        "norm": L.norm_descs(cfg),
        "wq": ParamDesc((d, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamDesc((d, Hkv, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDesc((d, Hkv, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDesc((H, Dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        descs["bq"] = ParamDesc((H, Dh), ("heads", "head_dim"), init="zeros")
        descs["bk"] = ParamDesc((Hkv, Dh), ("kv_heads", "head_dim"), init="zeros")
        descs["bv"] = ParamDesc((Hkv, Dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        descs["q_norm"] = ParamDesc((Dh,), ("head_dim",), init="ones")
        descs["k_norm"] = ParamDesc((Dh,), ("head_dim",), init="ones")
    return descs


def attn_cache_descs(cfg: ModelConfig, batch: int, seq: int, window: int):
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    if window > 0:
        W = min(window, seq)
        return {
            "k": ParamDesc((batch, W, Hkv, Dh), ("batch", None, "kv_heads", None), dtype=cdt),
            "v": ParamDesc((batch, W, Hkv, Dh), ("batch", None, "kv_heads", None), dtype=cdt),
            "pos": ParamDesc((batch, W), ("batch", None), dtype=jnp.int32),
        }
    return {
        "k": ParamDesc((batch, seq, Hkv, Dh), ("batch", "kv_seq", "kv_heads", None), dtype=cdt),
        "v": ParamDesc((batch, seq, Hkv, Dh), ("batch", "kv_seq", "kv_heads", None), dtype=cdt),
    }


def _project_qkv(cfg: ModelConfig, p, x, kv_x=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(cdt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        q = L.rms_head_norm(p["q_norm"], q)
        k = L.rms_head_norm(p["k_norm"], k)
    if cfg.cotangent_dtype:
        # the f32 attention-score dots (preferred_element_type) would
        # otherwise push f32 cotangents back through the projections
        from repro.models.transformer import cotangent_cast
        dt = jnp.dtype(cfg.cotangent_dtype)
        q, k, v = (cotangent_cast(t, dt) for t in (q, k, v))
    return q, k, v


def _expand_kv(cfg: ModelConfig, k):
    """Repeat kv heads to the q-head count and re-annotate sharding."""
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    if H != Hkv:
        k = jnp.repeat(k, H // Hkv, axis=2)
    return constrain(k, ("batch", None, "heads", None))


def apply_attn(cfg: ModelConfig, p, x, *, window: int, causal: bool = True,
               mode: str = "train", cache=None, pos_t=None, enc_out=None,
               cross: bool = False):
    """Returns (out, new_cache)."""
    B, S, _ = x.shape
    Dh = cfg.head_dim
    scale = 1.0 / math.sqrt(Dh)
    h = L.apply_norm(cfg, p["norm"], x)

    if mode in ("train", "prefill"):
        kv_src = enc_out if cross else None
        q, k, v = _project_qkv(cfg, p, h, kv_src)
        if not cross:
            pos = jnp.arange(S)[None]
            q = L.positions_for(cfg, q, pos) if cfg.pos_embed == "rope" else q
            k = L.positions_for(cfg, k, pos) if cfg.pos_embed == "rope" else k
        k_store, v_store = k, v
        q = constrain(q, ("batch", None, "heads", None))
        if cfg.use_pallas and not cross:
            # TPU hot path: Pallas flash kernel (GQA handled by index maps)
            from repro.kernels import ops as kops
            o = kops.flash_attention(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                jnp.swapaxes(v, 1, 2), causal=causal, window=window,
                scale=scale, block_q=cfg.attn_q_block,
                block_k=min(cfg.attn_kv_block, cfg.attn_q_block))
            o = jnp.swapaxes(o, 1, 2)
        else:
            ke, ve = _expand_kv(cfg, k), _expand_kv(cfg, v)
            if cross or not causal:
                o = bidir_attention(q, ke, ve, scale=scale,
                                    kv_block=cfg.attn_kv_block)
            elif window > 0:
                o = local_attention(q, ke, ve, scale=scale, window=window,
                                    q_block=cfg.attn_q_block)
            else:
                o = causal_attention(q, ke, ve, scale=scale,
                                     kv_block=cfg.attn_kv_block)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
        new_cache = None
        if mode == "prefill" and not cross:
            if window > 0:
                W = min(window, S)
                new_cache = {
                    "k": k_store[:, S - W:], "v": v_store[:, S - W:],
                    "pos": jnp.broadcast_to(jnp.arange(S - W, S)[None], (B, W)),
                }
            else:
                new_cache = {"k": k_store, "v": v_store}
        elif mode == "prefill" and cross:
            new_cache = {"k": k_store, "v": v_store}
        return x + out, new_cache

    # ---- decode: S == 1 ----
    assert cache is not None
    if cross:
        ke = _expand_kv(cfg, cache["k"])
        ve = _expand_kv(cfg, cache["v"])
        q, _, _ = _project_qkv(cfg, p, h, h)  # k,v unused for cross decode
        mask = jnp.ones((ke.shape[1],), bool)
        o = decode_attention(q, ke, ve, mask, scale)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
        return x + out, cache

    q, k, v = _project_qkv(cfg, p, h)
    pos = jnp.full((B, 1), pos_t)
    if cfg.pos_embed == "rope":
        q = L.positions_for(cfg, q, pos)
        k = L.positions_for(cfg, k, pos)
    if window > 0:
        W = cache["k"].shape[1]
        slot = pos_t % W
        k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        pos_c = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], pos.astype(jnp.int32), slot, axis=1)
        mask = (pos_c >= 0) & (pos_c <= pos_t) & (pos_c > pos_t - window)
        new_cache = {"k": k_c, "v": v_c, "pos": pos_c}
    else:
        k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos_t, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos_t, axis=1)
        T = k_c.shape[1]
        mask = jnp.arange(T)[None] <= pos_t
        new_cache = {"k": k_c, "v": v_c}
    ke, ve = _expand_kv(cfg, k_c), _expand_kv(cfg, v_c)
    o = decode_attention(q, ke, ve, mask, scale)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return x + out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_descs(cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    ql, kl = cfg.mla_q_lora, cfg.mla_kv_lora
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    return {
        "norm": L.norm_descs(cfg),
        "wq_a": ParamDesc((d, ql), ("embed", "lora")),
        "q_norm": ParamDesc((ql,), ("lora",), init="ones"),
        "wq_b": ParamDesc((ql, H, dn + dr), ("lora", "heads", "head_dim")),
        "wkv_a": ParamDesc((d, kl + dr), ("embed", "lora")),
        "kv_norm": ParamDesc((kl,), ("lora",), init="ones"),
        "wk_b": ParamDesc((kl, H, dn), ("lora", "heads", "head_dim")),
        "wv_b": ParamDesc((kl, H, dv), ("lora", "heads", "head_dim")),
        "wo": ParamDesc((H, dv, d), ("heads", "head_dim", "embed")),
    }


def mla_cache_descs(cfg: ModelConfig, batch: int, seq: int):
    cdt = jnp.dtype(cfg.compute_dtype)
    return {
        "c_kv": ParamDesc((batch, seq, cfg.mla_kv_lora), ("batch", "kv_seq", None), dtype=cdt),
        "k_rope": ParamDesc((batch, seq, cfg.mla_rope_dim), ("batch", "kv_seq", None), dtype=cdt),
    }


def _mla_common(cfg: ModelConfig, p, h, positions):
    cdt = jnp.dtype(cfg.compute_dtype)
    dn, dr = cfg.mla_nope_dim, cfg.mla_rope_dim
    kl = cfg.mla_kv_lora
    cq = jnp.einsum("bsd,dl->bsl", h, p["wq_a"].astype(cdt))
    # low-rank RMS norms (fp32 internally)
    cq = cq * jax.lax.rsqrt(jnp.mean(jnp.square(cq.astype(jnp.float32)), -1,
                                     keepdims=True) + 1e-6).astype(cdt)
    cq = cq * p["q_norm"].astype(cdt)
    qf = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"].astype(cdt))
    q_nope, q_rope = qf[..., :dn], qf[..., dn:]
    q_rope = L.apply_rope(cfg, q_rope, positions)
    ckv_f = jnp.einsum("bsd,dl->bsl", h, p["wkv_a"].astype(cdt))
    c_kv, k_rope = ckv_f[..., :kl], ckv_f[..., kl:]
    c_kv = c_kv * jax.lax.rsqrt(jnp.mean(jnp.square(c_kv.astype(jnp.float32)),
                                         -1, keepdims=True) + 1e-6).astype(cdt)
    c_kv = c_kv * p["kv_norm"].astype(cdt)
    k_rope = L.apply_rope(cfg, k_rope[:, :, None, :], positions)[:, :, 0]
    q_nope = constrain(q_nope, ("batch", None, "heads", None))
    return q_nope, q_rope, c_kv, k_rope


def apply_mla(cfg: ModelConfig, p, x, *, mode="train", cache=None, pos_t=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, _ = x.shape
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    scale = 1.0 / math.sqrt(dn + dr)
    h = L.apply_norm(cfg, p["norm"], x)

    if mode in ("train", "prefill"):
        pos = jnp.arange(S)[None]
        q_nope, q_rope, c_kv, k_rope = _mla_common(cfg, p, h, pos)
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["wk_b"].astype(cdt))
        v = jnp.einsum("bsl,lhk->bshk", c_kv, p["wv_b"].astype(cdt))
        k_nope = constrain(k_nope, ("batch", None, "heads", None))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, cfg.n_heads, dr))], axis=-1)
        o = causal_attention(q, k, v, scale=scale, kv_block=cfg.attn_kv_block)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope} if mode == "prefill" else None
        return x + out, new_cache

    # ---- absorbed decode ----
    assert cache is not None
    pos = jnp.full((B, 1), pos_t)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_common(cfg, p, h, pos)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv_new, pos_t, axis=1)
    krp = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope_new, pos_t, axis=1)
    T = ckv.shape[1]
    # absorb W_k into q:  q_eff (B,S,H,L)
    q_eff = jnp.einsum("bshk,lhk->bshl", q_nope, p["wk_b"].astype(cdt))
    s = (jnp.einsum("bshl,btl->bhst", q_eff, ckv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bshr,btr->bhst", q_rope, krp,
                      preferred_element_type=jnp.float32)) * scale
    mask = jnp.arange(T)[None, None, None, :] <= pos_t
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhst,btl->bshl", w.astype(cdt), ckv,
                     preferred_element_type=jnp.float32).astype(cdt)
    o = jnp.einsum("bshl,lhk->bshk", o_c, p["wv_b"].astype(cdt))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cdt))
    return x + out, {"c_kv": ckv, "k_rope": krp}
