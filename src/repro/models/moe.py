"""Mixture-of-Experts FFN (DeepSeek-V2 / Granite style).

Scatter-based dispatch (no GShard one-hot dispatch einsum): tokens are
scatter-added into per-expert capacity slots and gathered back, so dispatch
costs O(tokens·d) data movement and zero matmul FLOPs.

Grouping/sharding layout: the *batch* dim is the parallel group axis (it is
the data-sharded dim, so each data shard routes its own tokens — GShard's
"groups == shards" layout); the sequence dim is scanned in chunks of
``moe.group_size`` to bound the expert-space buffer working set.  Expert
weights carry an ("experts" -> data) sharding in the default rules, giving
expert-parallelism over the data axis: the `constrain` on the dispatched
buffer makes XLA redistribute *activations* (all-to-all-shaped), never the
expert weights.  Capacity follows GShard (c = g·k/E·capacity_factor), k-slot-
major priority; top-k gate weights are renormalized (DeepSeek-style); shared
experts are an always-on dense GLU branch.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamDesc
from repro.models import layers as L


def moe_descs(cfg: ModelConfig):
    m = cfg.moe
    d, E, eff = cfg.d_model, m.n_experts, m.expert_ff
    descs = {
        "norm": L.norm_descs(cfg),
        "router": ParamDesc((d, E), ("embed", "experts"), dtype=jnp.float32),
        "w1": ParamDesc((E, d, eff), ("experts", "embed", "expert_ff")),
        "w3": ParamDesc((E, d, eff), ("experts", "embed", "expert_ff")),
        "w2": ParamDesc((E, eff, d), ("experts", "expert_ff", "embed")),
    }
    if m.n_shared:
        sff = m.n_shared * eff
        descs["shared"] = {
            "w1": ParamDesc((d, sff), ("embed", "ff")),
            "w3": ParamDesc((d, sff), ("embed", "ff")),
            "w2": ParamDesc((sff, d), ("ff", "embed")),
        }
    return descs


def _capacity(g: int, k: int, E: int, factor: float) -> int:
    c = int(math.ceil(g * k / E * factor))
    return max(4, ((c + 3) // 4) * 4)


def _route_chunk(cfg: ModelConfig, p, h):
    """h: (B, t, d) one sequence chunk -> (y, aux_loss)."""
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    B, t, d = h.shape
    c = _capacity(t, K, E, m.capacity_factor)
    cdt = h.dtype

    logits = jnp.einsum("btd,de->bte", h.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, t, E)
    gate_w, gate_idx = jax.lax.top_k(probs, K)                   # (B, t, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * P_e   (per group)
    f = jax.vmap(lambda idx: jnp.zeros((E,), jnp.float32)
                 .at[idx.reshape(-1)].add(1.0))(gate_idx) / (t * K)
    P_e = probs.mean(axis=1)
    aux = (E * jnp.sum(f * P_e, axis=-1)).mean()

    # position-in-expert, k-slot-major priority (GShard)
    idx_km = jnp.swapaxes(gate_idx, 1, 2).reshape(B, K * t)      # k-major
    oh = jax.nn.one_hot(idx_km, E, dtype=jnp.int32)              # (B, K*t, E)
    pos = jnp.cumsum(oh, axis=1) - 1
    pos_of = jnp.sum(pos * oh, axis=-1).reshape(B, K, t)
    eid = jnp.swapaxes(gate_idx, 1, 2)                           # (B, K, t)
    keep = pos_of < c

    if m.dispatch == "index":
        # index-indirection dispatch: scatter ONLY the int32 slot->token map
        # (negligible bytes), then gather the token data.  The (B, E*c, d)
        # expert buffer is produced by a gather, never by a partial-sum
        # scatter-add that GSPMD would replicate + all-reduce.
        h_pad = jnp.concatenate([h, jnp.zeros((B, 1, d), cdt)], axis=1)
        tok = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None, None],
                               (B, K, t))
        inv = jnp.full((B, E * c + 1), t, jnp.int32)

        def set_one(inv_b, slot_b, tok_b):
            return inv_b.at[slot_b].set(tok_b)

        for k in range(K):
            slot = jnp.where(keep[:, k], eid[:, k] * c + pos_of[:, k], E * c)
            inv = jax.vmap(set_one)(inv, slot, tok[:, k])
        xe = jnp.take_along_axis(h_pad, inv[:, : E * c, None], axis=1)
        xe = xe.reshape(B, E, c, d)
    else:
        def scatter_one(xs_b, slot_b, h_b):
            return xs_b.at[slot_b].add(h_b)

        xs = jnp.zeros((B, E * c + 1, d), cdt)
        for k in range(K):
            slot = jnp.where(keep[:, k], eid[:, k] * c + pos_of[:, k], E * c)
            xs = jax.vmap(scatter_one)(xs, slot, h)
        xe = xs[:, : E * c].reshape(B, E, c, d)
    xe = constrain(xe, ("batch", "experts", None, None))

    act = L.act_fn(cfg.act)
    g1 = jnp.einsum("becd,edf->becf", xe, p["w1"].astype(cdt))
    u1 = jnp.einsum("becd,edf->becf", xe, p["w3"].astype(cdt))
    ye = jnp.einsum("becf,efd->becd", act(g1) * u1, p["w2"].astype(cdt))
    ye = constrain(ye, ("batch", "experts", None, None))
    yf = jnp.concatenate(
        [ye.reshape(B, E * c, d), jnp.zeros((B, 1, d), cdt)], axis=1)

    y = jnp.zeros((B, t, d), cdt)
    for k in range(K):
        slot = jnp.where(keep[:, k], eid[:, k] * c + pos_of[:, k], E * c)
        gathered = jax.vmap(lambda yf_b, s_b: jnp.take(yf_b, s_b, axis=0))(yf, slot)
        y = y + gate_w[:, :, k, None].astype(cdt) * gathered
    return y, aux


def apply_moe(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (x + moe_out, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    h = L.apply_norm(cfg, p["norm"], x)
    g = min(m.group_size, S)
    while S % g:
        g -= 1
    ns = S // g

    if ns == 1:
        y, aux = _route_chunk(cfg, p, h)
    else:
        hg = jnp.moveaxis(h.reshape(B, ns, g, d), 1, 0)  # (ns, B, g, d)

        def body(_, h_c):
            return (), _route_chunk(cfg, p, h_c)

        _, (yg, auxs) = jax.lax.scan(body, (), hg)
        y = jnp.moveaxis(yg, 0, 1).reshape(B, S, d)
        aux = auxs.mean()
    out = y.reshape(B, S, d)

    if m.n_shared:
        sp = p["shared"]
        cdt = h.dtype
        act = L.act_fn(cfg.act)
        z = act(jnp.einsum("bsd,df->bsf", h, sp["w1"].astype(cdt))) * \
            jnp.einsum("bsd,df->bsf", h, sp["w3"].astype(cdt))
        out = out + jnp.einsum("bsf,fd->bsd", z, sp["w2"].astype(cdt))
    return x + out, aux * m.aux_loss_weight
