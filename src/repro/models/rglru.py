"""Griffin / RecurrentGemma RG-LRU recurrent block.

Block: x -> (gate branch: GeLU(x·W_gate)) ⊙ RG-LRU(conv1d(x·W_in)) -> W_out.
RG-LRU: r_t = σ(u·W_a), i_t = σ(u·W_x), a_t = a^(c·r_t) with a = σ(Λ),
        h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t).

Same chunked-linear-recurrence evaluation as the Mamba block (state is
(B, lru_width), no d_state expansion).  `repro.kernels.rglru_scan` is the
Pallas/TPU tiling of the inner recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamDesc
from repro.models import layers as L
from repro.models.ssm import _causal_conv


def rglru_descs(cfg: ModelConfig):
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    K = r.d_conv
    return {
        "norm": L.norm_descs(cfg),
        "in_x": ParamDesc((d, w), ("embed", "rnn")),
        "in_gate": ParamDesc((d, w), ("embed", "rnn")),
        "conv_w": ParamDesc((K, w), (None, "rnn")),
        "conv_b": ParamDesc((w,), ("rnn",), init="zeros"),
        "gate_a": ParamDesc((w, w), ("rnn", None)),
        "gate_x": ParamDesc((w, w), ("rnn", None)),
        "a_param": ParamDesc((w,), ("rnn",), init="lru_a"),
        "out_proj": ParamDesc((w, d), ("rnn", "embed")),
    }


def rglru_cache_descs(cfg: ModelConfig, batch: int):
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    return {
        "state": ParamDesc((batch, w), ("batch", "rnn"), dtype=jnp.float32),
        "conv": ParamDesc((batch, r.d_conv - 1, w), ("batch", None, "rnn"),
                          dtype=jnp.dtype(cfg.compute_dtype)),
    }


def rglru_scan(u, a_gate, x_gate, a_param, *, c: float, chunk: int, h0=None):
    """u, a_gate, x_gate: (B, S, W) — returns (y, h_final), fp32 recurrence."""
    B, S, W = u.shape
    log_a = -c * jax.nn.softplus(-a_param.astype(jnp.float32))  # log σ(Λ) scaled
    # a_t = exp(log_a * r_t)
    r = jax.nn.sigmoid(a_gate.astype(jnp.float32))
    i = jax.nn.sigmoid(x_gate.astype(jnp.float32))
    log_at = log_a[None, None] * r                  # (B,S,W)
    at = jnp.exp(log_at)
    bt = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 1e-12)) \
        * (i * u.astype(jnp.float32))

    nc = max(1, S // chunk)
    while S % nc:
        nc -= 1
    ch = S // nc
    h0 = jnp.zeros((B, W), jnp.float32) if h0 is None else h0
    ac = jnp.moveaxis(at.reshape(B, nc, ch, W), 1, 0)
    bc = jnp.moveaxis(bt.reshape(B, nc, ch, W), 1, 0)

    def chunk_step(h, xs):
        a_, b_ = xs

        def combine(p, q):
            a1, b1 = p
            a2, b2 = q
            return a1 * a2, a2 * b1 + b2

        accA, accB = jax.lax.associative_scan(combine, (a_, b_), axis=1)
        hs = accA * h[:, None] + accB
        return hs[:, -1], hs

    h_fin, ys = jax.lax.scan(chunk_step, h0, (ac, bc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, W)
    return y, h_fin


def apply_rglru(cfg: ModelConfig, p, x, *, mode="train", cache=None, pos_t=None):
    r = cfg.rglru
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    w = r.lru_width or d
    h = L.apply_norm(cfg, p["norm"], x)

    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, p["in_gate"].astype(cdt)),
                       approximate=True)
    u = jnp.einsum("bsd,dw->bsw", h, p["in_x"].astype(cdt))
    u = constrain(u, ("batch", None, "rnn"))

    if mode in ("train", "prefill"):
        uc, tail = _causal_conv(u, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))
        a_gate = jnp.einsum("bsw,wv->bsv", uc, p["gate_a"].astype(cdt))
        x_gate = jnp.einsum("bsw,wv->bsv", uc, p["gate_x"].astype(cdt))
        if cfg.use_pallas:
            from repro.kernels import ops as kops
            log_a = -r.c * jax.nn.softplus(-p["a_param"].astype(jnp.float32))
            rg = jax.nn.sigmoid(a_gate.astype(jnp.float32))
            ig = jax.nn.sigmoid(x_gate.astype(jnp.float32))
            log_at = log_a[None, None] * rg
            at = jnp.exp(log_at)
            bt = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 1e-12)) \
                * (ig * uc.astype(jnp.float32))
            h0 = jnp.zeros((B, w), jnp.float32)
            y, h_fin = kops.rglru_scan(at, bt, h0, chunk=r.chunk)
        else:
            y, h_fin = rglru_scan(uc, a_gate, x_gate, p["a_param"],
                                  c=r.c, chunk=r.chunk)
        out = jnp.einsum("bsw,wd->bsd", y.astype(cdt) * gate,
                         p["out_proj"].astype(cdt))
        new_cache = None
        if mode == "prefill":
            new_cache = {"state": h_fin,
                         "conv": tail if tail is not None else
                         jnp.zeros((B, r.d_conv - 1, w), cdt)}
        return x + out, new_cache

    # ---- decode ----
    assert cache is not None
    tail = cache["conv"]
    win = jnp.concatenate([tail.astype(cdt), u], axis=1)       # (B, K, w)
    uc = jnp.einsum("bkw,kw->bw", win, p["conv_w"].astype(cdt)) \
        + p["conv_b"].astype(cdt)
    a_gate = jnp.einsum("bw,wv->bv", uc, p["gate_a"].astype(cdt))
    x_gate = jnp.einsum("bw,wv->bv", uc, p["gate_x"].astype(cdt))
    log_a = -r.c * jax.nn.softplus(-p["a_param"].astype(jnp.float32))
    rg = jax.nn.sigmoid(a_gate.astype(jnp.float32))
    ig = jax.nn.sigmoid(x_gate.astype(jnp.float32))
    log_at = log_a[None] * rg
    at = jnp.exp(log_at)
    bt = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 1e-12)) \
        * (ig * uc.astype(jnp.float32))
    h_new = at * cache["state"] + bt
    out = jnp.einsum("bw,wd->bd", h_new.astype(cdt) * gate[:, 0],
                     p["out_proj"].astype(cdt))[:, None]
    new_tail = jnp.concatenate([tail[:, 1:], u.astype(tail.dtype)], axis=1)
    return x + out, {"state": h_new, "conv": new_tail}
