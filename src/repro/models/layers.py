"""Shared model primitives: norms, FFN, embeddings, rotary / sinusoidal positions."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDesc


# ---------------------------------------------------------------------------
# activations / norms
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def norm_descs(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    out = {"scale": ParamDesc((d,), ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        out["bias"] = ParamDesc((d,), ("embed",), init="zeros")
    return out


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm (gemma3): normalize the last (head_dim) axis."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------

def ffn_descs(cfg: ModelConfig, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    descs = {
        "norm": norm_descs(cfg),
        "w1": ParamDesc((d, ff), ("embed", "ff")),
        "w2": ParamDesc((ff, d), ("ff", "embed")),
    }
    if cfg.gated_ffn:
        descs["w3"] = ParamDesc((d, ff), ("embed", "ff"))
    else:
        descs["b1"] = ParamDesc((ff,), ("ff",), init="zeros")
        descs["b2"] = ParamDesc((d,), ("embed",), init="zeros")
    return descs


def apply_ffn(cfg: ModelConfig, p, x):
    cdt = jnp.dtype(cfg.compute_dtype)
    h = apply_norm(cfg, p["norm"], x)
    act = act_fn(cfg.act)
    if cfg.gated_ffn:
        g = jnp.einsum("bsd,df->bsf", h, p["w1"].astype(cdt))
        u = jnp.einsum("bsd,df->bsf", h, p["w3"].astype(cdt))
        z = act(g) * u
        out = jnp.einsum("bsf,fd->bsd", z, p["w2"].astype(cdt))
    else:
        z = act(jnp.einsum("bsd,df->bsf", h, p["w1"].astype(cdt)) + p["b1"].astype(cdt))
        out = jnp.einsum("bsf,fd->bsd", z, p["w2"].astype(cdt)) + p["b2"].astype(cdt)
    return x + out


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_descs(cfg: ModelConfig):
    descs = {"embed": {"table": ParamDesc((cfg.vocab_padded, cfg.d_model),
                                          ("vocab", "embed"), scale=0.02)}}
    if not cfg.tie_embeddings:
        descs["unembed"] = {"table": ParamDesc((cfg.vocab_padded, cfg.d_model),
                                               ("vocab", "embed"), scale=0.02)}
    return descs


def apply_embed(cfg: ModelConfig, params, tokens):
    cdt = jnp.dtype(cfg.compute_dtype)
    table = params["embed"]["table"]
    x = jnp.take(table, tokens, axis=0).astype(cdt)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cdt)
    return x


def unembed_table(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"]["table"]
    return params["unembed"]["table"]


# ---------------------------------------------------------------------------
# positions: RoPE / M-RoPE / sinusoidal
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, rot_dim: int):
    half = rot_dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return inv  # (half,)


def apply_rope(cfg: ModelConfig, x, positions, rot_dim: int | None = None):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    rot = rot_dim or D
    half = rot // 2
    inv = rope_freqs(cfg, rot)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:rot]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.concatenate([xr1, xr2], axis=-1)
    if rot < D:
        out = jnp.concatenate([out, x[..., rot:]], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(cfg: ModelConfig, x, positions):
    """Qwen2-VL M-RoPE: head_dim/2 frequency slots split into (t, h, w) sections.

    For pure-text streams the three position ids coincide (the VLM frontend is
    a stub per the assignment); the section structure is still exercised.
    positions: (..., S) or (..., 3, S).
    """
    D = x.shape[-1]
    half = D // 2
    sections = cfg.mrope_sections
    assert sum(sections) == half, (sections, half)
    if positions.ndim == x.ndim - 2:  # (..., S) -> same pos for all sections
        pos3 = jnp.stack([positions] * 3, axis=-2)
    else:
        pos3 = positions
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # choose section s for each frequency slot
    sec_id = jnp.concatenate([
        jnp.full((n,), i, dtype=jnp.int32) for i, n in enumerate(sections)
    ])  # (half,)
    # pos3: (..., 3, S) -> (..., S, 3); each freq slot picks one of the 3 ids
    p = jnp.moveaxis(pos3, -2, -1)  # (..., S, 3)
    pos_slot = jnp.take(p, sec_id, axis=-1)  # (..., S, half)
    ang = pos_slot.astype(jnp.float32) * inv  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos, sin = cos[..., None, :], sin[..., None, :]  # broadcast heads
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


def sinusoidal_pos(d_model: int, positions):
    """Whisper-style sinusoids: positions (...,) -> (..., d_model), fp32."""
    half = d_model // 2
    inv = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                  * (math.log(10000.0) / max(1, half - 1)))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def positions_for(cfg: ModelConfig, q, pos):
    """Apply the configured positional scheme to q/k tensors (..., S, H, D)."""
    if cfg.mrope_sections:
        return apply_mrope(cfg, q, pos)
    return apply_rope(cfg, q, pos)
