"""Parameter descriptor system.

A model is described by a pytree (nested dicts) of `ParamDesc` leaves.  The
same tree is the single source of truth for

  * initialization         (`init_tree`)
  * sharding PartitionSpecs (`spec_tree`)
  * abstract shapes         (`shape_tree`)

Logical axis names on each parameter dim map to mesh axes through a rules
dict (e.g. ``{"ff": "model", "vocab": "model", "batch": ("pod", "data")}``).
A logical axis is only sharded when the dimension size is divisible by the
product of the mesh axis sizes it maps to; otherwise it silently falls back
to replication (this is what makes e.g. 28-head models lower on a 16-way
model axis).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDesc:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]  # logical axis name (str) or None, per dim
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | lru_a
    scale: float | None = None  # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_desc(x) -> bool:
    return isinstance(x, ParamDesc)


def tree_map_desc(fn: Callable[[ParamDesc], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_desc)


def stack_desc(tree, n: int, axis_name: str | None = "layers"):
    """Add a leading stacking dim (for scan-over-layers parameter stacking)."""

    def f(d: ParamDesc) -> ParamDesc:
        return dataclasses.replace(d, shape=(n,) + d.shape, axes=(axis_name,) + d.axes)

    return tree_map_desc(f, tree)


def _fan_in(shape: tuple[int, ...]) -> int:
    if len(shape) == 0:
        return 1
    if len(shape) == 1:
        return shape[0]
    # last dim is the output dim by convention here
    return max(1, math.prod(shape[:-1]))


def init_tree(tree, key, param_dtype=jnp.float32):
    """Materialize parameters from descriptors."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_desc)
    keys = jax.random.split(key, max(1, len(leaves)))

    def init_one(d: ParamDesc, k):
        dtype = d.dtype if d.dtype is not None else param_dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "lru_a":
            # RG-LRU / LRU "Lambda" parameter: softplus-inverse of a in (0.9, 0.999)
            u = jax.random.uniform(k, d.shape, jnp.float32, 0.9, 0.999)
            # a = sigmoid(L) ** (c * r); init L so sigmoid(L)=u^(1/c) with c=8
            val = jnp.log(u ** (1.0 / 8.0) / (1 - u ** (1.0 / 8.0)))
            return val.astype(dtype)
        scale = d.scale if d.scale is not None else 1.0 / math.sqrt(_fan_in(d.shape))
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [init_one(d, k) for d, k in zip(leaves, keys)]
    )


def _axis_size(mesh_shape: dict[str, int], mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        return mesh_shape.get(mesh_axes, 1)
    return math.prod(mesh_shape.get(a, 1) for a in mesh_axes)


def resolve_spec(d: ParamDesc, rules: dict[str, Any], mesh_shape: dict[str, int]) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback."""
    parts = []
    used: set = set()

    def flat(ax):
        return (ax,) if isinstance(ax, str) else tuple(ax)

    for dim, ax in zip(d.shape, d.axes):
        mapped = rules.get(ax) if ax is not None else None
        if mapped is None:
            parts.append(None)
            continue
        size = _axis_size(mesh_shape, mapped)
        names = flat(mapped)
        if size <= 1 or dim % size != 0 or any(n in used for n in names):
            parts.append(None)
            continue
        used.update(names)
        parts.append(mapped)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def spec_tree(tree, rules: dict[str, Any], mesh: Mesh):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return tree_map_desc(lambda d: resolve_spec(d, rules, mesh_shape), tree)


def sharding_tree(tree, rules: dict[str, Any], mesh: Mesh):
    specs = spec_tree(tree, rules, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def shape_tree(tree, param_dtype=jnp.float32):
    return tree_map_desc(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or param_dtype), tree
    )


def count_params(tree) -> int:
    return sum(math.prod(d.shape) for d in jax.tree_util.tree_leaves(
        tree_map_desc(lambda d: d, tree), is_leaf=is_desc) if is_desc(d))


# ---------------------------------------------------------------------------
# Default logical-axis -> mesh-axis rules
# ---------------------------------------------------------------------------

def default_rules(multi_pod: bool = False, *, shard_layers_over_data: bool = False,
                  seq_axis: bool = False) -> dict[str, Any]:
    """Baseline tensor-parallel rules.

    batch      -> data (and pod) axes  (pure DP)
    vocab/ff/heads/inner/rnn -> model axis (TP)
    layers     -> optionally data (ZeRO-3-style param sharding; hillclimb lever)
    seq        -> data (sequence-parallel KV cache for batch=1 long context)
    """
    data_axes = ("pod", "data") if multi_pod else ("data",)
    rules: dict[str, Any] = {
        "batch": data_axes if len(data_axes) > 1 else data_axes[0],
        "vocab": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "experts": "data",        # expert-parallel over the data axis
        "expert_ff": "model",     # + TP inside experts
        "inner": "model",         # mamba d_inner
        "rnn": "model",           # rg-lru width
        "state": None,
        "lora": None,
        "layers": data_axes[-1] if shard_layers_over_data else None,
        "kv_seq": data_axes[-1] if seq_axis else None,
        "seq_act": None,          # activation sequence sharding (train/prefill)
        "frames": None,
    }
    return rules
