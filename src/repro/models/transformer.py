"""Model assembly: layer-pattern scan, train/prefill/decode entry points.

The layer stack is a sequence of (pattern, repeats) groups (see
`ModelConfig.blocks`).  Each group's parameters are stacked along a leading
dim and the group body (the unrolled pattern, <= 6 layers) is `lax.scan`ned —
one compiled block per group regardless of depth.  The body is `jax.checkpoint`ed
for training (configurable policy).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.params import ParamDesc, stack_desc

ENC_SPEC = LayerSpec(mixer="attn", window=0, ffn="dense", causal=False)


# ---------------------------------------------------------------------------
# descriptors
# ---------------------------------------------------------------------------

def layer_descs(cfg: ModelConfig, spec: LayerSpec):
    d: dict[str, Any] = {}
    if spec.mixer == "attn":
        d["mixer"] = A.attn_descs(cfg)
    elif spec.mixer == "mla":
        d["mixer"] = A.mla_descs(cfg)
    elif spec.mixer == "mamba":
        d["mixer"] = S.mamba_descs(cfg)
    elif spec.mixer == "rglru":
        d["mixer"] = R.rglru_descs(cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        d["cross"] = A.attn_descs(cfg, cross=True)
    if spec.ffn == "dense":
        d["ffn"] = L.ffn_descs(cfg)
    elif spec.ffn == "moe":
        d["moe"] = M.moe_descs(cfg)
    return d


def build_descriptors(cfg: ModelConfig):
    descs: dict[str, Any] = dict(L.embed_descs(cfg))
    descs["final_norm"] = L.norm_descs(cfg)
    descs["blocks"] = [
        stack_desc({f"l{i}": layer_descs(cfg, s) for i, s in enumerate(pattern)},
                   reps)
        for pattern, reps in cfg.blocks
    ]
    if cfg.enc_dec:
        descs["encoder"] = {
            "blocks": [stack_desc({"l0": layer_descs(cfg, ENC_SPEC)},
                                  cfg.n_enc_layers)],
            "final_norm": L.norm_descs(cfg),
        }
    return descs


def layer_cache_descs(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int):
    d: dict[str, Any] = {}
    if spec.mixer == "attn":
        d["mixer"] = A.attn_cache_descs(cfg, batch, seq, spec.window)
    elif spec.mixer == "mla":
        d["mixer"] = A.mla_cache_descs(cfg, batch, seq)
    elif spec.mixer == "mamba":
        d["mixer"] = S.mamba_cache_descs(cfg, batch)
    elif spec.mixer == "rglru":
        d["mixer"] = R.rglru_cache_descs(cfg, batch)
    if spec.cross_attn:
        Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
        cdt = jnp.dtype(cfg.compute_dtype)
        d["cross"] = {
            "k": ParamDesc((batch, cfg.enc_frames, Hkv, Dh),
                           ("batch", None, "kv_heads", None), dtype=cdt),
            "v": ParamDesc((batch, cfg.enc_frames, Hkv, Dh),
                           ("batch", None, "kv_heads", None), dtype=cdt),
        }
    return d


def build_cache_descriptors(cfg: ModelConfig, batch: int, seq: int):
    return [
        stack_desc({f"l{i}": layer_cache_descs(cfg, s, batch, seq)
                    for i, s in enumerate(pattern)}, reps)
        for pattern, reps in cfg.blocks
    ]


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------

def apply_layer(cfg: ModelConfig, spec: LayerSpec, p, x, *, mode, cache,
                pos_t, enc_out):
    new_cache: dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    c_mix = cache.get("mixer") if cache else None
    if spec.mixer == "attn":
        x, nc = A.apply_attn(cfg, p["mixer"], x, window=spec.window,
                             causal=spec.causal, mode=mode, cache=c_mix,
                             pos_t=pos_t)
    elif spec.mixer == "mla":
        x, nc = A.apply_mla(cfg, p["mixer"], x, mode=mode, cache=c_mix,
                            pos_t=pos_t)
    elif spec.mixer == "mamba":
        x, nc = S.apply_mamba(cfg, p["mixer"], x, mode=mode, cache=c_mix,
                              pos_t=pos_t)
    elif spec.mixer == "rglru":
        x, nc = R.apply_rglru(cfg, p["mixer"], x, mode=mode, cache=c_mix,
                              pos_t=pos_t)
    if nc is not None:
        new_cache["mixer"] = nc
    if spec.cross_attn:
        c_cross = cache.get("cross") if cache else None
        cmode = mode if mode != "decode" else "decode"
        x, ncc = A.apply_attn(cfg, p["cross"], x, window=0, causal=False,
                              mode=cmode, cache=c_cross, pos_t=pos_t,
                              enc_out=enc_out, cross=True)
        if ncc is not None and mode == "prefill":
            new_cache["cross"] = ncc
        elif mode == "decode":
            new_cache["cross"] = c_cross
    if spec.ffn == "dense":
        x = L.apply_ffn(cfg, p["ffn"], x)
    elif spec.ffn == "moe":
        x, aux = M.apply_moe(cfg, p["moe"], x)
    x = constrain(x, ("batch", "seq_act", None))
    if cfg.cotangent_dtype and mode == "train":
        # pin the residual-stream cotangent dtype at every layer boundary:
        # without this the f32 score/CE dots leak f32 activation gradients
        # (and f32 sequence-parallel collectives) through the whole stack
        x = cotangent_cast(x, jnp.dtype(cfg.cotangent_dtype))
    return x, (new_cache if new_cache else None), aux


# ---------------------------------------------------------------------------
# group scan
# ---------------------------------------------------------------------------

def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots_with_no_batch_dims":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


def run_blocks(cfg: ModelConfig, blocks_params, x, *, mode, caches=None,
               pos_t=None, enc_out=None, block_cfgs=None):
    """Run all (pattern, repeats) groups.  Returns (x, new_caches, aux)."""
    block_cfgs = block_cfgs or cfg.blocks
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    cdt = jnp.dtype(cfg.compute_dtype)
    for gi, (pattern, reps) in enumerate(block_cfgs):
        gp = blocks_params[gi]
        if cfg.bf16_param_stack and mode == "train":
            # hoist the param cast out of the scan: per-layer weight loads
            # AND the stacked-gradient accumulation/reduction run in the
            # compute dtype (the f32 master copy converts once per group)
            gp = jax.tree_util.tree_map(
                lambda w: w.astype(cdt)
                if jnp.issubdtype(w.dtype, jnp.floating) else w, gp)
        gc = caches[gi] if caches is not None else None

        if mode == "train":
            def body(x, p_g, _pattern=pattern):
                aux = jnp.zeros((), jnp.float32)
                for i, spec in enumerate(_pattern):
                    x, _, a = apply_layer(cfg, spec, p_g[f"l{i}"], x,
                                          mode="train", cache=None,
                                          pos_t=None, enc_out=enc_out)
                    aux = aux + a
                return x, aux

            body_r = _remat(cfg, body)
            x, auxs = jax.lax.scan(lambda c, p_g: body_r(c, p_g), x, gp)
            aux_total = aux_total + auxs.sum()
            new_caches.append(None)
        elif mode == "prefill":
            def body_p(x, p_g, _pattern=pattern):
                ncs = {}
                for i, spec in enumerate(_pattern):
                    x, nc, _ = apply_layer(cfg, spec, p_g[f"l{i}"], x,
                                           mode="prefill", cache=None,
                                           pos_t=None, enc_out=enc_out)
                    ncs[f"l{i}"] = nc
                return x, ncs

            x, ncs = jax.lax.scan(body_p, x, gp)
            new_caches.append(ncs)
        else:  # decode
            def body_d(x, xs, _pattern=pattern):
                p_g, c_g = xs
                ncs = {}
                for i, spec in enumerate(_pattern):
                    x, nc, _ = apply_layer(cfg, spec, p_g[f"l{i}"], x,
                                           mode="decode", cache=c_g[f"l{i}"],
                                           pos_t=pos_t, enc_out=enc_out)
                    ncs[f"l{i}"] = nc
                return x, ncs

            x, ncs = jax.lax.scan(body_d, x, (gp, gc))
            new_caches.append(ncs)
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------

def run_encoder(cfg: ModelConfig, params, enc_feats):
    cdt = jnp.dtype(cfg.compute_dtype)
    B, F, d = enc_feats.shape
    x = enc_feats.astype(cdt) + L.sinusoidal_pos(
        d, jnp.arange(F))[None].astype(cdt)
    x = constrain(x, ("batch", "seq_act", None))
    enc_blocks = (((ENC_SPEC,), cfg.n_enc_layers),)
    x, _, _ = run_blocks(cfg, params["encoder"]["blocks"], x, mode="train",
                         block_cfgs=enc_blocks)
    return L.apply_norm(cfg, params["encoder"]["final_norm"], x)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def cotangent_cast(x, dtype):
    """Identity whose cotangent is cast to `dtype` — a dtype barrier that
    stops the f32 CE-loss cotangent from propagating f32 activation grads
    (and f32 sequence-parallel collectives) through the whole stack."""
    return x


def _ct_fwd(x, dtype):
    return x, None


def _ct_bwd(dtype, _, ct):
    return (ct.astype(dtype),)


cotangent_cast.defvjp(_ct_fwd, _ct_bwd)


def embed_tokens(cfg: ModelConfig, params, tokens, pos_offset=0):
    x = L.apply_embed(cfg, params, tokens)
    if cfg.pos_embed == "sinusoidal":
        pos = pos_offset + jnp.arange(tokens.shape[1])
        x = x + L.sinusoidal_pos(cfg.d_model, pos)[None].astype(x.dtype)
    return constrain(x, ("batch", "seq_act", None))


def chunked_ce_loss(cfg: ModelConfig, params, x, labels):
    """Sequence-chunked cross-entropy; chunk body rematted so full logits are
    never resident."""
    B, Snum, d = x.shape
    table = L.unembed_table(cfg, params)
    V, Vp = cfg.vocab, cfg.vocab_padded
    ch = min(cfg.loss_chunk, Snum)
    while Snum % ch:
        ch -= 1
    nch = Snum // ch
    xc = jnp.moveaxis(x.reshape(B, nch, ch, d), 1, 0)
    yc = jnp.moveaxis(labels.reshape(B, nch, ch), 1, 0)
    ldt = jnp.dtype(cfg.logits_dtype)

    def chunk_fn(x_c, y_c):
        logits = jnp.einsum("bsd,vd->bsv", x_c, table.astype(x_c.dtype),
                            preferred_element_type=ldt)
        if Vp > V:
            logits = jnp.where(jnp.arange(Vp)[None, None] < V, logits,
                               jnp.asarray(-1e30, ldt))
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label log-prob via masked reduction (vocab-shard friendly: fuses
        # into one pass over logits, no cross-shard gather)
        onehot = jnp.arange(Vp)[None, None] == y_c[..., None]
        ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        return (lse - ll).sum()

    chunk_fn = jax.checkpoint(chunk_fn,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def body(tot, xs):
        x_c, y_c = xs
        return tot + chunk_fn(x_c, y_c), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    return total / (B * Snum)


def forward_train(cfg: ModelConfig, params, batch):
    """-> (loss, metrics)."""
    tokens = batch["tokens"]
    enc_out = None
    if cfg.enc_dec:
        enc_out = run_encoder(cfg, params, batch["enc_feats"])
    x = embed_tokens(cfg, params, tokens)
    x, _, aux = run_blocks(cfg, params["blocks"], x, mode="train",
                           enc_out=enc_out)
    x = L.apply_norm(cfg, params["final_norm"], x)
    if cfg.cotangent_dtype:
        x = cotangent_cast(x, jnp.dtype(cfg.cotangent_dtype))
    ce = chunked_ce_loss(cfg, params, x, batch["labels"])
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


def prefill(cfg: ModelConfig, params, tokens, enc_feats=None):
    """-> (last_token_logits, caches)."""
    enc_out = None
    if cfg.enc_dec:
        enc_out = run_encoder(cfg, params, enc_feats)
    x = embed_tokens(cfg, params, tokens)
    x, caches, _ = run_blocks(cfg, params["blocks"], x, mode="prefill",
                              enc_out=enc_out)
    x = L.apply_norm(cfg, params["final_norm"], x)
    last = x[:, -1:]
    table = L.unembed_table(cfg, params)
    logits = jnp.einsum("bsd,vd->bsv", last, table.astype(last.dtype),
                        preferred_element_type=jnp.float32)
    return logits[:, :, :cfg.vocab], caches


def decode_step(cfg: ModelConfig, params, caches, tokens, pos_t):
    """tokens: (B, 1); pos_t: scalar int — returns (logits, new_caches)."""
    x = embed_tokens(cfg, params, tokens, pos_offset=pos_t)
    x, new_caches, _ = run_blocks(cfg, params["blocks"], x, mode="decode",
                                  caches=caches, pos_t=pos_t)
    x = L.apply_norm(cfg, params["final_norm"], x)
    table = L.unembed_table(cfg, params)
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits[:, :, :cfg.vocab], new_caches


def init_cache(cfg: ModelConfig, batch: int, seq: int):
    """Zero-initialized cache (ring-buffer position slots marked invalid)."""
    from repro.models.params import tree_map_desc

    descs = build_cache_descriptors(cfg, batch, seq)

    def mk(d: ParamDesc):
        if d.dtype == jnp.int32:
            return jnp.full(d.shape, -1, jnp.int32)
        return jnp.zeros(d.shape, d.dtype)

    return [tree_map_desc(mk, g) for g in descs]
