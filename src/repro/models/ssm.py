"""Mamba-1 selective-state-space block (falcon-mamba-7b).

The selective scan h_t = exp(Δ_t A)·h_{t-1} + Δ_t·B_t·x_t is evaluated as a
*chunked* linear recurrence: `lax.scan` over time chunks carrying the state,
with a log-depth `associative_scan` inside each chunk — the (chunk, D, N)
intermediate is the only expanded tensor, so the working set is
O(chunk·d_inner·d_state) instead of O(seq·d_inner·d_state).
`repro.kernels.mamba_scan` is the Pallas/TPU tiling of the same math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamDesc
from repro.models import layers as L


def mamba_descs(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    din = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    N, K = s.d_state, s.d_conv
    return {
        "norm": L.norm_descs(cfg),
        "in_proj": ParamDesc((d, 2 * din), ("embed", "inner")),
        "conv_w": ParamDesc((K, din), (None, "inner")),
        "conv_b": ParamDesc((din,), ("inner",), init="zeros"),
        "x_proj": ParamDesc((din, dtr + 2 * N), ("inner", None)),
        "dt_proj": ParamDesc((dtr, din), (None, "inner")),
        "dt_bias": ParamDesc((din,), ("inner",), init="zeros"),
        "A_log": ParamDesc((din, N), ("inner", "state"), init="ones"),
        "D": ParamDesc((din,), ("inner",), init="ones"),
        "out_proj": ParamDesc((din, d), ("inner", "embed")),
    }


def mamba_cache_descs(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    return {
        "state": ParamDesc((batch, din, s.d_state), ("batch", "inner", None),
                           dtype=jnp.float32),
        "conv": ParamDesc((batch, s.d_conv - 1, din), ("batch", None, "inner"),
                          dtype=jnp.dtype(cfg.compute_dtype)),
    }


def _causal_conv(x, w, b, tail=None):
    """x: (B, S, D); w: (K, D) depthwise causal conv; tail: (B, K-1, D)."""
    K = w.shape[0]
    pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype) if tail is None \
        else tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return out + b[None, None], xp[:, -(K - 1):] if K > 1 else None


def selective_scan(u, dt, A, Bm, Cm, *, chunk: int, h0=None):
    """u, dt: (B, S, D); A: (D, N); Bm, Cm: (B, S, N).  Returns (y, h_final).

    y_t = C_t · h_t,  h_t = exp(dt_t·A)·h_{t-1} + dt_t·B_t·u_t
    """
    B, S, D = u.shape
    N = A.shape[1]
    nc = max(1, S // chunk)
    while S % nc:
        nc -= 1
    ch = S // nc
    h0 = jnp.zeros((B, D, N), jnp.float32) if h0 is None else h0

    uc = u.reshape(B, nc, ch, D)
    dtc = dt.reshape(B, nc, ch, D)
    Bc = Bm.reshape(B, nc, ch, N)
    Cc = Cm.reshape(B, nc, ch, N)

    def chunk_step(h, xs):
        u_, dt_, B_, C_ = xs  # (B, ch, D), (B, ch, D), (B, ch, N), (B, ch, N)
        dA = jnp.exp(dt_.astype(jnp.float32)[..., None] * A[None, None])  # (B,ch,D,N)
        dBu = (dt_.astype(jnp.float32) * u_.astype(jnp.float32))[..., None] \
            * B_.astype(jnp.float32)[..., None, :]                         # (B,ch,D,N)

        def combine(a, b):
            a1, b1 = a
            a2, b2 = b
            return a1 * a2, a2 * b1 + b2

        accA, accB = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
        hs = accA * h[:, None] + accB                                      # (B,ch,D,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, C_.astype(jnp.float32))
        return hs[:, -1], y

    h_fin, ys = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(uc, 1, 0), jnp.moveaxis(dtc, 1, 0),
         jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
    return y, h_fin


def apply_mamba(cfg: ModelConfig, p, x, *, mode="train", cache=None, pos_t=None):
    """Returns (out, new_cache)."""
    s = cfg.ssm
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    din = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    N = s.d_state
    h = L.apply_norm(cfg, p["norm"], x)

    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(cdt))
    xz = constrain(xz, ("batch", None, "inner"))
    xin, z = xz[..., :din], xz[..., din:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if mode in ("train", "prefill"):
        conv_tail = None
        xc, tail = _causal_conv(xin, p["conv_w"].astype(cdt),
                                p["conv_b"].astype(cdt))
        xc = jax.nn.silu(xc)
        proj = jnp.einsum("bsd,de->bse", xc, p["x_proj"].astype(cdt))
        dt_r, Bm, Cm = proj[..., :dtr], proj[..., dtr:dtr + N], proj[..., dtr + N:]
        dt = jax.nn.softplus(
            jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"].astype(cdt))
            + p["dt_bias"].astype(cdt))
        if cfg.use_pallas:
            from repro.kernels import ops as kops
            y, h_fin = kops.mamba_scan(xc, dt, A, Bm, Cm, chunk=s.chunk)
        else:
            y, h_fin = selective_scan(xc, dt, A, Bm, Cm, chunk=s.chunk)
        y = y.astype(cdt) + xc * p["D"].astype(cdt)
        out = jnp.einsum("bsd,de->bse", y * jax.nn.silu(z),
                         p["out_proj"].astype(cdt))
        new_cache = None
        if mode == "prefill":
            new_cache = {"state": h_fin, "conv": tail if tail is not None else
                         jnp.zeros((B, s.d_conv - 1, din), cdt)}
        return x + out, new_cache

    # ---- decode: single step ----
    assert cache is not None
    tail = cache["conv"]  # (B, K-1, din)
    window = jnp.concatenate([tail.astype(cdt), xin], axis=1)  # (B, K, din)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(cdt)) \
        + p["conv_b"].astype(cdt)
    xc = jax.nn.silu(xc)[:, None]  # (B, 1, din)
    proj = jnp.einsum("bsd,de->bse", xc, p["x_proj"].astype(cdt))
    dt_r, Bm, Cm = proj[..., :dtr], proj[..., dtr:dtr + N], proj[..., dtr + N:]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"].astype(cdt))
        + p["dt_bias"].astype(cdt))
    dA = jnp.exp(dt.astype(jnp.float32)[:, 0, :, None] * A[None])  # (B, D, N)
    dBu = (dt.astype(jnp.float32) * xc.astype(jnp.float32))[:, 0, :, None] \
        * Bm.astype(jnp.float32)[:, 0, None, :]               # (B, D, N)
    h_new = dA * cache["state"] + dBu
    y = jnp.einsum("bdn,bn->bd", h_new, Cm.astype(jnp.float32)[:, 0])[:, None]
    y = y.astype(cdt) + xc * p["D"].astype(cdt)
    out = jnp.einsum("bsd,de->bse", y * jax.nn.silu(z),
                     p["out_proj"].astype(cdt))
    new_tail = jnp.concatenate([tail[:, 1:], xin.astype(tail.dtype)], axis=1)
    return x + out, {"state": h_new, "conv": new_tail}
