"""Durable job store (DESIGN.md §15) — sqlite persistence + task state machine.

The engine is an in-memory object; a crash loses every in-flight graph.
This module adds the diracx-shaped durability layer that turns it into a
workflow *system*:

  * `TaskStateMachine` — the explicit per-task status automaton
    (``submitted -> ready -> dispatched -> done|failed|revoked``); illegal
    transitions raise `IllegalTransition`.  Pure in-memory, no I/O — the
    property-testable core.
  * `Journal` — the clock-thread recorder the engine's lifecycle hooks
    call.  It validates every transition through the state machine,
    buffers rows locally, and hands them to the store in batches so the
    per-task hot-path cost is a few dict probes plus one amortized lock
    acquisition per batch.
  * `JobStore` — sqlite tables plus a flat append-only write-ahead log
    (``<db>.log``) owned by a background writer thread.  The *log* is
    the durability hot path: each drain serializes queued batches with
    one ``json.dumps`` per batch and lands them in one ``os.write``, so
    a SIGKILL loses at most the un-flushed tail.  The sqlite tables are
    a *checkpoint* of the log, folded in at natural barriers —
    `load`/`journal_rows`/`close`/crash recovery — never during a run.
    This split is what keeps journaling inside the 5% tracing-overhead
    CI gate on a single core: per-row sqlite work (bind/step/upsert)
    costs ~3 us/row of GIL-holding time that a one-CPU host pays
    directly out of the run wall, while the log append costs ~0.5
    us/row of C-speed serialization.

Durability modes: ``durability="terminal"`` (default) records only
terminal rows (done/failed — what recovery needs); the fold writes them
into the tasks upsert alone.  ``durability="full"`` records every
transition and the fold additionally feeds the append-only journal
table for audit/forensics.

Recovery contract: `JobStore.load(wf_id)` folds the tasks table into a
resume view — a key is *restorable* iff it is durably ``done`` with a
decodable value whose `PhysicalRef`s still exist (same rule as
`RestartLog`); everything else is frontier and re-runs.  Journal rows
carry a per-workflow ``run_id`` so each attempt's transition sequence
replays consistently on its own (see `tests/test_jobstore.py`).
"""
from __future__ import annotations

import json
import os
import sqlite3
import threading
import time
from typing import Any, Iterable

from repro.core.restart_log import decode_value, encode_value, physical_refs

__all__ = [
    "SUBMITTED", "READY", "DISPATCHED", "DONE", "FAILED", "REVOKED",
    "STATUS_NAMES", "TERMINAL", "IllegalTransition", "TaskStateMachine",
    "Journal", "JobStore", "WorkflowState",
]

# status codes — small ints so the hot path compares by identity and the
# sqlite rows stay compact
SUBMITTED, READY, DISPATCHED, DONE, FAILED, REVOKED = range(6)
STATUS_NAMES = ("submitted", "ready", "dispatched", "done", "failed",
                "revoked")
TERMINAL = frozenset((DONE, FAILED))

# current status -> admissible next statuses.  `None` is "never seen".
# Self-loops for SUBMITTED/READY are *idempotent no-ops*, not errors:
# identical (name, args) pairs share a content-derived key, and a stolen
# task re-entering dispatch on the thief shard re-records READY.
_NEXT = {
    None: frozenset((SUBMITTED,)),
    SUBMITTED: frozenset((READY, FAILED)),
    READY: frozenset((DISPATCHED, FAILED)),
    DISPATCHED: frozenset((DONE, FAILED, REVOKED, READY)),
    REVOKED: frozenset((READY,)),
    DONE: frozenset(),
    FAILED: frozenset(),
}
_IDEMPOTENT = frozenset((SUBMITTED, READY))


class IllegalTransition(ValueError):
    """A recorded status change the state machine does not admit."""

    def __init__(self, key: str, cur: int | None, new: int):
        self.key, self.cur, self.new = key, cur, new
        super().__init__(
            f"illegal transition for {key!r}: "
            f"{STATUS_NAMES[cur] if cur is not None else '<new>'} -> "
            f"{STATUS_NAMES[new]}")


class TaskStateMachine:
    """Per-task status automaton; pure in-memory, no I/O.

    ``advance(key, status)`` returns True when the state changed, False
    for an idempotent re-record (duplicate submit of a shared key,
    ready-after-steal), and raises `IllegalTransition` otherwise.

    Example::

        sm = TaskStateMachine()
        sm.advance("k", SUBMITTED); sm.advance("k", READY)
        sm.advance("k", DISPATCHED); sm.advance("k", DONE)
        sm.advance("k", READY)   # raises IllegalTransition (done is final)
    """

    __slots__ = ("state", "duplicates")

    def __init__(self, seed: dict[str, int] | None = None):
        self.state: dict[str, int] = dict(seed) if seed else {}
        self.duplicates = 0

    def advance(self, key: str, status: int) -> bool:
        cur = self.state.get(key)
        if cur == status:
            if status in _IDEMPOTENT:
                self.duplicates += 1
                return False
            raise IllegalTransition(key, cur, status)
        if status not in _NEXT[cur]:
            raise IllegalTransition(key, cur, status)
        self.state[key] = status
        return True

    def counts(self) -> dict[str, int]:
        out = dict.fromkeys(STATUS_NAMES, 0)
        for s in self.state.values():
            out[STATUS_NAMES[s]] += 1
        return out

    def frontier(self) -> list[str]:
        """Keys not in a terminal state — what a resume must re-run."""
        return [k for k, s in self.state.items() if s not in TERMINAL]


class Journal:
    """Clock-thread transition recorder feeding a `JobStore`.

    Created via `JobStore.journal()` and attached as ``engine.journal``;
    the engine's lifecycle hooks call the ``task_*`` methods (clock
    thread only — same single-writer contract as the tracer).  Rows
    buffer locally and flush to the store every `batch` records; callers
    owning a natural barrier (end of run, workflow sealed) should call
    `flush()` so the tail is not stranded until close.
    """

    __slots__ = ("store", "sm", "_batch", "full", "_local", "rows_queued",
                 "flushes", "tracer", "clock", "default_wf", "_occ")

    def __init__(self, store: "JobStore", batch: int = 64,
                 durability: str = "terminal", tracer=None, clock=None,
                 default_wf: str = ""):
        if durability not in ("terminal", "full"):
            raise ValueError(f"durability must be terminal|full, "
                             f"got {durability!r}")
        self.store = store
        self.default_wf = default_wf
        self.sm = TaskStateMachine()
        self._batch = batch
        self.full = durability == "full"
        self._local: list = []
        self.rows_queued = 0
        self.flushes = 0
        self.tracer = tracer
        self.clock = clock
        self._occ: dict[str, int] = {}

    def unique_key(self, base: str) -> str:
        """Disambiguate a content-derived key: the store's primary key is
        (wf, key), so two live submissions of the same (name, args) must
        not share a row.  Occurrence order is submission order, which a
        deterministic program reproduces on resume, so the n-th duplicate
        maps to the same durable row across runs."""
        occ = self._occ
        n = occ.get(base)
        if n is None:
            occ[base] = 1
            return base
        occ[base] = n + 1
        return f"{base}~{n}"

    # -- engine lifecycle hooks (clock thread only) --------------------
    # Terminal durability is the throughput mode (the <=5% gate in
    # benchmarks/observability.py): the engine skips the non-terminal
    # hooks entirely (gated on `self.full`) and the terminal hooks skip
    # the state machine, leaving one tuple-append per completion on the
    # hot path.  Full durability runs every hook through `sm.advance`,
    # so illegal transitions are rejected at the source; terminal-mode
    # journals get the same enforcement at replay (`JobStore.load`).
    def task_submitted(self, key: str) -> None:
        if self.sm.advance(key, SUBMITTED) and self.full:
            self._add(key, SUBMITTED, None, None)

    def task_ready(self, key: str) -> None:
        if self.sm.advance(key, READY) and self.full:
            self._add(key, READY, None, None)

    def task_dispatched(self, key: str) -> None:
        if self.sm.advance(key, DISPATCHED) and self.full:
            self._add(key, DISPATCHED, None, None)

    def task_revoked(self, key: str) -> None:
        if self.sm.advance(key, REVOKED) and self.full:
            self._add(key, REVOKED, None, None)

    def task_done(self, key: str, value: Any) -> None:
        if self.full:
            self.sm.advance(key, DONE)
        self._add(key, DONE, value, None)

    def task_failed(self, key: str, error: str) -> None:
        if self.full:
            self.sm.advance(key, FAILED)
        self._add(key, FAILED, None, str(error))

    # ------------------------------------------------------------------
    def _add(self, key: str, status: int, value, error) -> None:
        self._local.append((key, status, value, error))
        if len(self._local) >= self._batch:
            self.flush()

    def flush(self) -> None:
        """Hand the local buffer to the store's writer queue (one lock)."""
        rows = self._local
        if not rows:
            return
        self._local = []
        self.rows_queued += len(rows)
        self.flushes += 1
        self.store.enqueue_rows(rows, self.default_wf, full=self.full)
        tr = self.tracer
        if tr is not None and self.clock is not None:
            tr.event("journal_flush", self.clock.now(), float(len(rows)))


class WorkflowState:
    """Folded durable state of one workflow, as `JobStore.load` returns it.

    ``done`` maps task key -> decoded value for every durably completed
    task whose value survived encoding and whose `PhysicalRef`s still
    exist; ``failed`` maps key -> error string; ``counts`` tallies rows
    per status name; ``run_id`` is the attempt counter recorded so far.
    """

    __slots__ = ("wf_id", "done", "failed", "counts", "run_id")

    def __init__(self, wf_id: str, done: dict, failed: dict,
                 counts: dict, run_id: int):
        self.wf_id, self.done, self.failed = wf_id, done, failed
        self.counts, self.run_id = counts, run_id


_SCHEMA = """
CREATE TABLE IF NOT EXISTS workflows(
    wf_id TEXT PRIMARY KEY, name TEXT, status TEXT DEFAULT 'running',
    runs INTEGER DEFAULT 0, created_wall REAL, updated_wall REAL);
CREATE TABLE IF NOT EXISTS tasks(
    wf_id TEXT NOT NULL, key TEXT NOT NULL, run_id INTEGER,
    status INTEGER NOT NULL, value TEXT, error TEXT, wall REAL,
    PRIMARY KEY (wf_id, key)) WITHOUT ROWID;
CREATE TABLE IF NOT EXISTS journal(
    seq INTEGER PRIMARY KEY AUTOINCREMENT, wf_id TEXT, run_id INTEGER,
    key TEXT, status INTEGER, value TEXT, error TEXT, wall REAL);
CREATE INDEX IF NOT EXISTS journal_wf ON journal(wf_id, seq);
"""

# tasks upsert: the materialized latest-status row.  A done-with-value row
# is never demoted by a non-terminal row (a changed program resubmitting a
# completed key must not erase its durable value); everything else — new
# runs re-running failed or value-less keys included — overwrites.
_UPSERT = """
INSERT INTO tasks(wf_id, key, run_id, status, value, error, wall)
VALUES(?, ?, ?, ?, ?, ?, ?)
ON CONFLICT(wf_id, key) DO UPDATE SET
    run_id=excluded.run_id, status=excluded.status, value=excluded.value,
    error=excluded.error, wall=excluded.wall
WHERE NOT (tasks.status = 3 AND tasks.value IS NOT NULL
           AND excluded.status NOT IN (3, 4))
"""


def _encode_op(op) -> str:
    """One write-ahead-log line for a queued op.  The fast path is a
    single ``json.dumps`` of the whole batch with raw values; batches
    holding non-JSON values (PhysicalRefs, arbitrary objects) fall back
    to per-row encoding, where a value that even `encode_value` cannot
    make durable is dropped and the row grows a 5th element as the
    marker (folded as value-less: the task re-runs on resume).  Raw and
    encoded rows fold identically because `encode_value` is identity on
    JSON round-tripped data."""
    kind, payload, wall = op
    if kind == "wf":
        return json.dumps(["w", wall, payload[0], payload[1]])
    rows, default_wf, full = payload
    flag = 1 if full else 0
    try:
        return json.dumps(["r", wall, default_wf, flag, rows])
    except (TypeError, ValueError):
        safe = []
        for key, status, value, error in rows:
            try:
                enc = encode_value(value)
                json.dumps(enc)
                safe.append([key, status, enc, error])
            except (TypeError, ValueError):
                safe.append([key, status, None, error, 0])
        return json.dumps(["r", wall, default_wf, flag, safe])


def _read_log(path: str) -> list:
    """Parse a write-ahead log back into the writer-queue op shape.
    Stops at the first unparsable line (a torn tail from an OS-level
    crash; SIGKILL cannot tear a single ``os.write``)."""
    ops: list = []
    try:
        with open(path, "r", encoding="utf-8") as f:
            data = f.read()
    except OSError:
        return ops
    for line in data.splitlines():
        if not line:
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            break
        if rec[0] == "r":
            ops.append(("rows", (rec[4], rec[2], bool(rec[3])), rec[1]))
        else:
            ops.append(("wf", (rec[2], rec[3]), rec[1]))
    return ops


class JobStore:
    """Persistent job store: append-only log hot path + sqlite checkpoint.

    All writes are batched off the hot path: `Journal.flush` appends row
    batches to an in-memory queue under a plain lock; a daemon writer
    thread drains the queue every wakeup (`flush_interval` seconds, or
    sooner past `flush_max` queued rows) into the write-ahead log file
    ``<path>.log`` — one JSON line per batch, one ``os.write`` per
    drain.  Drained batches also stay queued in writer memory and are
    folded into the sqlite tables only at barriers (`checkpoint`, which
    `load`/`journal_rows` call, and `close`); a fresh `JobStore` over a
    database whose owner was SIGKILLed replays the surviving log tail
    into sqlite before serving reads.  An in-memory store
    (``":memory:"``) has no log file and folds each drain directly.

    Example::

        store = JobStore("run.db")
        eng = Engine(clock)
        eng.journal = store.journal(default_wf="demo")
        ... run ...
        eng.journal.flush(); store.sync()   # log-durable past here
        state = store.load("demo")      # -> WorkflowState(done={...})
    """

    def __init__(self, path: str, flush_interval: float = 0.05,
                 flush_max: int = 4096):
        self.path = path
        self.flush_interval = flush_interval
        self.flush_max = flush_max
        self._conn = sqlite3.connect(path, check_same_thread=False,
                                     timeout=10.0)
        self._dblock = threading.Lock()
        with self._dblock:
            self._conn.execute("PRAGMA journal_mode=WAL")
            self._conn.execute("PRAGMA synchronous=NORMAL")
            self._conn.executescript(_SCHEMA)
            self._conn.commit()
        self._run_ids: dict[str, int] = {}
        self._qlock = threading.Lock()
        self._cv = threading.Condition(self._qlock)
        self._queue: list = []          # ("rows", batch, wall) | ("wf", ...)
        self._pending: list = []        # logged, not yet folded (writer only)
        self._enqueued = 0
        self._committed = 0
        self._closed = False
        self._ckpt_req = False
        self._ckpt_gen = 0
        self._wake = threading.Event()
        self.batches_committed = 0
        self._log_path = None if path == ":memory:" else path + ".log"
        self._log_fd = None
        if self._log_path is not None:
            self._recover_log()
            self._log_fd = os.open(self._log_path,
                                   os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                                   0o644)
        self._writer = threading.Thread(target=self._writer_loop,
                                        name="jobstore-writer", daemon=True)
        self._writer.start()

    def _recover_log(self) -> None:
        """Crash recovery: fold a leftover log tail from a previous owner
        into sqlite, then truncate it.  Runs before the writer starts."""
        ops = _read_log(self._log_path)
        if ops:
            self._fold_ops(ops)
        if os.path.exists(self._log_path):
            with open(self._log_path, "w"):
                pass

    # -- workflow registry ---------------------------------------------
    def begin_run(self, wf_id: str, name: str | None = None) -> int:
        """Register (or re-open) a workflow and bump its attempt counter."""
        wall = time.time()
        with self._dblock:
            self._conn.execute(
                "INSERT INTO workflows(wf_id, name, status, runs, "
                "created_wall, updated_wall) VALUES(?, ?, 'running', 1, ?, ?) "
                "ON CONFLICT(wf_id) DO UPDATE SET runs=workflows.runs+1, "
                "status='running', updated_wall=excluded.updated_wall",
                (wf_id, name or wf_id, wall, wall))
            self._conn.commit()
            run_id = self._conn.execute(
                "SELECT runs FROM workflows WHERE wf_id=?",
                (wf_id,)).fetchone()[0]
        with self._qlock:
            self._run_ids[wf_id] = run_id
        return run_id

    def journal(self, batch: int = 64, durability: str = "terminal",
                default_wf: str = "", tracer=None, clock=None) -> Journal:
        """Create a `Journal` feeding this store (see class docstring).

        ``default_wf`` names the workflow for keys without a ``wf::``
        prefix; it is registered via `begin_run` on first use here.
        """
        if default_wf not in self._run_ids:
            self.begin_run(default_wf)
        return Journal(self, batch=batch, durability=durability,
                       tracer=tracer, clock=clock, default_wf=default_wf)

    # -- writer queue ---------------------------------------------------
    def enqueue_rows(self, rows: list, default_wf: str = "",
                     full: bool = False) -> None:
        """Queue a batch of (key, status, value, error) rows (any thread).
        Keys without a ``wf::`` prefix are attributed to `default_wf`.
        ``full`` batches additionally land in the append-only journal
        table (audit trail) when folded; terminal batches fold only into
        the tasks upsert."""
        with self._qlock:
            if self._closed:
                raise RuntimeError("JobStore is closed")
            self._queue.append(("rows", (rows, default_wf, full),
                                time.time()))
            self._enqueued += len(rows)
            backlog = self._enqueued - self._committed
        if backlog >= self.flush_max:
            self._wake.set()

    def set_workflow_status(self, wf_id: str, status: str) -> None:
        """Queue a workflow status change ('running'|'done'|'failed')."""
        with self._qlock:
            if self._closed:
                raise RuntimeError("JobStore is closed")
            self._queue.append(("wf", (wf_id, status), time.time()))
            self._enqueued += 1   # counts as one op for sync() accounting

    def _writer_loop(self) -> None:
        while True:
            self._wake.wait(self.flush_interval)
            self._wake.clear()
            self._flush_once()
            with self._qlock:
                ckpt = self._ckpt_req
                done = self._closed and not self._queue
            if ckpt or done:
                self._checkpoint_writer()
            if done:
                if self._log_fd is not None:
                    os.close(self._log_fd)
                    self._log_fd = None
                    try:
                        os.unlink(self._log_path)
                    except OSError:
                        pass
                break

    def _flush_once(self) -> None:
        """Drain the queue: append every op to the log (one JSON line per
        op, one ``os.write`` for the drain) and stash it for the next
        fold.  This is the only work the writer does while a run is hot —
        per-row sqlite cost would come straight out of the run wall on a
        single-core host (the writer shares the GIL and the CPU with the
        clock thread)."""
        with self._qlock:
            if not self._queue:
                return
            ops, self._queue = self._queue, []
        n_rows = 0
        if self._log_fd is not None:
            lines = []
            for op in ops:
                kind, payload, _wall = op
                n_rows += len(payload[0]) if kind == "rows" else 1
                lines.append(_encode_op(op))
            os.write(self._log_fd, ("\n".join(lines) + "\n").encode())
            self._pending.extend(ops)
        else:                           # :memory: — no log, fold directly
            for kind, payload, _wall in ops:
                n_rows += len(payload[0]) if kind == "rows" else 1
            self._fold_ops(ops)
        with self._qlock:
            self._committed += n_rows
            self.batches_committed += 1
            self._cv.notify_all()

    def _checkpoint_writer(self) -> None:
        """Writer-thread half of `checkpoint`: fold everything logged so
        far into sqlite and truncate the log.  Single-threaded with the
        log/pending state by construction."""
        ops, self._pending = self._pending, []
        if ops:
            self._fold_ops(ops)
        if self._log_fd is not None:
            os.ftruncate(self._log_fd, 0)
        with self._qlock:
            self._ckpt_req = False
            self._ckpt_gen += 1
            self._cv.notify_all()

    def _fold_ops(self, ops: list) -> None:
        """Fold queued/logged ops into the sqlite tables (one transaction).
        Ops carry either raw in-process values or their JSON round-trips
        from a recovered log; `encode_value` is identity on the latter, so
        both encode to the same durable text."""
        with self._qlock:
            overlay = dict(self._run_ids)
        with self._dblock:
            run_ids = dict(self._conn.execute(
                "SELECT wf_id, runs FROM workflows").fetchall())
        run_ids.update(overlay)
        task_rows, journal_rows, wf_rows = [], [], []
        get_run = run_ids.get
        dumps = json.dumps
        for kind, payload, wall in ops:
            if kind == "wf":
                wf_rows.append((payload[1], wall, payload[0]))
                continue
            rows, default_wf, full = payload
            for row in rows:
                key, status, value, error = row[0], row[1], row[2], row[3]
                wf_id, sep, _ = key.partition("::")
                if not sep:
                    wf_id = default_wf
                enc = None
                # len(row) == 5 marks a value dropped at log time as
                # non-serializable: persist value-less, re-run on resume
                if status == DONE and len(row) == 4:
                    if value is None:
                        enc = "null"
                    elif type(value) in (int, float, str, bool):
                        enc = dumps(value)
                    else:
                        try:
                            enc = dumps(encode_value(value))
                        except (TypeError, ValueError):
                            enc = None  # non-durable value: re-run on resume
                task_rows.append((wf_id, key, get_run(wf_id, 0), status,
                                  enc, error, wall))
            if full:
                journal_rows.extend(task_rows[-len(rows):])
        with self._dblock:
            cur = self._conn.cursor()
            if journal_rows:
                cur.executemany(
                    "INSERT INTO journal(wf_id, key, run_id, status, value, "
                    "error, wall) VALUES(?, ?, ?, ?, ?, ?, ?)", journal_rows)
            if task_rows:
                cur.executemany(_UPSERT, task_rows)
            for status, wall, wf_id in wf_rows:
                cur.execute(
                    "UPDATE workflows SET status=?, updated_wall=? "
                    "WHERE wf_id=?", (status, wall, wf_id))
            self._conn.commit()

    def sync(self, timeout: float = 30.0) -> None:
        """Block until every op enqueued so far is durable — appended to
        the write-ahead log (or folded into sqlite for an in-memory
        store).  A SIGKILL after `sync` returns loses nothing."""
        deadline = time.monotonic() + timeout
        self._wake.set()
        with self._qlock:
            target = self._enqueued
            while self._committed < target:
                if time.monotonic() > deadline:
                    raise TimeoutError("JobStore.sync timed out")
                self._cv.wait(0.05)
                self._wake.set()

    def checkpoint(self, timeout: float = 60.0) -> None:
        """Fold everything enqueued so far into the sqlite tables and
        truncate the log.  `load` and `journal_rows` call this so reads
        always see a folded view; during a run nothing calls it — the
        log alone carries durability until a barrier."""
        with self._qlock:
            if self._closed:
                return                  # close() already folded everything
        self.sync(timeout)
        deadline = time.monotonic() + timeout
        with self._qlock:
            gen = self._ckpt_gen
            self._ckpt_req = True
        self._wake.set()
        with self._qlock:
            while self._ckpt_gen == gen:
                if time.monotonic() > deadline:
                    raise TimeoutError("JobStore.checkpoint timed out")
                self._cv.wait(0.05)
                self._wake.set()

    def close(self) -> None:
        """Flush and fold everything, stop the writer thread, remove the
        (now redundant) log, and close the connection — the sqlite
        database alone is the complete durable state afterwards."""
        with self._qlock:
            if self._closed:
                return
            self._closed = True
        self._wake.set()
        self._writer.join(timeout=30.0)
        with self._dblock:
            self._conn.commit()
            self._conn.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- recovery / inspection -----------------------------------------
    def load(self, wf_id: str) -> WorkflowState:
        """Fold the durable tasks table into a resume view (see module
        docstring for the restorability rule).  Checkpoints first so the
        view includes everything log-durable at the time of the call."""
        self.checkpoint()
        with self._dblock:
            rows = self._conn.execute(
                "SELECT key, status, value, error FROM tasks WHERE wf_id=?",
                (wf_id,)).fetchall()
            wf = self._conn.execute(
                "SELECT runs FROM workflows WHERE wf_id=?",
                (wf_id,)).fetchone()
        done, failed = {}, {}
        counts = dict.fromkeys(STATUS_NAMES, 0)
        for key, status, value, error in rows:
            counts[STATUS_NAMES[status]] += 1
            if status == DONE and value is not None:
                decoded = decode_value(json.loads(value))
                if all(r.exists() for r in physical_refs(decoded)):
                    done[key] = decoded
            elif status == FAILED:
                failed[key] = error or ""
        return WorkflowState(wf_id, done, failed, counts,
                             wf[0] if wf else 0)

    def import_restart_log(self, log, wf_id: str = "") -> int:
        """Seed the store from an existing `RestartLog` (migration path:
        recovery replays the restart log *and* the journal).  Returns the
        number of imported entries."""
        wall = time.time()
        if wf_id not in self._run_ids:
            self.begin_run(wf_id)
        run_id = self._run_ids[wf_id]
        prefix = f"{wf_id}::" if wf_id else ""
        n = 0
        with self._dblock:
            for key, value in log.items():
                try:
                    enc = json.dumps(encode_value(value))
                except (TypeError, ValueError):
                    continue
                self._conn.execute(_UPSERT, (wf_id, prefix + key, run_id,
                                             DONE, enc, None, wall))
                n += 1
            self._conn.commit()
        return n

    def journal_rows(self, wf_id: str, run_id: int | None = None) -> list:
        """The append-only journal for a workflow (optionally one run),
        in sequence order, as (run_id, key, status) tuples.  Only
        ``durability="full"`` journals feed this table; terminal-mode
        durable state lives in the tasks upsert alone."""
        self.checkpoint()
        q = ("SELECT run_id, key, status FROM journal WHERE wf_id=? "
             "ORDER BY seq")
        args: tuple = (wf_id,)
        if run_id is not None:
            q = ("SELECT run_id, key, status FROM journal "
                 "WHERE wf_id=? AND run_id=? ORDER BY seq")
            args = (wf_id, run_id)
        with self._dblock:
            return self._conn.execute(q, args).fetchall()

    @staticmethod
    def peek(path: str, wf_id: str = "") -> dict[str, int]:
        """Read-only progress poll usable from *another process* while the
        owning process is live (WAL readers never block the writer, and
        log readers just scan a flat file).  Returns durable per-status
        counts for `wf_id`: the folded sqlite tables plus each key's
        last status in the un-folded log tail.  Exact for terminal
        statuses (a key's done/failed row lands exactly once across the
        two sources); a full-durability key whose early transitions were
        checkpointed while later ones sit in the log is counted in both
        sources' non-terminal buckets, so those are an estimate."""
        conn = sqlite3.connect(f"file:{path}?mode=ro", uri=True, timeout=5.0)
        try:
            rows = conn.execute(
                "SELECT status, COUNT(*) FROM tasks WHERE wf_id=? "
                "GROUP BY status", (wf_id,)).fetchall()
        finally:
            conn.close()
        out = dict.fromkeys(STATUS_NAMES, 0)
        for status, n in rows:
            out[STATUS_NAMES[status]] = n
        last: dict[str, int] = {}
        for kind, payload, _wall in _read_log(path + ".log"):
            if kind != "rows":
                continue
            batch, default_wf, _full = payload
            for row in batch:
                key = row[0]
                wf, sep, _ = key.partition("::")
                if (wf if sep else default_wf) == wf_id:
                    last[key] = row[1]
        for status in last.values():
            out[STATUS_NAMES[status]] += 1
        return out
