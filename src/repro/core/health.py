"""Online health observability: rolling windows, straggler detection, and
error-rate-driven site drain (DESIGN.md §13).

PR 7's `Tracer`/`RunReport` explain a run *after* it ends; this module
watches it *while* it executes and feeds what it sees back into placement —
the closed loop behind the paper's "reliable" claim (§3.12: route around
bad resources while 10^5-10^6 tasks are in flight).  Three pieces:

  * `RollingStat`    — a time-windowed ring of buckets over the `Clock`.
                       Windowing is pure epoch arithmetic on caller-passed
                       timestamps (``epoch = int(t / bucket_s)``) — no wall
                       reads, no RNG — so the same workflow under `SimClock`
                       produces byte-identical windowed rates on every
                       replay, and the identical code runs under `RealClock`.
  * `HealthMonitor`  — subscribes to engine task completions (dispatch /
                       finish hooks), Falkon executor completions, and the
                       `Tracer.event()` stream, and derives per-site health
                       states (``healthy -> degraded -> drained ->
                       blacklisted``, probe-based recovery), straggler
                       flags (running > k x rolling-p95 for the task's
                       vmap signature/app), and backpressure watermarks.
  * feedback         — state changes actuate through existing seams:
                       `Site.suspended_until` (drain/blacklist; the
                       balancer and the federation stealer already skip
                       suspended sites), `Site.derate` (degraded sites
                       keep serving but at reduced weight), and
                       `FalkonService.drain_queued` (revoke queued tasks
                       from a drained service so the engine re-places them
                       on healthy sites without charging retries).

The monitor also emits a periodic JSONL metrics stream (schema
``repro.metrics_stream/v1``): one line per cadence with per-site health,
windowed rates, queue depths, and — when a `MetricsRegistry` is attached —
the full component snapshot.  `tools/live_monitor.py` tails it;
`tools/trace_view.py validate` checks it.

Hot-path contract (same as the tracer's): with no monitor attached every
engine/service hook is a single ``is not None`` test.  With one attached, a
successful completion costs one counter decrement plus, for one in
`duration_stride` completions, a sampled turnaround update — it never
touches the straggler registry (resolved entries are pruned lazily), and
the windowed error accounting itself runs *off* the completion path, on a
bucket-cadence tick that folds `Site.stats` counter deltas (already
maintained by the engine) into the rolling windows and runs the state
machine.  The tick is self-disarming: it arms on dispatch activity and
stops when the watched engines go idle, so a `SimClock.run()` still
terminates.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Any, Callable, Optional

from repro.core.metrics import percentile_of

__all__ = ["RollingStat", "HealthConfig", "HealthMonitor",
           "METRICS_STREAM_SCHEMA"]

# JSONL metrics-stream schema tag; every emitted line carries it and
# `tools/trace_view.py validate` rejects lines without it.
METRICS_STREAM_SCHEMA = "repro.metrics_stream/v1"


class RollingStat:
    """Time-windowed (count, total, samples) over a ring of buckets.

    Observations land in the bucket ``int(t / bucket_s)``; a query at time
    `now` first expires every bucket older than the window, then reduces
    over the survivors — O(buckets) per query, O(1) amortized per observe.
    Timestamps come from the caller's clock (virtual under `SimClock`, wall
    under `RealClock`); the structure itself never reads a clock and uses
    no RNG, so replays are exact.

    With ``keep_samples > 0`` each bucket additionally keeps its first k
    observed values, enabling windowed percentiles (`percentile`) — the
    straggler detector's rolling p95 lives on this.

    Example::

        rs = RollingStat(window=30.0, buckets=10)
        rs.observe(t, 1.0 if failed else 0.0)     # per completion
        err = rs.mean(now)                        # windowed error rate
        thr = rs.rate(now)                        # events per second
    """

    __slots__ = ("window", "buckets", "bucket_s", "keep_samples",
                 "_ring", "_head")

    def __init__(self, window: float = 30.0, buckets: int = 10,
                 keep_samples: int = 0):
        if window <= 0.0:
            raise ValueError("window must be > 0")
        if buckets < 1:
            raise ValueError("buckets must be >= 1")
        self.window = float(window)
        self.buckets = buckets
        self.bucket_s = self.window / buckets
        self.keep_samples = keep_samples
        # ring entry: [count, total, samples-or-None], indexed epoch % n
        self._ring = [[0, 0.0, None] for _ in range(buckets)]
        self._head: Optional[int] = None    # newest epoch seen

    def _advance(self, t: float) -> None:
        """Expire buckets between the last-seen epoch and `t`'s epoch."""
        e = int(t / self.bucket_s)
        head = self._head
        if head is None:
            self._head = e
            return
        if e <= head:
            return
        n = self.buckets
        if e - head >= n:
            for b in self._ring:
                b[0] = 0
                b[1] = 0.0
                b[2] = None
        else:
            ring = self._ring
            for k in range(head + 1, e + 1):
                b = ring[k % n]
                b[0] = 0
                b[1] = 0.0
                b[2] = None
        self._head = e

    def observe(self, t: float, v: float = 1.0) -> None:
        """Record one observation at clock time `t` with value `v`."""
        self._advance(t)
        e = int(t / self.bucket_s)
        if self._head - e >= self.buckets:
            return                      # older than the whole window
        b = self._ring[e % self.buckets]
        b[0] += 1
        b[1] += v
        if self.keep_samples:
            s = b[2]
            if s is None:
                b[2] = s = []
            if len(s) < self.keep_samples:
                s.append(v)

    # -- windowed queries (all expire stale buckets first) --------------
    def count(self, now: float) -> int:
        """Observations inside the window ending at `now`."""
        self._advance(now)
        return sum(b[0] for b in self._ring)

    def total(self, now: float) -> float:
        """Sum of observed values inside the window."""
        self._advance(now)
        return sum(b[1] for b in self._ring)

    def mean(self, now: float) -> float:
        """Windowed mean value — the windowed *rate* for 0/1 indicators
        (e.g. error fraction when observing 1.0 per failure)."""
        self._advance(now)
        c = t = 0.0
        for b in self._ring:
            c += b[0]
            t += b[1]
        return t / c if c else 0.0

    def rate(self, now: float) -> float:
        """Observations per second over the window."""
        return self.count(now) / self.window

    def value_rate(self, now: float) -> float:
        """Value sum per second over the window (e.g. bytes/s)."""
        return self.total(now) / self.window

    def percentile(self, q: float, now: float) -> float:
        """Windowed q-quantile of kept samples (0.0 when none kept;
        requires ``keep_samples > 0`` to be meaningful)."""
        self._advance(now)
        vals: list = []
        for b in self._ring:
            s = b[2]
            if s:
                vals.extend(s)
        vals.sort()
        return percentile_of(vals, q)

    def observe_bulk(self, t: float, count: int, total: float) -> None:
        """Fold `count` observations summing to `total` into the bucket at
        time `t` in one call — the counter-delta path (the `HealthMonitor`
        tick aggregates a whole bucket's completions at once instead of
        paying one `observe` per task).  Kept samples are not updated."""
        if count <= 0:
            return
        self._advance(t)
        e = int(t / self.bucket_s)
        if self._head - e >= self.buckets:
            return
        b = self._ring[e % self.buckets]
        b[0] += count
        b[1] += total

    def snapshot(self, now: float) -> dict:
        """JSON-able windowed summary."""
        self._advance(now)
        c = sum(b[0] for b in self._ring)
        t = sum(b[1] for b in self._ring)
        return {"window_s": self.window, "count": c, "total": t,
                "mean": t / c if c else 0.0,
                "rate_per_s": c / self.window}

    def __repr__(self):
        return (f"<RollingStat window={self.window}s "
                f"buckets={self.buckets}>")


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Thresholds and cadences for the `HealthMonitor` state machine.

    Error-rate thresholds are windowed per-attempt failure fractions over
    `window` seconds (clock time), evaluated only once `min_samples`
    completions are in the window.  `degrade_*` softens a site's balancer
    weight; `drain_*`/`blacklist_*` suspend it outright via the
    `Site.suspended_until` seam (backoffs escalate by `backoff_factor` per
    consecutive failed probe).  Recovery is probe-based: when a suspension
    lapses, traffic flows again and the next window of fresh samples either
    recovers the site (error <= `recover_error_rate`) or re-drains it.
    """

    window: float = 30.0            # rolling window (clock seconds)
    buckets: int = 10               # ring granularity
    min_samples: int = 8            # completions before thresholds engage
    degrade_error_rate: float = 0.10
    drain_error_rate: float = 0.25
    blacklist_error_rate: float = 0.45
    recover_error_rate: float = 0.10
    degrade_derate: float = 0.5     # balancer weight multiplier when degraded
    drain_backoff: float = 60.0     # first drain suspension (probe delay)
    backoff_factor: float = 2.0     # escalation per consecutive re-drain
    blacklist_backoff: float = 600.0
    blacklist_after_drains: int = 3  # failed probes before blacklisting
    revoke_on_drain: bool = True    # hand queued tasks back on drain
    # straggler detection: a task in flight longer than
    # max(straggler_min_s, straggler_factor x rolling p95 turnaround for
    # its vmap signature / (app, name)) is flagged once
    straggler_factor: float = 3.0
    straggler_min_s: float = 1.0
    straggler_interval: float = 5.0     # scan cadence; <= 0 disables
    # in-flight tracking bound: dispatches past this many tracked tasks
    # are not registered for straggler detection until the registry
    # drains.  Providers accept work far beyond executor capacity
    # (queued internally), so an unbounded registry would mirror the
    # whole backlog — megabytes of cache-hostile state on a saturated
    # run — to watch tasks that are mostly queue-waiting anyway.  The
    # registry is a dispatch-ordered deque that completions never touch
    # (the §13 hot-path contract): resolved entries drain from its head
    # during scans, O(1) amortized per admitted task.  Small runs
    # (tests, the recovery benchmark) sit far below the cap and are
    # tracked exhaustively; error windows are exact regardless.
    straggler_track_cap: int = 8192
    duration_window: float = 120.0      # turnaround stats window
    duration_samples: int = 32          # kept samples per bucket
    # turnaround sampling stride: only every k-th successful completion
    # pays for percentile-reservoir updates (the tracer's span-sampling
    # idea, strided wider because reservoirs need less data than spans);
    # error windows are exact regardless — they come from Site.stats
    # counter deltas, not from sampling
    duration_stride: int = 32
    # per-executor drain (Falkon hosts): None disables
    executor_drain_error_rate: Optional[float] = None
    executor_min_samples: int = 6
    executor_backoff: float = 120.0
    # backpressure watermarks: ready backlog vs pool capacity
    queue_high_watermark: float = 2.0
    queue_low_watermark: float = 0.5
    emit_interval: float = 5.0          # JSONL cadence when a sink attached


class _SiteHealth:
    """Per-site monitor state (internal)."""

    __slots__ = ("site", "state", "outcomes", "latency", "lat_ewma",
                 "consecutive_drains", "stragglers", "revoked",
                 "seen_completed", "seen_failed", "last_fail_t")

    def __init__(self, site, cfg: HealthConfig):
        self.site = site
        self.state = "healthy"
        # fed by counter deltas each tick: count = windowed attempts,
        # total = windowed failed attempts
        self.outcomes = RollingStat(cfg.window, cfg.buckets)
        self.latency = RollingStat(cfg.duration_window, cfg.buckets,
                                   keep_samples=cfg.duration_samples)
        self.lat_ewma = 0.0
        self.consecutive_drains = 0
        self.stragglers = 0
        self.revoked = 0
        # high-water marks of Site.stats at the last tick (delta base)
        self.seen_completed = site.stats.completed
        self.seen_failed = site.stats.failed
        # last tick that folded a failure — lets the tick skip the state
        # machine exactly (windowed err is 0) on healthy, failure-free
        # sites
        self.last_fail_t = float("-inf")


class HealthMonitor:
    """Closed-loop run health: rolling per-site signals -> placement.

    Wire-up (the engine/service hooks stay single-``is not None``-test
    cheap when no monitor is attached)::

        hm = HealthMonitor(clock, tracer=tracer, registry=registry)
        hm.watch(engine)            # or a FederatedEngine
        hm.watch_service(svc)       # per-executor signals + drain_queued
        hm.attach_sink("run.jsonl") # periodic metrics-stream emission
        ... run ...
        hm.states()                 # {"site0": "healthy", ...}
        hm.transitions              # state-change log (deterministic
                                    # under SimClock)

    State machine per site: ``healthy -> degraded`` (windowed error rate
    over `degrade_error_rate`: the site keeps serving at `degrade_derate`
    balancer weight), ``-> drained`` (over `drain_error_rate`: suspended
    for `drain_backoff`, queued tasks optionally revoked back to the
    engine), ``-> blacklisted`` (over `blacklist_error_rate`, or repeated
    failed probes: long suspension).  Recovery is probe-based — a lapsed
    suspension lets traffic flow; a clean fresh window transitions back to
    healthy, a dirty one re-drains with escalated backoff.
    """

    def __init__(self, clock, config: HealthConfig | None = None,
                 tracer=None, registry=None,
                 on_straggler: Callable | None = None):
        self.clock = clock
        self.cfg = config or HealthConfig()
        self.tracer = tracer
        self.registry = registry
        # re-dispatch hint: called as on_straggler(task, in_flight_s,
        # threshold_s) when a straggler is flagged
        self.on_straggler = on_straggler
        self._sites: dict[str, _SiteHealth] = {}
        self._engines: list = []
        self._services: list = []
        # straggler registry: tasks in dispatch order, appended at
        # `_place` while under `straggler_track_cap`, never touched by
        # completions — resolved entries drain from the head during
        # scans (§13 hot-path contract)
        self._running: deque = deque()
        self._flagged: set[int] = set()        # straggler-flagged task ids
        # turnaround stats per vmap signature / (app, name), shared across
        # sites; bounded key cardinality (workflow-level)
        self._durations: dict = {}
        self._dur_skip = 0
        self._exec_stats: dict = {}            # (svc, eid) -> RollingStat
        self.transitions: list[dict] = []      # exact state-change log
        self.straggler_log: deque = deque(maxlen=256)
        self.stragglers_flagged = 0
        self.tasks_revoked = 0
        self.executors_drained = 0
        self.lines_emitted = 0
        # single cadence driver: one clock event per bucket interval runs
        # counter-delta accounting + the state machine, and on their own
        # due-times the straggler scan / stream emission.  The interval
        # adapts: while every site is healthy and completions are sparse
        # the tick stretches (doubling, capped at one window) so its
        # cost stays a bounded fraction of completion volume; any failure
        # delta or non-healthy site snaps it back to bucket resolution.
        # Worst-case detection latency for the *first* failure burst is
        # one stretched interval (<= window) — busy or failing runs
        # always tick at full resolution.
        self._tick_s = self.cfg.window / self.cfg.buckets
        self._tick_cur = self._tick_s
        self._tick_max = max(self._tick_s, self.cfg.window)
        self._stretch_min = 32      # completions/tick below which to stretch
        self._next_scan = 0.0
        self._next_emit = 0.0
        self._emit_interval = self.cfg.emit_interval
        # straggler-scan threshold cache: per-key flag thresholds and
        # their minimum (the floor), recomputed at most once per duration
        # bucket — the percentile sorts run at bucket cadence, not scan
        # cadence.  The O(1) head-age-vs-floor pre-check skips the whole
        # scan when nothing can possibly be flagged.
        self._thresholds: dict = {}
        self._thr_floor = 0.0
        self._thr_at = float("-inf")
        self._thr_refresh = self.cfg.duration_window / self.cfg.buckets
        self._armed = False
        self._stride = max(1, self.cfg.duration_stride)
        self._track_cap = max(0, self.cfg.straggler_track_cap)
        self._bp_high = False
        self._sink = None
        self._own_sink = False
        if tracer is not None and hasattr(tracer, "subscribe"):
            # component-event stream (satellite of the same loop): fold
            # alert-worthy kinds into windowed rates for the snapshots
            tracer.subscribe(self._on_event)
        self._alerts: dict[str, RollingStat] = {}

    # -- wiring ---------------------------------------------------------
    def watch(self, target):
        """Attach to an `Engine` or `FederatedEngine` (all shards).
        Returns the target for chaining."""
        shards = getattr(target, "shards", None)
        if shards is not None and hasattr(target, "mailboxes"):
            target.health = self
            for eng in shards:
                self.watch(eng)
            return target
        target.health = self
        self._engines.append(target)
        return target

    def watch_service(self, svc):
        """Attach to a `FalkonService`: enables queue-depth readings for
        its site and — when `executor_drain_error_rate` is configured —
        per-executor windowed error tracking.  The service-side completion
        hook is only installed when executor tracking is on, so the common
        site-level-only configuration adds zero service hot-path cost."""
        if self.cfg.executor_drain_error_rate is not None:
            svc.health = self
        self._services.append(svc)
        return svc

    def attach_sink(self, sink, interval: float | None = None) -> None:
        """Emit the JSONL metrics stream (``repro.metrics_stream/v1``) to
        `sink` — a path or a file-like object — every `emit_interval`
        clock seconds while the watched engines have work in flight."""
        if isinstance(sink, str):
            sink = open(sink, "w", encoding="utf-8")
            self._own_sink = True
        self._sink = sink
        if interval is not None:
            self._emit_interval = float(interval)
        self._next_emit = 0.0

    def close(self) -> None:
        """Flush and close an owned sink (no-op for caller-owned files)."""
        if self._sink is not None and self._own_sink:
            self._sink.close()
            self._sink = None

    # -- hooks (engine / service hot path) ------------------------------
    # The engine inlines the bodies of `task_dispatched` / `task_finished`
    # directly in `_place` / `_done` (same idiom as its inlined
    # Tracer.task_done) — a bound-method call per task would alone eat
    # half the 5% overhead budget.  These methods are the reference
    # implementation and the path for other drivers.

    def arm(self) -> None:
        """Start the tick cadence (idempotent; called on the first
        dispatch after an idle period)."""
        if not self._armed:
            self._armed = True
            self._tick_cur = self._tick_s
            self.clock.schedule(self._tick_s, self._tick)

    def task_dispatched(self, task, now: float) -> None:
        """Engine `_place` hook: the task was handed to a site.  Hot-path
        cost: arming the tick cadence when idle, plus one deque append
        while the registry is under `straggler_track_cap`."""
        if not self._armed:
            self.arm()
        r = self._running
        if len(r) < self._track_cap:
            r.append(task)

    def task_finished(self, task, site, ok: bool, now: float) -> None:
        """Engine `_done` hook: one attempt finished (success or failure,
        but not drain revocation — see `task_revoked`).  Neither outcome
        touches the straggler registry: a resolved entry drains from the
        deque head during scans, and a retried task's entry tracks the
        live object (its `submit_time` is re-stamped on re-placement).
        A success pays the sampling stride counter; every
        `duration_stride`-th success samples its turnaround into the
        percentile reservoirs (`sample_turnaround`).  Error windows are
        NOT updated here — the tick derives them exactly from
        `Site.stats` counter deltas."""
        if ok:
            if self._dur_skip:
                self._dur_skip -= 1
            else:
                self.sample_turnaround(task, site, now)

    def sample_turnaround(self, task, site, now: float) -> None:
        """The 1-in-`duration_stride` sampled completion: feed the site
        EWMA / windowed latency percentiles and the per-signature
        turnaround reservoirs behind straggler thresholds."""
        self._dur_skip = self._stride - 1
        turnaround = now - task.submit_time
        sh = self._sites.get(site.name)
        if sh is None:
            sh = self._site_state(site)
        # EWMA over the *sampled* turnarounds — the cheap latency
        # signal next to the windowed percentiles
        sh.lat_ewma = (turnaround if sh.lat_ewma == 0.0
                       else 0.8 * sh.lat_ewma + 0.2 * turnaround)
        sh.latency.observe(now, turnaround)
        key = task.vmap_key
        if key is None:
            key = (task.app, task.name)
        rs = self._durations.get(key)
        if rs is None and len(self._durations) < 512:
            # bounded key cardinality: past the cap, per-key
            # duration stats stop growing (site stats still update)
            self._durations[key] = rs = RollingStat(
                self.cfg.duration_window, self.cfg.buckets,
                keep_samples=self.cfg.duration_samples)
        if rs is not None:
            rs.observe(now, turnaround)

    def task_revoked(self, task) -> None:
        """Engine hook for drain revocations: administrative requeue, not
        a site failure — no error-window charge.  The registry entry (if
        any) stays: it tracks the live task object, whose `submit_time`
        is re-stamped when the engine re-places it."""
        self.tasks_revoked += 1

    # -- the cadence driver ---------------------------------------------
    def _tick(self) -> None:
        """One cadence interval: fold `Site.stats` deltas into the rolling
        windows, run the state machine, and — when due — the straggler
        scan and the stream emission.  Self-disarming: stops rescheduling
        once the watched engines go idle (re-armed by the next dispatch),
        so `SimClock.run()` terminates."""
        now = self.clock.now()
        window = self.cfg.window
        quiet = True
        volume = 0
        for eng in self._engines:
            for site in eng.balancer.sites:
                sh = self._sites.get(site.name)
                if sh is None:
                    sh = self._site_state(site)
                stats = site.stats
                done, failed = stats.completed, stats.failed
                d_fail = failed - sh.seen_failed
                d_all = (done - sh.seen_completed) + d_fail
                if d_all:
                    volume += d_all
                    if d_fail:
                        sh.last_fail_t = now
                    sh.outcomes.observe_bulk(now, d_all, float(d_fail))
                    sh.seen_completed = done
                    sh.seen_failed = failed
                state = sh.state
                if state in ("drained", "blacklisted"):
                    # a suspended site is not re-judged on its stale
                    # window: every tick would otherwise count as one
                    # more failed probe and escalate the backoff with no
                    # probe traffic having flowed.  The first tick after
                    # the suspension lapses judges the probe (fresh
                    # samples — plus window leftovers when the backoff
                    # is shorter than the window).
                    if now >= site.suspended_until:
                        self._evaluate(sh, now)
                elif d_all:
                    # a healthy site with no failure inside the window has
                    # windowed err == 0 exactly — the state machine cannot
                    # move it, so skip the windowed queries
                    if (state != "healthy"
                            or now - sh.last_fail_t <= window):
                        self._evaluate(sh, now)
                elif state != "healthy":
                    # degraded with no fresh completions: still let the
                    # window be judged once its samples expire
                    self._evaluate(sh, now)
                if d_fail or state != "healthy":
                    quiet = False
        if now >= self._next_scan and self.cfg.straggler_interval > 0.0:
            self._next_scan = now + self.cfg.straggler_interval
            self._scan(now)       # may push _next_scan further out
        if self._sink is not None and now >= self._next_emit:
            self._next_emit = now + self._emit_interval
            self.emit_line(now)
        if self._active():
            # normalize volume to completions per *bucket* interval so a
            # stretched tick doesn't un-stretch itself just by covering
            # more time
            if quiet and volume * self._tick_s < (self._stretch_min
                                                  * self._tick_cur):
                self._tick_cur = min(self._tick_cur * 2.0, self._tick_max)
            else:
                self._tick_cur = self._tick_s
            self.clock.schedule(self._tick_cur, self._tick)
        else:
            self._armed = False
            if self._running:
                # idle: everything left is resolved residue — release the
                # task references (§9 GC contract)
                self._running.clear()
                self._flagged.clear()

    def on_executor(self, svc, executor, ok: bool, now: float) -> None:
        """Falkon `_complete` hook: per-executor windowed error tracking;
        drains (suspends) individual executors whose windowed error rate
        crosses `executor_drain_error_rate` (None disables)."""
        thr = self.cfg.executor_drain_error_rate
        if thr is None:
            return
        key = (svc.name, executor.id)
        rs = self._exec_stats.get(key)
        if rs is None:
            self._exec_stats[key] = rs = RollingStat(self.cfg.window,
                                                     self.cfg.buckets)
        rs.observe(now, 0.0 if ok else 1.0)
        if (not ok and now >= executor.suspended_until
                and rs.count(now) >= self.cfg.executor_min_samples
                and rs.mean(now) >= thr):
            executor.suspended_until = now + self.cfg.executor_backoff
            self.executors_drained += 1
            if self.tracer is not None:
                self.tracer.event("executor_drained", now)

    def _on_event(self, kind: str, t: float, value: float) -> None:
        """Tracer event-stream subscriber: windowed rates for alert-worthy
        component events (pool worker errors land here on the real path,
        where failures are seen by the pool before the engine)."""
        if kind != "worker_error":
            return
        rs = self._alerts.get(kind)
        if rs is None:
            self._alerts[kind] = rs = RollingStat(self.cfg.window,
                                                  self.cfg.buckets)
        rs.observe(t, value)

    # -- state machine --------------------------------------------------
    def _site_state(self, site) -> _SiteHealth:
        sh = self._sites.get(site.name)
        if sh is None:
            self._sites[site.name] = sh = _SiteHealth(site, self.cfg)
        return sh

    def _evaluate(self, sh: _SiteHealth, now: float) -> None:
        cfg = self.cfg
        n = sh.outcomes.count(now)
        if n < cfg.min_samples:
            return
        err = sh.outcomes.total(now) / n
        site = sh.site
        state = sh.state
        if state in ("drained", "blacklisted"):
            # only reached once the suspension has lapsed (the tick skips
            # suspended sites): the samples are fresh post-probe traffic,
            # plus pre-drain leftovers when the backoff is shorter than
            # the window — those age out within one window of the probe
            if err <= cfg.recover_error_rate:
                sh.consecutive_drains = 0
                site.derate = 1.0
                self._transition(sh, now, "healthy",
                                 f"probe ok err={err:.3f} n={n}")
            elif err >= cfg.drain_error_rate:
                sh.consecutive_drains += 1
                to = ("blacklisted" if state == "blacklisted"
                      or err >= cfg.blacklist_error_rate
                      or sh.consecutive_drains >= cfg.blacklist_after_drains
                      else "drained")
                self._suspend(sh, now, to, err, n)
            return
        if err >= cfg.blacklist_error_rate:
            sh.consecutive_drains += 1
            self._suspend(sh, now, "blacklisted", err, n)
        elif err >= cfg.drain_error_rate:
            sh.consecutive_drains += 1
            self._suspend(sh, now, "drained", err, n)
        elif err >= cfg.degrade_error_rate:
            if state != "degraded":
                site.derate = cfg.degrade_derate
                self._transition(sh, now, "degraded",
                                 f"err={err:.3f} n={n}")
        elif state == "degraded":
            site.derate = 1.0
            self._transition(sh, now, "healthy", f"err={err:.3f} n={n}")

    def _suspend(self, sh: _SiteHealth, now: float, to_state: str,
                 err: float, n: int) -> None:
        cfg = self.cfg
        site = sh.site
        if to_state == "blacklisted":
            backoff = cfg.blacklist_backoff
        else:
            backoff = (cfg.drain_backoff
                       * cfg.backoff_factor ** max(
                           0, sh.consecutive_drains - 1))
        # never shrink an existing suspension; the balancer and the
        # federation stealer both already skip suspended sites
        site.suspended_until = max(site.suspended_until, now + backoff)
        site.derate = 1.0
        revoked = 0
        if cfg.revoke_on_drain:
            svc = getattr(site.provider, "service", None)
            if svc is not None and hasattr(svc, "drain_queued"):
                revoked = svc.drain_queued()
                sh.revoked += revoked
        self._transition(sh, now, to_state,
                         f"err={err:.3f} n={n} backoff={backoff:g}"
                         + (f" revoked={revoked}" if revoked else ""))
        # when the suspension lapses (the probe), held tasks must be able
        # to flow again even if no completion occurs to trigger a drain
        # pass — and if *every* site is suspended the engine would
        # otherwise deadlock on its pending queue
        for eng in self._engines:
            self.clock.schedule(backoff + 1e-9, eng.poke)

    def _transition(self, sh: _SiteHealth, now: float, to_state: str,
                    reason: str) -> None:
        rec = {"t": round(now, 9), "site": sh.site.name,
               "from": sh.state, "to": to_state, "reason": reason}
        sh.state = to_state
        sh.site.health_state = to_state
        self.transitions.append(rec)
        if self.tracer is not None:
            self.tracer.event(f"health_{to_state}", now)

    # -- straggler scan (tick sub-cadence) ------------------------------
    def _scan(self, now: float) -> None:
        cfg = self.cfg
        running = self._running
        if not running:
            return
        # Drain resolved entries off the head: completions never touch
        # the registry (§13 hot-path contract), so each admitted task is
        # popped here exactly once — O(1) amortized per admission.  The
        # deque is in dispatch order (`submit_time` is stamped at
        # `_place`), so after the drain the head region holds the oldest
        # live tasks; a retried task's entry stays mid-deque tracking
        # the live object with its re-stamped (younger) submit time.
        flagged = self._flagged
        while running:
            task = running[0]
            if not task.output.resolved:
                break
            running.popleft()
            if flagged:
                flagged.discard(task.id)
        # Cheap pre-check: the first live unflagged entry is the oldest
        # candidate — if even it is younger than the smallest cached
        # threshold, nothing can be flagged and the scan skips entirely.
        head_age = None
        for task in running:
            if task.output.resolved or task.id in flagged:
                continue
            head_age = now - task.submit_time
            break
        if head_age is None:
            return
        slack = self._thr_floor - head_age
        if slack > 0.0:
            # nothing can be flagged before the oldest candidate's age
            # reaches the cached floor (ages grow at 1 s/s; every other
            # task is younger) — push the next scan out to that horizon,
            # capped so a shrinking p95 is picked up within one duration
            # window.  On a healthy run successive scans space out
            # geometrically instead of paying the walk at tick cadence.
            ns = now + min(slack, cfg.duration_window)
            if ns > self._next_scan:
                self._next_scan = ns
            return
        # Recompute per-key thresholds only on demand — when the oldest
        # candidate has outgrown the cached floor.  The floor goes stale
        # only downward-late (a shrinking p95 delays a flag until the
        # task's age crosses the old floor — ages grow monotonically, so
        # no flag is ever lost).  With no key at `min_samples` yet the
        # recompute is sort-free and retried at duration-bucket cadence.
        self._thr_at = now
        thresholds = self._thresholds = {}
        min_thr = None
        for key, rs in self._durations.items():
            if rs.count(now) < cfg.min_samples:
                continue
            thr = max(cfg.straggler_min_s,
                      cfg.straggler_factor * rs.percentile(0.95, now))
            thresholds[key] = thr
            if min_thr is None or thr < min_thr:
                min_thr = thr
        # no key has enough samples yet -> 0.0 keeps the pre-check open
        self._thr_floor = min_thr if min_thr is not None else 0.0
        if not thresholds:
            ns = now + max(cfg.straggler_interval, self._thr_refresh)
            if ns > self._next_scan:
                self._next_scan = ns
            return
        unknown = 0
        for task in running:
            if task.output.resolved:
                continue    # mid-deque stale; drains once it reaches head
            in_flight = now - task.submit_time
            if in_flight <= min_thr:
                break
            tid = task.id
            if tid in flagged:
                continue
            key = task.vmap_key
            if key is None:
                key = (task.app, task.name)
            threshold = thresholds.get(key)
            if threshold is None:
                # this key can't flag until it accumulates samples; a
                # long prefix of such tasks (a cold fan-out waiting in a
                # provider queue) must not turn the scan O(running) —
                # bail and retry next scan, when the prefix has either
                # completed or earned a threshold
                unknown += 1
                if unknown > 64:
                    break
                continue
            if in_flight <= threshold:
                continue
            self._flagged.add(tid)
            self.stragglers_flagged += 1
            site = task.site
            if site is not None:
                self._site_state(site).stragglers += 1
            self.straggler_log.append(
                (now, task.name, site.name if site else "", in_flight,
                 threshold))
            if self.tracer is not None:
                self.tracer.event("straggler", now,
                                  in_flight - threshold)
            if self.on_straggler is not None:
                # re-dispatch hint: the callback may cancel/clone the
                # task; the monitor itself only flags
                self.on_straggler(task, in_flight, threshold)

    # -- metrics stream --------------------------------------------------
    def _active(self) -> bool:
        # engine counters, not the registry: resolved entries linger in
        # `_running` until drained and must not keep the tick alive.
        # Summed across shards, not tested per shard — a stolen task
        # completes on the thief, leaving the victim's own inflight()
        # positive and the thief's negative forever (they only balance
        # in aggregate), and a per-shard test would keep ticking an idle
        # federation.
        return sum(eng.inflight() for eng in self._engines) > 0

    def emit_line(self, now: float | None = None) -> dict:
        """Append one metrics-stream line to the sink (and return it)."""
        if now is None:
            now = self.clock.now()
        self._check_watermarks(now)
        line = self.snapshot_line(now)
        if self._sink is not None:
            self._sink.write(json.dumps(line, sort_keys=True) + "\n")
            flush = getattr(self._sink, "flush", None)
            if flush is not None:
                flush()
            self.lines_emitted += 1
        return line

    def _check_watermarks(self, now: float) -> None:
        cap = sum(e.pool_capacity() for e in self._engines)
        if cap <= 0 or self.tracer is None:
            return
        backlog = sum(e.ready_backlog() for e in self._engines)
        if not self._bp_high:
            if backlog > self.cfg.queue_high_watermark * cap:
                self._bp_high = True
                self.tracer.event("backpressure_high", now, backlog)
        elif backlog < self.cfg.queue_low_watermark * cap:
            self._bp_high = False
            self.tracer.event("backpressure_low", now, backlog)

    def _site_entry(self, sh: _SiteHealth, now: float) -> dict:
        site = sh.site
        o = sh.outcomes
        n = o.count(now)
        errs = o.total(now)
        svc = getattr(site.provider, "service", None)
        queue = (len(svc.queue) + svc._parked) if svc is not None \
            and hasattr(svc, "queue") else 0
        return {
            "state": sh.state,
            "error_rate": errs / n if n else 0.0,
            "window_completions": n,
            "tasks_per_s": (n - errs) / o.window,
            "latency_ewma_s": sh.lat_ewma,
            "latency_p95_s": sh.latency.percentile(0.95, now),
            "outstanding": site.outstanding,
            "capacity": site.capacity,
            "utilization": (site.outstanding / site.capacity
                            if site.capacity else 0.0),
            "queue": queue,
            "stragglers": sh.stragglers,
            "revoked": sh.revoked,
            "suspended_for_s": max(0.0, site.suspended_until - now),
        }

    def snapshot_line(self, now: float | None = None) -> dict:
        """One metrics-stream record: per-site health + engine backlog +
        tracer windowed event rates + registry component snapshot."""
        if now is None:
            now = self.clock.now()
        line = {
            "schema": METRICS_STREAM_SCHEMA,
            "t": now,
            "sites": {name: self._site_entry(sh, now)
                      for name, sh in sorted(self._sites.items())},
            "backlog": sum(e.ready_backlog() for e in self._engines),
            "inflight": sum(e.inflight() for e in self._engines),
            # tracked registry size (may exceed live in-flight between
            # prunes; bounded by straggler_track_cap)
            "tracked": len(self._running),
            "stragglers": self.stragglers_flagged,
            "revoked": self.tasks_revoked,
            "transitions": len(self.transitions),
        }
        if self._alerts:
            line["alerts"] = {k: rs.snapshot(now)
                              for k, rs in sorted(self._alerts.items())}
        if self.tracer is not None and hasattr(self.tracer, "event_rates"):
            line["events"] = self.tracer.event_rates(now)
        if self.registry is not None:
            line["components"] = self.registry.snapshot()
        return line

    # -- inspection ------------------------------------------------------
    def states(self) -> dict:
        """Current per-site health state, e.g. ``{"site0": "healthy"}``."""
        return {name: sh.state for name, sh in sorted(self._sites.items())}

    def transition_log_json(self) -> str:
        """The exact state-change log as canonical JSON — byte-identical
        across `SimClock` replays of the same workflow (the determinism
        acceptance check)."""
        return json.dumps(self.transitions, sort_keys=True)

    def metrics(self) -> dict:
        """Registry-compatible bounded snapshot."""
        now = self.clock.now()
        return {
            "sites": {name: self._site_entry(sh, now)
                      for name, sh in sorted(self._sites.items())},
            "transitions": len(self.transitions),
            "stragglers_flagged": self.stragglers_flagged,
            "tasks_revoked": self.tasks_revoked,
            "executors_drained": self.executors_drained,
            "lines_emitted": self.lines_emitted,
        }
