"""Data-availability restart log (paper §3.12).

Unlike Condor's rescue DAG (which tags *jobs* as finished), Swift logs
*datasets successfully produced*.  On restart, logged datasets are marked
available and only tasks whose outputs are missing re-run.  Side effects the
paper calls out — both supported and tested:

  (a) new inputs added after a partial run are picked up on restart;
  (b) the program can be modified and restarted, as long as prior data flows
      are unchanged (keys are dataflow-derived, not graph-position-derived).

Values must be JSON-serializable or `PhysicalRef`s (artifact pointers);
artifact entries are only honored on resume if the files still exist.
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

from repro.core.xdtm import PhysicalRef


def _encode(value: Any):
    if isinstance(value, PhysicalRef):
        return {"__ref__": value.path, "meta": list(value.meta)}
    if isinstance(value, (list, tuple)):
        return [_encode(v) for v in value]
    if isinstance(value, dict):
        return {k: _encode(v) for k, v in value.items()}
    return value


def _decode(value: Any):
    if isinstance(value, dict) and "__ref__" in value:
        return PhysicalRef(value["__ref__"], tuple(value.get("meta", ())))
    if isinstance(value, list):
        return [_decode(v) for v in value]
    if isinstance(value, dict):
        return {k: _decode(v) for k, v in value.items()}
    return value


def _refs(value: Any) -> list[PhysicalRef]:
    out = []
    if isinstance(value, PhysicalRef):
        out.append(value)
    elif isinstance(value, (list, tuple)):
        for v in value:
            out.extend(_refs(v))
    elif isinstance(value, dict):
        for v in value.values():
            out.extend(_refs(v))
    return out


class RestartLog:
    """Append-only log of datasets successfully produced (paper §3.12).

    Pass to `Engine(restart_log=...)` and mark procedures/tasks
    ``durable=True``: their results are appended on success, and a rerun
    of the same program resolves logged outputs immediately instead of
    re-executing the producing tasks.

    Example::

        log = RestartLog("run.rlog")
        eng = Engine(restart_log=log)
        eng.submit("stage1", expensive_fn, durable=True)
        # ... crash, restart: the same submit returns the logged value
    """

    def __init__(self, path: str):
        self.path = path
        self._log: dict[str, Any] = {}
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    if not line.strip():
                        continue
                    rec = json.loads(line)
                    self._log[rec["key"]] = _decode(rec["value"])

    def append(self, key: str, value: Any) -> None:
        try:
            enc = _encode(value)
            json.dumps(enc)
        except (TypeError, ValueError):
            return  # non-durable value; skip logging
        self._log[key] = value
        with open(self.path, "a") as f:
            f.write(json.dumps({"key": key, "value": enc}) + "\n")

    def lookup(self, key: str) -> Tuple[bool, Any]:
        if key not in self._log:
            return False, None
        value = self._log[key]
        # artifact entries only count if the physical data still exists
        for ref in _refs(value):
            if not ref.exists():
                return False, None
        return True, value

    def __len__(self):
        return len(self._log)
