"""Data-availability restart log (paper §3.12).

Unlike Condor's rescue DAG (which tags *jobs* as finished), Swift logs
*datasets successfully produced*.  On restart, logged datasets are marked
available and only tasks whose outputs are missing re-run.  Side effects the
paper calls out — both supported and tested:

  (a) new inputs added after a partial run are picked up on restart;
  (b) the program can be modified and restarted, as long as prior data flows
      are unchanged (keys are dataflow-derived, not graph-position-derived).

Values must be JSON-serializable or `PhysicalRef`s (artifact pointers);
artifact entries are only honored on resume if the files still exist.
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

from repro.core.xdtm import PhysicalRef


def encode_value(value: Any):
    """JSON-encodable form of a task value: `PhysicalRef`s become tagged
    dicts, containers recurse, scalars pass through.  Shared by
    `RestartLog` and the sqlite `JobStore` so both durability layers
    agree on what a persisted value means."""
    if isinstance(value, PhysicalRef):
        return {"__ref__": value.path, "meta": list(value.meta)}
    if isinstance(value, (list, tuple)):
        return [encode_value(v) for v in value]
    if isinstance(value, dict):
        return {k: encode_value(v) for k, v in value.items()}
    return value


def decode_value(value: Any):
    """Inverse of `encode_value` (tagged dicts back to `PhysicalRef`s)."""
    if isinstance(value, dict) and "__ref__" in value:
        return PhysicalRef(value["__ref__"], tuple(value.get("meta", ())))
    if isinstance(value, list):
        return [decode_value(v) for v in value]
    if isinstance(value, dict):
        return {k: decode_value(v) for k, v in value.items()}
    return value


def physical_refs(value: Any) -> list[PhysicalRef]:
    """Every `PhysicalRef` reachable inside a value — resume only honors
    an entry if all of them still exist on disk."""
    out = []
    if isinstance(value, PhysicalRef):
        out.append(value)
    elif isinstance(value, (list, tuple)):
        for v in value:
            out.extend(physical_refs(v))
    elif isinstance(value, dict):
        for v in value.values():
            out.extend(physical_refs(v))
    return out


class RestartLog:
    """Append-only log of datasets successfully produced (paper §3.12).

    Pass to `Engine(restart_log=...)` and mark procedures/tasks
    ``durable=True``: their results are appended on success, and a rerun
    of the same program resolves logged outputs immediately instead of
    re-executing the producing tasks.

    Example::

        log = RestartLog("run.rlog")
        eng = Engine(restart_log=log)
        eng.submit("stage1", expensive_fn, durable=True)
        # ... crash, restart: the same submit returns the logged value
    """

    def __init__(self, path: str):
        self.path = path
        self._log: dict[str, Any] = {}
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    if not line.strip():
                        continue
                    rec = json.loads(line)
                    self._log[rec["key"]] = decode_value(rec["value"])

    def append(self, key: str, value: Any) -> None:
        try:
            enc = encode_value(value)
            json.dumps(enc)
        except (TypeError, ValueError):
            return  # non-durable value; skip logging
        self._log[key] = value
        with open(self.path, "a") as f:
            f.write(json.dumps({"key": key, "value": enc}) + "\n")

    def lookup(self, key: str) -> Tuple[bool, Any]:
        if key not in self._log:
            return False, None
        value = self._log[key]
        # artifact entries only count if the physical data still exists
        for ref in physical_refs(value):
            if not ref.exists():
                return False, None
        return True, value

    def items(self):
        """(key, decoded value) pairs — `JobStore.import_restart_log`
        reads these to seed a durable store from a legacy .rlog file."""
        return self._log.items()

    def __len__(self):
        return len(self._log)
