"""Clock abstraction: real wall-clock or deterministic discrete-event clock.

Engine benchmarks that reproduce the paper's provider comparisons (PBS vs
Falkon, Fig 6/10/11/13/14/17) run on `SimClock` — virtual time, so a
"25,292 second" GRAM/PBS MolDyn run simulates in milliseconds and results are
deterministic.  `RealClock` is the wall-clock event loop behind the real
execution path (DESIGN.md §10): the same engine/provider/Falkon code runs
unchanged, task bodies execute on real worker threads
(`repro.core.realpool`), and completions re-enter the loop through the
thread-safe `post` queue.

Threading contract (DESIGN.md §10): every scheduler object — `Engine`,
`FalkonService`, providers, the data layer — runs entirely on the thread
that called `run()` ("the clock thread").  Worker threads touch only the
pool's work queue and `post`/`post_release`; everything they hand back is
executed on the clock thread.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from typing import Callable


class Clock:
    """Abstract scheduler clock: `now`, `schedule(delay, fn)`, `run`.

    Example — run one deferred callback::

        clock = SimClock()
        clock.schedule(5.0, lambda: print(clock.now()))   # prints 5.0
        clock.run()
    """

    def now(self) -> float:
        raise NotImplementedError

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        raise NotImplementedError

    def run(self) -> None:
        """Process events until idle."""
        raise NotImplementedError

    # -- cross-thread hand-off (real execution path, DESIGN.md §10) -----
    # True only on clocks whose `post` may be called from other threads
    # and whose `run` waits for held external work; worker pools require
    # it (a SimClock cannot host real workers — see ThreadExecutorPool)
    threadsafe_post = False

    def post(self, fn: Callable[[], None]) -> None:
        """Enqueue `fn` to run on the clock thread.  The base implementation
        is `schedule(0, fn)` — correct for single-threaded clocks like
        `SimClock`, where "another thread" does not exist but transports
        (e.g. `QueueTransport`) still want one delivery API.  `RealClock`
        overrides this with a thread-safe, loop-waking version."""
        self.schedule(0.0, fn)

    def post_many(self, fns) -> None:
        """Enqueue an ordered batch of callbacks in one operation — the
        bulk form of `post`, used by boundary reader threads (process
        transports, DESIGN.md §14) that drain several messages per
        wakeup.  The base implementation posts one by one; `RealClock`
        overrides it with a single lock acquisition and one loop wakeup
        for the whole batch."""
        for fn in fns:
            self.post(fn)

    def post_release(self, fn: Callable[[], None]) -> None:
        """`post(fn)` plus the release of one `hold()` token, atomically —
        used by worker pools so the loop can never observe "no holds, no
        events" between a completion being enqueued and its token being
        returned."""
        self.post(fn)
        self.release()

    def hold(self) -> None:
        """Take one external-work token: `run()` must not exit while tokens
        are outstanding (a task is on a worker thread and its completion
        has not been posted yet).  No-op on purely event-driven clocks."""

    def release(self) -> None:
        """Return one external-work token (see `hold`)."""


class SimClock(Clock):
    """Deterministic discrete-event clock (virtual time).

    Events fire in (time, insertion) order; `now()` jumps to each event's
    timestamp, so a simulated 7-hour MolDyn campaign runs in milliseconds
    and every run replays identically.

    Example::

        clock = SimClock()
        clock.schedule(3600.0, lambda: None)
        clock.run()
        assert clock.now() == 3600.0      # virtual seconds, instant wall time
    """

    def __init__(self):
        self._now = 0.0
        self._heap: list = []
        self._seq = itertools.count()

    def now(self) -> float:
        return self._now

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (self._now + max(0.0, delay),
                                    next(self._seq), fn))

    def run(self) -> None:
        heap = self._heap
        pop = heapq.heappop
        while heap:
            t, _, fn = pop(heap)
            if t > self._now:
                self._now = t
            fn()


class RealClock(Clock):
    """Wall-clock event loop with thread-safe wakeups (DESIGN.md §10).

    Single-threaded core, Karajan-style: `schedule(0, fn)` runs via a FIFO
    queue, positive delays wait on a monotonic timer heap.  Two extensions
    make it the spine of the *real* execution path:

      * `post(fn)` / `post_release(fn)` — thread-safe enqueue from worker
        threads (task completions, transport deliveries); the loop wakes
        immediately, even mid-timer-wait.
      * `hold()` / `release()` — external-work tokens: while a task body is
        out on a worker thread there may be no queued event and no timer,
        yet the run is not finished.  `run()` blocks on the condition
        variable instead of exiting while tokens are outstanding.

    Example — same program as `SimClock`, but measured::

        clock = RealClock()
        clock.schedule(0.01, lambda: None)
        clock.run()                        # really waits ~10 ms
        assert clock.now() >= 0.01

    Everything scheduled or posted executes on the thread that called
    `run()`; scheduler state is never touched from worker threads.
    """

    threadsafe_post = True

    def __init__(self):
        self._queue: deque = deque()
        self._heap: list = []
        self._seq = itertools.count()
        self._t0 = time.monotonic()
        self._cond = threading.Condition()
        self._posted: deque = deque()
        self._holds = 0

    def now(self) -> float:
        return time.monotonic() - self._t0

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        if delay <= 0:
            self._queue.append(fn)
        else:
            heapq.heappush(self._heap, (self.now() + delay,
                                        next(self._seq), fn))

    # -- cross-thread hand-off ------------------------------------------
    def post(self, fn: Callable[[], None]) -> None:
        with self._cond:
            self._posted.append(fn)
            self._cond.notify()

    def post_many(self, fns) -> None:
        with self._cond:
            self._posted.extend(fns)
            self._cond.notify()

    def post_release(self, fn: Callable[[], None]) -> None:
        with self._cond:
            self._posted.append(fn)
            self._holds -= 1
            self._cond.notify()

    def hold(self) -> None:
        with self._cond:
            self._holds += 1

    def release(self) -> None:
        with self._cond:
            self._holds -= 1
            self._cond.notify()

    # -------------------------------------------------------------------
    def run(self) -> None:
        queue = self._queue
        heap = self._heap
        cond = self._cond
        posted = self._posted
        while True:
            if posted:
                # drain cross-thread posts into the ordinary FIFO; the lock
                # is only needed around the handoff
                with cond:
                    while posted:
                        queue.append(posted.popleft())
            if queue:
                queue.popleft()()
                continue
            wait = None
            if heap:
                wait = heap[0][0] - self.now()
                if wait <= 0:
                    _, _, fn = heapq.heappop(heap)
                    fn()
                    continue
            with cond:
                if posted:
                    continue
                if wait is None and self._holds == 0:
                    break          # idle: no events, no timers, no workers
                cond.wait(wait)    # timer due, or a post/release will wake us
