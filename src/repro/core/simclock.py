"""Clock abstraction: real wall-clock or deterministic discrete-event clock.

Engine benchmarks that reproduce the paper's provider comparisons (PBS vs
Falkon, Fig 6/10/11/13/14/17) run on `SimClock` — virtual time, so a
"25,292 second" GRAM/PBS MolDyn run simulates in milliseconds and results are
deterministic.  Measurements of *our own* dispatch overhead use `RealClock`.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable


class Clock:
    def now(self) -> float:
        raise NotImplementedError

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        raise NotImplementedError

    def run(self) -> None:
        """Process events until idle."""
        raise NotImplementedError


class SimClock(Clock):
    def __init__(self):
        self._now = 0.0
        self._heap: list = []
        self._seq = itertools.count()

    def now(self) -> float:
        return self._now

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (self._now + max(0.0, delay),
                                    next(self._seq), fn))

    def run(self) -> None:
        heap = self._heap
        pop = heapq.heappop
        while heap:
            t, _, fn = pop(heap)
            if t > self._now:
                self._now = t
            fn()


class RealClock(Clock):
    """Immediate execution; `schedule` with delay==0 runs via a FIFO queue
    (no threads — the engine is event-driven, Karajan-style)."""

    def __init__(self):
        self._queue: list = []
        self._heap: list = []
        self._seq = itertools.count()
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        if delay <= 0:
            self._queue.append(fn)
        else:
            heapq.heappush(self._heap, (self.now() + delay,
                                        next(self._seq), fn))

    def run(self) -> None:
        while self._queue or self._heap:
            if self._queue:
                self._queue.pop(0)()
                continue
            t, _, fn = heapq.heappop(self._heap)
            wait = t - self.now()
            if wait > 0:
                time.sleep(wait)
            fn()
