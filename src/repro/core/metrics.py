"""Bounded streaming metrics for million-task runs (DESIGN.md §4).

The seed implementation appended one log entry per task to plain lists
(`queue_len_log`, `alloc_log`, per-executor `task_log`), so a 10^6-task run
grew tens of millions of tuples.  `StreamStat` replaces those with O(1)
rolling counters (count / total / peak / last) plus a fixed-size,
deterministic reservoir: observations are kept every `stride`-th sample and
when the reservoir fills, every other kept sample is dropped and the stride
doubles.  Memory is bounded by `cap` regardless of run length, and the
decimation is reproducible under `SimClock` (no RNG).
"""
from __future__ import annotations


class StreamStat:
    """Rolling summary of a (time, value) series with a bounded sample."""

    __slots__ = ("cap", "count", "total", "peak", "last", "sample",
                 "_stride", "_skip")

    def __init__(self, cap: int = 512):
        if cap < 2:
            raise ValueError("cap must be >= 2")
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self.peak: float | None = None
        self.last: float | None = None
        self.sample: list[tuple[float, float]] = []
        self._stride = 1
        self._skip = 0

    def observe(self, t: float, v: float) -> None:
        self.count += 1
        self.total += v
        if self.peak is None or v > self.peak:
            self.peak = v
        self.last = v
        if self._skip:
            self._skip -= 1
            return
        self.sample.append((t, v))
        if len(self.sample) >= self.cap:
            # decimate: drop every other sample, keeping the first so the
            # series origin stays anchored
            del self.sample[1::2]
            self._stride *= 2
        self._skip = self._stride - 1

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        return {
            "count": self.count,
            "total": self.total,
            "mean": self.mean(),
            "peak": self.peak,
            "last": self.last,
            "samples_kept": len(self.sample),
            "sample_stride": self._stride,
        }

    def __repr__(self):
        return (f"<StreamStat n={self.count} mean={self.mean():.3g} "
                f"peak={self.peak} kept={len(self.sample)}>")
