"""Bounded streaming metrics for million-task runs (DESIGN.md §4).

The seed implementation appended one log entry per task to plain lists
(`queue_len_log`, `alloc_log`, per-executor `task_log`), so a 10^6-task run
grew tens of millions of tuples.  `StreamStat` replaces those with O(1)
rolling counters (count / total / peak / last) plus a fixed-size,
deterministic reservoir: observations are kept every `stride`-th sample and
when the reservoir fills, every other kept sample is dropped and the stride
doubles.  Memory is bounded by `cap` regardless of run length, and the
decimation is reproducible under `SimClock` (no RNG).
"""
from __future__ import annotations

import math


def percentile_of(sorted_vals, q: float) -> float:
    """Nearest-rank percentile of an ascending-sorted sequence (0 when
    empty).  Deterministic and allocation-free — shared by `StreamStat`
    reservoirs and the observability layer's span percentiles."""
    n = len(sorted_vals)
    if not n:
        return 0.0
    idx = min(n - 1, max(0, math.ceil(q * n) - 1))
    v = sorted_vals[idx]
    # reservoir entries are (t, v) pairs; bare series are floats
    return v[1] if isinstance(v, tuple) else v


class StreamStat:
    """Rolling summary of a (time, value) series with a bounded sample."""

    __slots__ = ("cap", "count", "total", "peak", "low", "last", "sample",
                 "_stride", "_skip")

    def __init__(self, cap: int = 512):
        if cap < 2:
            raise ValueError("cap must be >= 2")
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self.peak: float | None = None
        self.low: float | None = None
        self.last: float | None = None
        self.sample: list[tuple[float, float]] = []
        self._stride = 1
        self._skip = 0

    def observe(self, t: float, v: float) -> None:
        self.count += 1
        self.total += v
        if self.peak is None or v > self.peak:
            self.peak = v
        if self.low is None or v < self.low:
            self.low = v
        self.last = v
        if self._skip:
            self._skip -= 1
            return
        self.sample.append((t, v))
        if len(self.sample) >= self.cap:
            # decimate: drop every other sample, keeping the first so the
            # series origin stays anchored
            del self.sample[1::2]
            self._stride *= 2
        self._skip = self._stride - 1

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    # -- cross-process merge (DESIGN.md §14) ----------------------------
    def snapshot(self) -> dict:
        """Full picklable/JSON-able state — lossless up to the reservoir,
        unlike `summary()`.  Ship it across a process boundary and rebuild
        with `from_snapshot`, or fold it into an aggregate with `merge`."""
        return {
            "cap": self.cap,
            "count": self.count,
            "total": self.total,
            "peak": self.peak,
            "min": self.low,
            "last": self.last,
            "sample": list(self.sample),
            "stride": self._stride,
        }

    @classmethod
    def from_snapshot(cls, snap: dict) -> "StreamStat":
        """Rebuild a stat from `snapshot()` output (e.g. one shipped back
        by a federation shard process)."""
        st = cls(cap=snap["cap"])
        st.count = snap["count"]
        st.total = snap["total"]
        st.peak = snap["peak"]
        st.low = snap["min"]
        st.last = snap["last"]
        st.sample = [tuple(s) for s in snap["sample"]]
        st._stride = snap["stride"]
        return st

    def merge(self, other: "StreamStat") -> "StreamStat":
        """Fold another stat into this one (cross-process aggregation):
        count/total/peak/min are exact; the merged reservoir is the
        time-sorted union of both samples, decimated back under `cap` by
        the same drop-every-other scheme `observe` uses, so percentile
        estimates stay within reservoir tolerance.  `last` takes the
        merge argument's value when it has one (the caller folds shards
        into an aggregate, so "most recently merged" is the useful
        reading).  Returns self."""
        if other.count == 0:
            return self
        self.count += other.count
        self.total += other.total
        if self.peak is None or (other.peak is not None
                                 and other.peak > self.peak):
            self.peak = other.peak
        if self.low is None or (other.low is not None
                                and other.low < self.low):
            self.low = other.low
        if other.last is not None:
            self.last = other.last
        merged = sorted(self.sample + list(other.sample))
        stride = max(self._stride, other._stride)
        while len(merged) >= self.cap:
            del merged[1::2]
            stride *= 2
        self.sample = merged
        self._stride = stride
        self._skip = 0
        return self

    def percentile(self, q: float) -> float:
        """Streaming percentile estimated from the reservoir (exact until
        the first decimation, q-quantile of a deterministic stride
        thinning after).  `q` in [0, 1]."""
        return percentile_of(sorted(v for _, v in self.sample), q)

    def summary(self) -> dict:
        vals = sorted(v for _, v in self.sample)
        return {
            "count": self.count,
            "total": self.total,
            "mean": self.mean(),
            "peak": self.peak,
            "min": self.low,
            "last": self.last,
            "p50": percentile_of(vals, 0.50),
            "p95": percentile_of(vals, 0.95),
            "p99": percentile_of(vals, 0.99),
            "samples_kept": len(self.sample),
            "sample_stride": self._stride,
        }

    def __repr__(self):
        return (f"<StreamStat n={self.count} mean={self.mean():.3g} "
                f"peak={self.peak} kept={len(self.sample)}>")
