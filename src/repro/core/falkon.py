"""Falkon: fast, lightweight task execution service (paper §4).

Multi-level scheduling: *resource provisioning* (DRP acquires executors,
paying the batch-scheduler allocation latency once) is decoupled from *task
dispatch* (streamlined, O(1), ~ms per task).  Executors register with the
service; queued tasks are dispatched to idle executors; DRP grows/shrinks the
pool on queue pressure; hosts with repeated failures are suspended
("stale NFS handle" handling, §3.12).

Scale behavior (DESIGN.md §2/§4): per-task dispatch cost is O(1) in both
queue depth and pool size — the idle-executor pool is a deque, the DRP
shrink sweep is amortized over the idle timeout instead of scanning every
executor on every completion, and metrics are bounded `StreamStat`
summaries.  Construct the service with ``trace=True`` to additionally keep
the full per-event logs (`queue_len_log`, `alloc_log`, per-executor
`task_log`) that the Fig-18-style benchmark views need; traces grow with
task count and are therefore off by default.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

from repro.core.metrics import StreamStat
from repro.core.simclock import Clock
from repro.core.task import execute_task, sim_duration

# compat aliases — the seed exposed these as falkon-private helpers and
# other modules imported them from here
_execute = execute_task
_sim_duration = sim_duration


@dataclasses.dataclass
class DRPConfig:
    min_executors: int = 0
    max_executors: int = 64
    alloc_latency: float = 81.0      # GRAM4+PBS allocation latency (paper §5.4.3)
    alloc_chunk: int = 32            # executors acquired per allocation
    idle_timeout: float = 300.0      # de-register idle executors
    queue_per_executor: float = 1.0  # grow when queue > this x executors


@dataclasses.dataclass
class FalkonConfig:
    dispatch_overhead: float = 1.0 / 487.0   # paper: 487 tasks/s streamlined
    drp: DRPConfig = dataclasses.field(default_factory=DRPConfig)
    host_fail_threshold: int = 2
    host_suspend_time: float = 60.0


class Executor:
    __slots__ = ("id", "host", "busy", "suspended_until", "consec_failures",
                 "idle_since", "busy_time", "tasks_done", "registered_at",
                 "task_log")

    def __init__(self, eid: int, host: str, now: float):
        self.id = eid
        self.host = host
        self.busy = False
        self.suspended_until = 0.0
        self.consec_failures = 0
        self.idle_since = now
        self.busy_time = 0.0
        self.tasks_done = 0
        self.registered_at = now
        self.task_log: list = []   # (start, end) per task; trace mode only


class FalkonService:
    """Web-services interface -> in-process API (see DESIGN.md §2)."""

    def __init__(self, clock: Clock, config: FalkonConfig | None = None,
                 name: str = "falkon", trace: bool = False):
        self.clock = clock
        self.cfg = config or FalkonConfig()
        self.name = name
        self.trace = trace
        self.queue: deque = deque()
        self.executors: list[Executor] = []
        self._idle: deque = deque()   # O(1) dispatch: idle-executor pool
        self._next_eid = 0
        self._allocating = 0
        self._last_shrink_scan = float("-inf")
        # metrics — bounded summaries always on; raw logs only under trace
        self.peak_queue = 0
        self.dispatched = 0
        self.tasks_finished = 0
        self.queue_stat = StreamStat(cap=512)   # queue length per pump
        self.alloc_stat = StreamStat(cap=256)   # executors per allocation
        self.queue_len_log: list = []
        self.alloc_log: list = []

    # ------------------------------------------------------------------
    # resource provisioning (DRP)
    # ------------------------------------------------------------------
    def provision(self, n: int):
        """Explicitly acquire n executors (paying allocation latency once)."""
        self._allocate(n)

    def _allocate(self, n: int):
        n = min(n, self.cfg.drp.max_executors - len(self.executors)
                - self._allocating)
        if n <= 0:
            return
        self._allocating += n
        now = self.clock.now()
        self.alloc_stat.observe(now, n)
        if self.trace:
            self.alloc_log.append((now, n))

        def arrive():
            self._allocating -= n
            for _ in range(n):
                e = Executor(self._next_eid, f"{self.name}-host{self._next_eid}",
                             self.clock.now())
                self._next_eid += 1
                self.executors.append(e)
                self._idle.append(e)
            self._pump()

        self.clock.schedule(self.cfg.drp.alloc_latency, arrive)

    def _maybe_grow(self):
        d = self.cfg.drp
        have = len(self.executors) + self._allocating
        if have >= d.max_executors:
            return
        if len(self.queue) > d.queue_per_executor * max(1, have) or have == 0:
            want = min(d.alloc_chunk, len(self.queue) - have + 1)
            self._allocate(max(1, want))

    def _maybe_shrink(self):
        d = self.cfg.drp
        # amortized O(1): nothing can be idle past the timeout while the
        # queue is non-empty, and a full pool scan at most once per half
        # timeout — the seed scanned every executor on every completion,
        # making per-task cost O(pool size)
        if self.queue or len(self.executors) <= d.min_executors:
            return
        now = self.clock.now()
        if now - self._last_shrink_scan < d.idle_timeout * 0.5:
            return
        self._last_shrink_scan = now
        drop = set()
        for e in self.executors:
            if (not e.busy and len(self.executors) - len(drop) >
                    d.min_executors
                    and now - e.idle_since > d.idle_timeout):
                drop.add(e.id)  # de-register (paper: idle auto-deregistration)
        if drop:
            self.executors = [e for e in self.executors if e.id not in drop]
            self._idle = deque(e for e in self._idle if e.id not in drop)

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    def submit(self, task, when_done: Callable):
        task._falkon_done = when_done
        task.submit_time = self.clock.now()
        self.queue.append(task)
        if len(self.queue) > self.peak_queue:
            self.peak_queue = len(self.queue)
        self._maybe_grow()
        self._pump()

    def _idle_executor(self) -> Optional[Executor]:
        idle = self._idle
        if not idle:
            return None
        # fast path: head of the pool is usable (the overwhelmingly common
        # case — suspensions and stale entries are failure-path artifacts)
        e = idle[0]
        if not e.busy and self.clock.now() >= e.suspended_until:
            idle.popleft()
            return e
        now = self.clock.now()
        skipped = []
        found = None
        while self._idle:
            e = self._idle.popleft()
            if e.busy:
                continue  # stale entry
            if now < e.suspended_until:
                skipped.append(e)  # suspended: back of the pool
                continue
            found = e
            break
        self._idle.extend(skipped)
        if found is None and skipped:
            # everyone suspended: retry when the first suspension lapses
            wake = min(e.suspended_until for e in skipped)
            self.clock.schedule(max(0.0, wake - now) + 1e-9, self._pump)
        return found

    def _pump(self):
        queue = self.queue
        self.queue_stat.observe(self.clock.now(), len(queue))
        if self.trace:
            self.queue_len_log.append((self.clock.now(), len(queue)))
        while queue:
            e = self._idle_executor()
            if e is None:
                break
            task = queue.popleft()
            self._dispatch(e, task)

    def _dispatch(self, e: Executor, task):
        e.busy = True
        self.dispatched += 1
        overhead = self.cfg.dispatch_overhead
        start = self.clock.now() + overhead
        task.start_time = start
        task.host = e.host

        def finish():
            ok, value, err = execute_task(task)
            end = self.clock.now()
            if self.trace:
                e.task_log.append((start, end))
            self.tasks_finished += 1
            e.busy = False
            e.idle_since = end
            e.busy_time += max(0.0, end - start)
            if ok:
                e.consec_failures = 0
                e.tasks_done += 1
            else:
                e.consec_failures += 1
                if e.consec_failures >= self.cfg.host_fail_threshold:
                    # paper §3.12: suspend faulty host, reschedule elsewhere
                    e.suspended_until = end + self.cfg.host_suspend_time
                    e.consec_failures = 0
            self._idle.append(e)
            # break the task -> callback -> task reference cycle so
            # completed tasks are freed by refcounting, not the cycle GC
            callback = task._falkon_done
            task._falkon_done = None
            callback(ok, value, err)
            self._maybe_shrink()
            self._pump()

        self.clock.schedule(overhead + sim_duration(task), finish)

    # ------------------------------------------------------------------
    def utilization(self) -> dict:
        now = self.clock.now()
        total_busy = sum(e.busy_time for e in self.executors)
        total_alive = sum(now - e.registered_at for e in self.executors)
        return {
            "executors": len(self.executors),
            "dispatched": self.dispatched,
            "peak_queue": self.peak_queue,
            "busy_time": total_busy,
            "alive_time": total_alive,
            "efficiency": total_busy / total_alive if total_alive else 0.0,
        }

    def metrics(self) -> dict:
        """Bounded metrics snapshot — safe at any task count."""
        return {
            "dispatched": self.dispatched,
            "finished": self.tasks_finished,
            "peak_queue": self.peak_queue,
            "queue": self.queue_stat.summary(),
            "allocations": self.alloc_stat.count,
            "executors_acquired": self.alloc_stat.total,
            "executors": len(self.executors),
        }
