"""Falkon: fast, lightweight task execution service (paper §4).

Multi-level scheduling: *resource provisioning* (DRP acquires executors,
paying the batch-scheduler allocation latency once) is decoupled from *task
dispatch* (streamlined, O(1), ~ms per task).  Executors register with the
service; queued tasks are dispatched to idle executors; DRP grows/shrinks the
pool on queue pressure; hosts with repeated failures are suspended
("stale NFS handle" handling, §3.12).

Scale behavior (DESIGN.md §2/§4): per-task dispatch cost is O(1) in both
queue depth and pool size — the idle-executor pool is a deque, the DRP
shrink sweep is amortized over the idle timeout instead of scanning every
executor on every completion, and metrics are bounded `StreamStat`
summaries.  Construct the service with ``trace=True`` to additionally keep
the raw per-event series (`queue_len_log`, `alloc_log`, per-executor
`task_log`) that the Fig-18-style benchmark views need — these live on a
`Tracer`'s bounded logs (DESIGN.md §12), so even a traced 10^6-task run
stays memory-bounded (the seed kept plain lists that grew O(tasks)).
Pass ``tracer=`` to share the engine's tracer, so DRP allocations and
affinity redirects land in the same trace as the task lifecycle spans.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

from repro.core.faults import TaskFailure
from repro.core.metrics import StreamStat
from repro.core.observability import BoundedLog, Tracer
from repro.core.simclock import Clock
from repro.core.task import execute_task, sim_duration

# compat aliases — the seed exposed these as falkon-private helpers and
# other modules imported them from here
_execute = execute_task
_sim_duration = sim_duration


@dataclasses.dataclass
class DRPConfig:
    min_executors: int = 0
    max_executors: int = 64
    alloc_latency: float = 81.0      # GRAM4+PBS allocation latency (paper §5.4.3)
    alloc_chunk: int = 32            # executors acquired per allocation
    idle_timeout: float = 300.0      # de-register idle executors
    queue_per_executor: float = 1.0  # grow when queue > this x executors


@dataclasses.dataclass
class FalkonConfig:
    dispatch_overhead: float = 1.0 / 487.0   # paper: 487 tasks/s streamlined
    # serialize_dispatch=True models the dispatcher as a serial resource:
    # task starts are gated at one per `dispatch_overhead`, so a single
    # service saturates at the paper's 487 tasks/s no matter how many
    # executors it feeds (§4: the measured number is a *dispatcher*
    # throughput ceiling).  This is the regime multi-engine federation
    # (DESIGN.md §8) exists for — N shard services give N dispatchers.
    # Default False keeps the seed's per-task-overhead timing exactly.
    serialize_dispatch: bool = False
    drp: DRPConfig = dataclasses.field(default_factory=DRPConfig)
    host_fail_threshold: int = 2
    host_suspend_time: float = 60.0


class Executor:
    __slots__ = ("id", "host", "busy", "suspended_until", "consec_failures",
                 "idle_since", "busy_time", "tasks_done", "registered_at",
                 "task_log", "cache", "local_q", "local_work", "in_idle")

    def __init__(self, eid: int, host: str, now: float):
        self.id = eid
        self.host = host
        self.busy = False
        self.suspended_until = 0.0
        self.consec_failures = 0
        self.idle_since = now
        self.busy_time = 0.0
        self.tasks_done = 0
        self.registered_at = now
        self.task_log: list = []   # (start, end) per task; trace mode only
        self.cache = None          # ExecutorCache when a DataLayer is attached
        self.local_q: deque = deque()   # affinity queue (data-aware dispatch)
        self.local_work = 0.0      # sum of parked tasks' sim durations
        self.in_idle = False       # a live entry exists in the idle deque


class FalkonService:
    """The Falkon execution service: multi-level scheduling (paper §4).

    Provisioning (DRP) is decoupled from dispatch; executors register with
    the service and queued tasks are dispatched to idle executors in O(1).
    Wrap in a `FalkonProvider` to register it as an engine site.

    Example — simulated pool (deterministic, virtual time)::

        clock = SimClock()
        svc = FalkonService(clock, FalkonConfig(
            drp=DRPConfig(max_executors=64, alloc_latency=5.0)))
        eng = Engine(clock)
        eng.add_site("pod0", FalkonProvider(svc), capacity=64)

    Real execution (DESIGN.md §10): pass ``pool=ThreadExecutorPool(clock)``
    (or a `ProcessExecutorPool`) and a `RealClock` — the same program then
    runs task bodies on actual workers, DRP provisioning acquires/releases
    real threads (the pool autoscales with the executor count), and staging
    through an attached data layer performs measured byte copies instead of
    priced ones.  ``pool=DeviceExecutorPool(clock)`` (DESIGN.md §11) keeps
    the same seam but fuses same-signature tasks into one vmapped device
    call per bundle; it is fixed-size (``autoscale`` False), so DRP still
    sizes only the logical executor set.
    """

    def __init__(self, clock: Clock, config: FalkonConfig | None = None,
                 name: str = "falkon", trace: bool = False,
                 data_layer=None, pool=None, tracer=None):
        self.clock = clock
        self.cfg = config or FalkonConfig()
        self.name = name
        self.trace = trace
        # observability (DESIGN.md §12): component events (DRP allocations,
        # affinity parks) are recorded whenever a tracer is attached; the
        # raw series + per-executor task logs additionally require
        # ``trace=True`` and are bounded by the tracer's log caps
        if tracer is None and trace:
            tracer = Tracer()
        self.tracer = tracer
        # data diffusion (DESIGN.md §7): when a DataLayer is attached, tasks
        # with declared inputs prefer idle executors already caching them and
        # input reads are priced by the staging cost model.  None keeps the
        # locality-blind O(1) dispatch path byte-for-byte.
        self.data_layer = data_layer
        # real execution (DESIGN.md §10): when a worker pool is attached,
        # task bodies run on its workers and completions re-enter through
        # the clock's post queue; None keeps the simulated path byte-for-byte
        self.pool = pool
        # online health (DESIGN.md §13): set by `HealthMonitor.watch_service`
        # — per-executor completions feed its windows and its site drain
        # calls `drain_queued`.  None keeps `_complete` to one attribute test.
        self.health = None
        self.queue: deque = deque()
        self.executors: list[Executor] = []
        self._idle: deque = deque()   # O(1) dispatch: idle-executor pool
        self._next_eid = 0
        self._allocating = 0
        self._last_shrink_scan = float("-inf")
        self._dispatcher_free_at = 0.0   # serialize_dispatch gate
        self._parked = 0   # tasks waiting in executor affinity queues
        # metrics — bounded summaries always on; raw logs only under trace
        self.peak_queue = 0
        self.dispatched = 0
        self.tasks_finished = 0
        self.queue_stat = StreamStat(cap=512)   # queue length per pump
        self.alloc_stat = StreamStat(cap=256)   # executors per allocation
        if trace:
            self.queue_len_log = tracer.log(f"{name}.queue_len")
            self.alloc_log = tracer.log(f"{name}.allocs")
        else:
            self.queue_len_log: list = []
            self.alloc_log: list = []

    # ------------------------------------------------------------------
    # resource provisioning (DRP)
    # ------------------------------------------------------------------
    def provision(self, n: int):
        """Explicitly acquire n executors (paying allocation latency once)."""
        self._allocate(n)

    def _allocate(self, n: int):
        n = min(n, self.cfg.drp.max_executors - len(self.executors)
                - self._allocating)
        if n <= 0:
            return
        self._allocating += n
        now = self.clock.now()
        self.alloc_stat.observe(now, n)
        if self.trace:
            self.alloc_log.append((now, n))
        if self.tracer is not None:
            self.tracer.event("drp_alloc", now, n)

        def arrive():
            self._allocating -= n
            for _ in range(n):
                e = Executor(self._next_eid, f"{self.name}-host{self._next_eid}",
                             self.clock.now())
                if self.trace:
                    # bounded Fig-18 per-executor timeline (DESIGN.md §12)
                    e.task_log = BoundedLog(self.tracer.log_cap)
                self._next_eid += 1
                if self.data_layer is not None:
                    self.data_layer.register_executor(e)
                self.executors.append(e)
                self._push_idle(e)
            if self.pool is not None and self.pool.autoscale:
                # real execution: provisioning acquires actual workers —
                # one pool worker per registered executor
                self.pool.resize(len(self.executors))
            self._pump()

        self.clock.schedule(self.cfg.drp.alloc_latency, arrive)

    def _maybe_grow(self):
        d = self.cfg.drp
        have = len(self.executors) + self._allocating
        if have >= d.max_executors:
            return
        # parked affinity-queue tasks are backlog too: they wait for
        # specific holders, but a larger pool gives spillover somewhere
        # to replicate
        backlog = len(self.queue) + self._parked
        if backlog > d.queue_per_executor * max(1, have) or have == 0:
            want = min(d.alloc_chunk, backlog - have + 1)
            self._allocate(max(1, want))

    def _maybe_shrink(self):
        d = self.cfg.drp
        # amortized O(1): nothing can be idle past the timeout while the
        # queue is non-empty, and a full pool scan at most once per half
        # timeout — the seed scanned every executor on every completion,
        # making per-task cost O(pool size).  Parked affinity-queue tasks
        # run only on their (busy) holder, which the per-executor
        # `local_q` check below protects — other idle executors may still
        # be released.
        if self.queue or len(self.executors) <= d.min_executors:
            return
        now = self.clock.now()
        if now - self._last_shrink_scan < d.idle_timeout * 0.5:
            return
        self._last_shrink_scan = now
        drop = set()
        for e in self.executors:
            if (not e.busy and not e.local_q
                    and len(self.executors) - len(drop) > d.min_executors
                    and now - e.idle_since > d.idle_timeout):
                drop.add(e.id)  # de-register (paper: idle auto-deregistration)
        if drop:
            if self.data_layer is not None:
                for e in self.executors:
                    if e.id in drop:
                        self.data_layer.deregister_executor(e)
            self.executors = [e for e in self.executors if e.id not in drop]
            self._idle = deque(e for e in self._idle if e.id not in drop)
            if self.pool is not None and self.pool.autoscale:
                # idle de-registration releases the backing workers too
                self.pool.resize(len(self.executors))

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    def submit(self, task, when_done: Callable):
        task._falkon_done = when_done
        task.submit_time = self.clock.now()
        self.queue.append(task)
        if len(self.queue) > self.peak_queue:
            self.peak_queue = len(self.queue)
        self._maybe_grow()
        self._pump()

    def _push_idle(self, e: Executor) -> None:
        """Add to the idle pool unless a live entry already exists — an
        executor claimed off-deque (cache-aware dispatch) keeps its old
        entry as the marker, so the deque never exceeds the pool size."""
        if not e.in_idle:
            e.in_idle = True
            self._idle.append(e)

    def _idle_executor(self) -> Optional[Executor]:
        idle = self._idle
        if not idle:
            return None
        # fast path: head of the pool is usable (the overwhelmingly common
        # case — suspensions and stale entries are failure-path artifacts)
        e = idle[0]
        if not e.busy and self.clock.now() >= e.suspended_until:
            idle.popleft()
            e.in_idle = False
            return e
        now = self.clock.now()
        skipped = []
        found = None
        while self._idle:
            e = self._idle.popleft()
            e.in_idle = False
            if e.busy:
                continue  # stale entry
            if now < e.suspended_until:
                skipped.append(e)  # suspended: back of the pool
                continue
            found = e
            break
        for s in skipped:
            s.in_idle = True
        self._idle.extend(skipped)
        if found is None and skipped:
            # everyone suspended: retry when the first suspension lapses
            wake = min(e.suspended_until for e in skipped)
            self.clock.schedule(max(0.0, wake - now) + 1e-9, self._pump)
        return found

    def _pump(self):
        queue = self.queue
        self.queue_stat.observe(self.clock.now(), len(queue))
        if self.trace:
            self.queue_len_log.append((self.clock.now(), len(queue)))
        dl = self.data_layer
        if dl is None:
            while queue:
                e = self._idle_executor()
                if e is None:
                    break
                task = queue.popleft()
                self._dispatch(e, task)
            return
        # cache-aware dispatch (DESIGN.md §7): each task is routed once —
        # to an idle holder of its inputs (run now), behind a busy holder
        # (its affinity queue, drained at that executor's next completion),
        # or to first-idle as cold spillover.  A task moved to an affinity
        # queue never returns to the global queue, so routing is amortized
        # O(1) per task.  Idle holders are claimed without removing their
        # idle-deque entry — the entry goes stale and the existing
        # busy-skip in `_idle_executor` drops it.
        now = self.clock.now()
        while queue:
            task = queue[0]
            if task.inputs:
                e, run_now = dl.pick_home(task, now)
                if e is not None and not run_now:
                    queue.popleft()
                    e.local_q.append(task)   # wait behind the busy holder
                    e.local_work += sim_duration(task)
                    self._parked += 1
                    if self.tracer is not None:
                        self.tracer.event("affinity_park", now)
                    continue
            else:
                e = None
            if e is None:
                e = self._idle_executor()
                if e is None:
                    break
            queue.popleft()
            self._dispatch(e, task)

    def _dispatch(self, e: Executor, task):
        e.busy = True
        self.dispatched += 1
        overhead = self.cfg.dispatch_overhead
        if self.cfg.serialize_dispatch:
            # the dispatcher is a serial resource (paper §4: 487 tasks/s is
            # a dispatcher ceiling): this task waits for the dispatcher to
            # free, then occupies it for one dispatch_overhead
            now = self.clock.now()
            gate = self._dispatcher_free_at
            wait = gate - now if gate > now else 0.0
            self._dispatcher_free_at = now + wait + overhead
            overhead = wait + overhead
        if self.pool is not None:
            self._dispatch_real(e, task, overhead)
            return
        dl = self.data_layer
        # input staging: cached inputs are read locally, the rest staged
        # from the shared store (and cached for the next task); the I/O time
        # extends the task's service time on this executor
        io = (dl.stage_inputs(e, task, self.clock)
              if dl is not None and task.inputs else 0.0)
        if io:
            sp = getattr(task, "span", None)
            if sp is not None:
                sp.io_s = io      # stage-wait lands on the lifecycle span
        start = self.clock.now() + overhead
        task.start_time = start
        task.host = e.host
        chk = task.fault_check
        if chk is not None and getattr(chk, "timed", False):
            # fail-slow faults (DESIGN.md §13): the injector carries rules
            # whose failures have their own latency (hang/timeout style),
            # so the check runs at dispatch — a hit occupies the executor
            # for the *fault's* duration, not the task's
            fault = None
            try:
                chk(task)
            except BaseException as f:  # noqa: BLE001
                fault = f
            if fault is not None:
                dur = getattr(fault, "latency", None)
                if dur is None:
                    dur = sim_duration(task)
                self.clock.schedule(
                    overhead + io + dur,
                    lambda: self._complete(e, task, False, None, fault,
                                           start))
                return

            def finish():
                # the dispatch-time draw already passed: mask the check so
                # completion doesn't draw (and possibly fail) a second time
                task.fault_check = None
                ok, value, err = execute_task(task)
                task.fault_check = chk
                self._complete(e, task, ok, value, err, start)
        else:
            def finish():
                ok, value, err = execute_task(task)
                self._complete(e, task, ok, value, err, start)

        self.clock.schedule(overhead + io + sim_duration(task), finish)

    def _dispatch_real(self, e: Executor, task, overhead: float):
        """Real execution (DESIGN.md §10): the task body — and, with a data
        layer attached, its real staging copies — runs on a pool worker; the
        measured completion re-enters on the clock thread.  The modeled
        `dispatch_overhead` applies only under ``serialize_dispatch`` (where
        it *is* the model being studied — the dispatcher ceiling); otherwise
        dispatch cost is whatever the dispatcher actually takes."""
        dl = self.data_layer
        stage = None
        if dl is not None and task.inputs:
            # cache/holder bookkeeping happens here on the clock thread;
            # only the byte copies run on the worker (inside the measured
            # service time, where the simulated path adds priced I/O)
            stage = dl.plan_staging(e, task)

        def finish_real(ok, value, err, io_s, run_s):
            if stage is not None:
                dl.end_staging(stage, io_s, self.clock.now())
            if io_s:
                sp = getattr(task, "span", None)
                if sp is not None:
                    sp.io_s = io_s    # measured stage-wait onto the span
            self._complete(e, task, ok, value, err, task.start_time,
                           busy_s=io_s + run_s)

        def handoff():
            task.start_time = self.clock.now()
            task.host = e.host
            self.pool.submit(task, finish_real, stage)

        if self.cfg.serialize_dispatch and overhead > 0.0:
            self.clock.schedule(overhead, handoff)
        else:
            handoff()

    def _complete(self, e: Executor, task, ok: bool, value, err,
                  start: float, busy_s: float | None = None):
        """Shared post-execution bookkeeping for both paths.  `busy_s` is
        the measured service time on the real path; the simulated path
        derives it from the scheduled start/end."""
        end = self.clock.now()
        if self.trace:
            e.task_log.append((start, end))
            self.tracer.exec_span(self.name, e.host, start, end, task.name)
        dl = self.data_layer
        if dl is not None and task.inputs:
            dl.release_inputs(e, task)
        self.tasks_finished += 1
        e.busy = False
        e.idle_since = end
        e.busy_time += busy_s if busy_s is not None else max(0.0, end - start)
        if ok:
            e.consec_failures = 0
            e.tasks_done += 1
        else:
            e.consec_failures += 1
            if e.consec_failures >= self.cfg.host_fail_threshold:
                # paper §3.12: suspend faulty host, reschedule elsewhere
                e.suspended_until = end + self.cfg.host_suspend_time
                e.consec_failures = 0
        if self.health is not None:
            # per-executor windowed error rates (DESIGN.md §13); the
            # monitor may extend `suspended_until` beyond the
            # consecutive-failure heuristic above
            self.health.on_executor(self, e, ok, end)
        next_local = None
        if e.local_q and end < e.suspended_until:
            # suspended host: hand its affinity queue back to the
            # service so other holders (or cold spillover) take it
            self._parked -= len(e.local_q)
            self.queue.extendleft(reversed(e.local_q))
            e.local_q.clear()
            e.local_work = 0.0
        elif e.local_q:
            next_local = e.local_q.popleft()
            e.local_work -= sim_duration(next_local)
            self._parked -= 1
        if next_local is None:
            self._push_idle(e)
        # break the task -> callback -> task reference cycle so
        # completed tasks are freed by refcounting, not the cycle GC
        callback = task._falkon_done
        task._falkon_done = None
        if next_local is not None:
            # affinity queue drains first: the executor keeps running
            # tasks whose inputs it already holds (data diffusion)
            self._dispatch(e, next_local)
        callback(ok, value, err)
        self._maybe_shrink()
        self._pump()

    def drain_queued(self) -> int:
        """Revoke every queued-but-not-running task (global queue plus
        executor affinity queues) back to its submitter with
        ``TaskFailure(kind="revoked")`` — the engine re-places revoked
        tasks on other sites without charging retries (DESIGN.md §13).
        Tasks already running on executors finish (or fail) normally.
        Called by the `HealthMonitor` when it drains this service's site;
        returns the number of tasks revoked."""
        out = list(self.queue)
        self.queue.clear()
        for e in self.executors:
            if e.local_q:
                self._parked -= len(e.local_q)
                out.extend(e.local_q)
                e.local_q.clear()
                e.local_work = 0.0
        for task in out:
            callback = task._falkon_done
            task._falkon_done = None
            callback(False, None,
                     TaskFailure(f"{self.name} drained", kind="revoked"))
        return len(out)

    def shutdown(self) -> None:
        """Stop the attached worker pool, if any (no-op on the simulated
        path).  Call after `run()` returns; queued work has completed."""
        if self.pool is not None:
            self.pool.shutdown()

    # ------------------------------------------------------------------
    def utilization(self) -> dict:
        now = self.clock.now()
        total_busy = sum(e.busy_time for e in self.executors)
        total_alive = sum(now - e.registered_at for e in self.executors)
        return {
            "executors": len(self.executors),
            "dispatched": self.dispatched,
            "peak_queue": self.peak_queue,
            "busy_time": total_busy,
            "alive_time": total_alive,
            "efficiency": total_busy / total_alive if total_alive else 0.0,
        }

    def metrics(self) -> dict:
        """Bounded metrics snapshot — safe at any task count."""
        m = {
            "dispatched": self.dispatched,
            "finished": self.tasks_finished,
            "peak_queue": self.peak_queue,
            "queue": self.queue_stat.summary(),
            "allocations": self.alloc_stat.count,
            "executors_acquired": self.alloc_stat.total,
            "executors": len(self.executors),
        }
        if self.data_layer is not None:
            m["parked"] = self._parked
            m["data"] = self.data_layer.metrics()
        if self.pool is not None and hasattr(self.pool, "metrics"):
            # real path: surface the pool's measured io/run/bundle stats
            # (e.g. DeviceExecutorPool's device_s / bundle_size summaries)
            m["pool"] = self.pool.metrics()
        return m
