"""Falkon: fast, lightweight task execution service (paper §4).

Multi-level scheduling: *resource provisioning* (DRP acquires executors,
paying the batch-scheduler allocation latency once) is decoupled from *task
dispatch* (streamlined, O(1), ~ms per task).  Executors register with the
service; queued tasks are dispatched to idle executors; DRP grows/shrinks the
pool on queue pressure; hosts with repeated failures are suspended
("stale NFS handle" handling, §3.12).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Optional

from repro.core.simclock import Clock


@dataclasses.dataclass
class DRPConfig:
    min_executors: int = 0
    max_executors: int = 64
    alloc_latency: float = 81.0      # GRAM4+PBS allocation latency (paper §5.4.3)
    alloc_chunk: int = 32            # executors acquired per allocation
    idle_timeout: float = 300.0      # de-register idle executors
    queue_per_executor: float = 1.0  # grow when queue > this x executors


@dataclasses.dataclass
class FalkonConfig:
    dispatch_overhead: float = 1.0 / 487.0   # paper: 487 tasks/s streamlined
    drp: DRPConfig = dataclasses.field(default_factory=DRPConfig)
    host_fail_threshold: int = 2
    host_suspend_time: float = 60.0


class Executor:
    __slots__ = ("id", "host", "busy", "suspended_until", "consec_failures",
                 "idle_since", "busy_time", "tasks_done", "registered_at",
                 "task_log")

    def __init__(self, eid: int, host: str, now: float):
        self.id = eid
        self.host = host
        self.busy = False
        self.suspended_until = 0.0
        self.consec_failures = 0
        self.idle_since = now
        self.busy_time = 0.0
        self.tasks_done = 0
        self.registered_at = now
        self.task_log: list = []   # (start, end) per task, for Fig 18 views


class FalkonService:
    """Web-services interface -> in-process API (see DESIGN.md §2)."""

    def __init__(self, clock: Clock, config: FalkonConfig | None = None,
                 name: str = "falkon"):
        self.clock = clock
        self.cfg = config or FalkonConfig()
        self.name = name
        self.queue: deque = deque()
        self.executors: list[Executor] = []
        self._idle: deque = deque()   # O(1) dispatch: idle-executor pool
        self._next_eid = 0
        self._allocating = 0
        self._dispatch_busy_until = 0.0
        # metrics
        self.peak_queue = 0
        self.dispatched = 0
        self.queue_len_log: list = []
        self.alloc_log: list = []

    # ------------------------------------------------------------------
    # resource provisioning (DRP)
    # ------------------------------------------------------------------
    def provision(self, n: int):
        """Explicitly acquire n executors (paying allocation latency once)."""
        self._allocate(n)

    def _allocate(self, n: int):
        n = min(n, self.cfg.drp.max_executors - len(self.executors)
                - self._allocating)
        if n <= 0:
            return
        self._allocating += n
        self.alloc_log.append((self.clock.now(), n))

        def arrive():
            self._allocating -= n
            for _ in range(n):
                e = Executor(self._next_eid, f"{self.name}-host{self._next_eid}",
                             self.clock.now())
                self._next_eid += 1
                self.executors.append(e)
                self._idle.append(e)
            self._pump()

        self.clock.schedule(self.cfg.drp.alloc_latency, arrive)

    def _maybe_grow(self):
        d = self.cfg.drp
        have = len(self.executors) + self._allocating
        if have >= d.max_executors:
            return
        if len(self.queue) > d.queue_per_executor * max(1, have) or have == 0:
            want = min(d.alloc_chunk, len(self.queue) - have + 1)
            self._allocate(max(1, want))

    def _maybe_shrink(self):
        d = self.cfg.drp
        now = self.clock.now()
        drop = set()
        for e in self.executors:
            if (not e.busy and len(self.executors) - len(drop) >
                    d.min_executors
                    and now - e.idle_since > d.idle_timeout
                    and not self.queue):
                drop.add(e.id)  # de-register (paper: idle auto-deregistration)
        if drop:
            self.executors = [e for e in self.executors if e.id not in drop]
            self._idle = deque(e for e in self._idle if e.id not in drop)

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    def submit(self, task, when_done: Callable):
        task._falkon_done = when_done
        task.submit_time = self.clock.now()
        self.queue.append(task)
        self.peak_queue = max(self.peak_queue, len(self.queue))
        self._maybe_grow()
        self._pump()

    def _idle_executor(self) -> Optional[Executor]:
        now = self.clock.now()
        skipped = []
        found = None
        while self._idle:
            e = self._idle.popleft()
            if e.busy:
                continue  # stale entry
            if now < e.suspended_until:
                skipped.append(e)  # suspended: back of the pool
                continue
            found = e
            break
        self._idle.extend(skipped)
        if found is None and skipped:
            # everyone suspended: retry when the first suspension lapses
            wake = min(e.suspended_until for e in skipped)
            self.clock.schedule(max(0.0, wake - now) + 1e-9, self._pump)
        return found

    def _pump(self):
        now = self.clock.now()
        self.queue_len_log.append((now, len(self.queue)))
        while self.queue:
            e = self._idle_executor()
            if e is None:
                break
            task = self.queue.popleft()
            self._dispatch(e, task)

    def _dispatch(self, e: Executor, task):
        e.busy = True
        self.dispatched += 1
        overhead = self.cfg.dispatch_overhead
        start = self.clock.now() + overhead
        task.start_time = start
        task.host = e.host

        def finish():
            ok, value, err = _execute(task)
            end = self.clock.now()
            e.task_log.append((start, end))
            e.busy = False
            e.idle_since = end
            e.busy_time += max(0.0, end - start)
            if ok:
                e.consec_failures = 0
                e.tasks_done += 1
            else:
                e.consec_failures += 1
                if e.consec_failures >= self.cfg.host_fail_threshold:
                    # paper §3.12: suspend faulty host, reschedule elsewhere
                    e.suspended_until = end + self.cfg.host_suspend_time
                    e.consec_failures = 0
            self._idle.append(e)
            task._falkon_done(ok, value, err)
            self._maybe_shrink()
            self._pump()

        self.clock.schedule(overhead + _sim_duration(task), finish)

    # ------------------------------------------------------------------
    def utilization(self) -> dict:
        now = self.clock.now()
        total_busy = sum(e.busy_time for e in self.executors)
        total_alive = sum(now - e.registered_at for e in self.executors)
        return {
            "executors": len(self.executors),
            "dispatched": self.dispatched,
            "peak_queue": self.peak_queue,
            "busy_time": total_busy,
            "alive_time": total_alive,
            "efficiency": total_busy / total_alive if total_alive else 0.0,
        }


def _sim_duration(task) -> float:
    d = getattr(task, "duration", None)
    return float(d) if d else 0.0


def _execute(task):
    chk = getattr(task, "fault_check", None)
    if chk is not None:
        try:
            chk(task)
        except BaseException as err:  # noqa: BLE001
            return False, None, err
    fn = getattr(task, "fn", None)
    if fn is None:
        return True, getattr(task, "sim_value", None), None
    try:
        args = [a.get() if hasattr(a, "get") and hasattr(a, "on_done") else a
                for a in task.args]
        return True, fn(*args), None
    except BaseException as err:  # noqa: BLE001 - engine handles retries
        return False, None, err
