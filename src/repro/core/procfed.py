"""Process-per-shard federation: one OS process per dispatcher
(DESIGN.md §14).

The in-process federation (§8) multiplies *dispatchers* — N engines, N
Falkon services — but they all share one Python interpreter, so on the
real execution path (`RealClock` + `ThreadExecutorPool`) every shard's
clock thread and worker threads contend for one GIL.  This module runs
each shard as its own process — a full `Engine` + `RealClock` + worker
pool built from a picklable `ShardSpec` recipe — with the existing
`Mailbox` crossing the boundary through a `ProcessTransport`: the
parent keeps the driver-side `DataFuture`s, every message is a small
pickle-safe tuple, and a reader thread per boundary drains receive
bursts onto the consumer's clock thread in one `post_many` wakeup.

Topology: the parent is the hub.  It routes each submission by the
partitioner, encodes pending-future arguments as `Ref(fid)` markers,
and registers a *forward* for every Ref it ships — when the producing
fid resolves, the parent fans a ``("resolve", ...)`` envelope out to
every shard that ever received a Ref for it.  Per-pipe FIFO ordering
then gives the fence invariant: a resolve envelope always arrives
*after* every Ref for its fid, so a shard can drop its local handle the
moment the envelope lands.

Work stealing is parent-coordinated (the parent is the only place the
global load vector exists): an idle shard triggers a ``("steal", ...)``
request to a victim chosen by the same load/directory policies as the
in-process `WorkStealer`; the victim re-encodes up to half its held
ready queue — all arguments already resolved, so the envelopes carry
raw values — and the parent re-submits the batch to the thief.  The
``"directory"`` policy prices victims against a parent-side replica of
each shard's `ShardDirectory` (kept fresh by ``("dir", ...)`` deltas),
preferring the victim whose sampled in-flight inputs the thief would
re-stage least.

Failure contract: a shard process that dies mid-run surfaces as EOF on
its boundary; the parent fails that shard's in-flight futures with
``TaskFailure(kind="host")``, emits a ``shard_death`` tracer event (so
a `HealthMonitor` subscribed to the tracer sees it), routes new work to
the surviving shards, and `run()` terminates instead of hanging.
"""
from __future__ import annotations

import itertools
import pickle
import socket
import struct
import threading
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

from repro.core.datastore import (DataLayer, ShardDirectory, SharedStore,
                                  StagingCostModel, inputs_of)
from repro.core.engine import Engine
from repro.core.falkon import DRPConfig, FalkonConfig, FalkonService
from repro.core.faults import RetryPolicy, TaskFailure
from repro.core.federation import Mailbox, MailboxTransport, hash_partitioner
from repro.core.futures import DataFuture
from repro.core.metrics import StreamStat
from repro.core.observability import RunReport, Tracer, build_report
from repro.core.providers import FalkonProvider
from repro.core.realpool import ThreadExecutorPool
from repro.core.simclock import RealClock

__all__ = ["Ref", "ShardSpec", "ProcessTransport", "SocketTransport",
           "ShardHost", "ProcessFederation"]


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

class Ref:
    """Pickle-safe marker for a not-yet-resolved argument crossing the
    process boundary: the parent substitutes ``Ref(fid)`` for a pending
    `DataFuture` and later ships a ``("resolve", ...)`` envelope carrying
    the fid's value (or error).  Shards decode a Ref into a local future
    registered with their `Mailbox` under the same fid."""

    __slots__ = ("fid",)

    def __init__(self, fid: int):
        self.fid = fid

    def __reduce__(self):
        return (Ref, (self.fid,))

    def __eq__(self, other):
        return type(other) is Ref and other.fid == self.fid

    def __hash__(self):
        return hash(("Ref", self.fid))

    def __repr__(self):
        return f"Ref({self.fid})"


@dataclass
class ShardSpec:
    """Picklable build recipe for one shard process.

    The child cannot receive live objects (engines, clocks, pools do not
    pickle), so the parent ships this declarative spec and the child's
    `ShardHost` builds the stack from it: `RealClock`, `Engine`
    (summary provenance), autoscaling `ThreadExecutorPool`, and one
    `FalkonService` site.  ``cache_capacity=None`` skips the data layer;
    otherwise every shard pre-declares ``shared_files`` (name, size)
    pairs in its own `SharedStore` replica and streams holder-map
    deltas back to the parent for directory-guided stealing.
    """

    executors: int = 4
    serialize_dispatch: bool = False
    dispatch_overhead: float = 1.0 / 487.0
    alloc_latency: float = 1e-3
    cache_capacity: float | None = None
    policy: str = "lru"
    shared_files: tuple = ()
    trace_sample: int = 0
    engine_kwargs: dict = field(default_factory=dict)


# -- module-level task bodies (spawn-context children can only unpickle
#    importable callables, so tests and benchmarks use these) ---------------

def body_sleep(seconds: float = 0.001) -> float:
    """Latency-bound task body: sleep and return the duration."""
    time.sleep(seconds)
    return seconds


def body_value(v):
    """Identity task body."""
    return v


def body_scale(v, k: float = 2):
    """Multiply-by-constant task body."""
    return v * k


def body_sum(*vals):
    """Sum task body (stage-3 joins in the MolDyn-shaped tests)."""
    total = 0
    for v in vals:
        total += v
    return total


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

class ProcessTransport(MailboxTransport):
    """`MailboxTransport` over a duplex connection to another process.

    ``conn`` is anything with the `multiprocessing.Connection` subset
    ``send/recv/poll/close`` — a real pipe `Connection`, or `_SockConn`
    for the socket-framed variant.  Sends are locked (producers include
    worker callbacks and the driver thread) and count under the lock;
    receives run on a dedicated daemon reader thread that batches a
    burst of available messages and hands the whole burst to the
    consumer's clock thread in one `Clock.post_many` wakeup — one lock
    acquisition and one condition-variable notify per burst, not per
    message.  ``("resolve", ...)`` messages route to the bound
    `Mailbox._deliver`; everything else goes to the ``dispatch``
    callback given to `start`.
    """

    BURST = 256

    def __init__(self, conn):
        self._conn = conn
        self._wlock = threading.Lock()
        self._clock = None
        self._deliver: Callable | None = None
        self._reader: threading.Thread | None = None
        self.closed = False
        self.sends = 0
        self.recvs = 0
        self.drains = 0

    def bind(self, clock, deliver: Callable) -> None:
        self._clock = clock
        self._deliver = deliver

    def send(self, msg) -> None:
        # pickling errors propagate (the connection stays clean — both
        # pipe Connections and _SockConn serialize fully before writing),
        # so callers can retry with a sanitized payload; a *broken*
        # connection just marks the transport closed and the reader's
        # EOF handles the rest
        with self._wlock:
            if self.closed:
                return
            try:
                self._conn.send(msg)
                self.sends += 1
            except (OSError, ValueError):
                self.closed = True

    def start(self, dispatch: Callable, on_eof: Callable) -> None:
        """Launch the boundary reader thread (after `bind`)."""
        self._reader = threading.Thread(
            target=self._read_loop, args=(dispatch, on_eof),
            daemon=True, name="procfed-reader")
        self._reader.start()

    def _read_loop(self, dispatch: Callable, on_eof: Callable) -> None:
        conn = self._conn
        clock = self._clock
        deliver = self._deliver
        while True:
            try:
                burst = [conn.recv()]
            except (EOFError, OSError):
                clock.post(on_eof)
                return
            try:
                while len(burst) < self.BURST and conn.poll(0):
                    burst.append(conn.recv())
            except (EOFError, OSError):
                pass                    # deliver what we have; EOF next recv
            self.recvs += len(burst)
            self.drains += 1
            fns = []
            for m in burst:
                if m[0] == "resolve" and deliver is not None:
                    fns.append(partial(deliver, m[1]))
                else:
                    fns.append(partial(dispatch, m))
            clock.post_many(fns)

    def close(self) -> None:
        with self._wlock:
            self.closed = True
            try:
                self._conn.close()
            except OSError:
                pass

    def metrics(self) -> dict:
        return {"sends": self.sends, "recvs": self.recvs,
                "drains": self.drains, "closed": self.closed}


class _SockConn:
    """Length-prefixed pickle framing over a stream socket, exposing the
    `Connection` subset `ProcessTransport` needs (send/recv/poll/close).
    Frames are ``!I`` byte-length headers followed by the pickle; a
    frame is fully serialized before any byte is written, so a pickling
    error never corrupts the stream."""

    _HDR = struct.Struct("!I")

    def __init__(self, sock: socket.socket):
        self._sock = sock

    def send(self, obj) -> None:
        data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        self._sock.sendall(self._HDR.pack(len(data)) + data)

    def _read_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise EOFError("socket closed")
            buf.extend(chunk)
        return bytes(buf)

    def recv(self):
        n, = self._HDR.unpack(self._read_exact(self._HDR.size))
        return pickle.loads(self._read_exact(n))

    def poll(self, timeout: float = 0.0) -> bool:
        import select
        r, _, _ = select.select([self._sock], [], [], timeout)
        return bool(r)

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


class SocketTransport(ProcessTransport):
    """`ProcessTransport` over a TCP socket instead of a pipe — the
    framing `_SockConn` provides, same reader/burst/post_many delivery.
    Lets shard processes live on other hosts in principle; the federation
    uses loopback (``transport="socket"``) and identifies each inbound
    connection by its first ``("ready", shard_id)`` frame."""

    def __init__(self, sock: socket.socket):
        super().__init__(_SockConn(sock))


# ---------------------------------------------------------------------------
# child side
# ---------------------------------------------------------------------------

def _shard_main(shard_id: int, spec: ShardSpec, endpoint) -> None:
    """Child-process entry point (the spawn target): build one shard from
    its spec and serve until the parent says shutdown (or disappears)."""
    ShardHost(shard_id, spec, endpoint).serve()


class ShardHost:
    """One shard process: a full engine stack plus the boundary protocol.

    Owns the child's `RealClock`, `Engine`, `ThreadExecutorPool`,
    `FalkonService` site, optional `DataLayer`, and the `Mailbox` whose
    transport is the pipe/socket back to the parent.  Duck-types the
    federation surface the engine's O(1) hooks expect
    (`notify_backlog` / `notify_idle` / `_bp_waiters` /
    `_wake_backpressure`), reporting load to the parent instead of
    poking a local stealer.  The host takes one permanent clock hold (the
    service token) so `Clock.run` idles between messages instead of
    exiting; ``("shutdown",)`` releases it.
    """

    def __init__(self, shard_id: int, spec: ShardSpec, endpoint):
        self.shard_id = shard_id
        self.spec = spec
        if endpoint[0] == "pipe":
            conn = endpoint[1]
        elif endpoint[0] == "tcp":
            sock = socket.create_connection((endpoint[1], endpoint[2]))
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _SockConn(sock)
        else:
            raise ValueError(f"unknown endpoint {endpoint[0]!r}")
        self.clock = RealClock()
        self.transport = ProcessTransport(conn)
        self.mailbox = Mailbox(self.clock, shard_id,
                               transport=self.transport)
        # the mailbox bound the transport to its own _deliver; re-bind to
        # the host hook so resolve envelopes also retire `_refs` entries
        # (the envelope is the fence — no more Refs for that fid can come)
        self.transport.bind(self.clock, self._on_resolve)
        self.tracer = (Tracer(sample_every=spec.trace_sample)
                       if spec.trace_sample > 0 else None)
        self.shared: SharedStore | None = None
        self.dl: DataLayer | None = None
        if spec.cache_capacity is not None:
            self.shared = SharedStore()
            for name, size in spec.shared_files:
                self.shared.file(name, size)
            self.dl = DataLayer(self.shared, StagingCostModel(),
                                cache_capacity=spec.cache_capacity,
                                policy=spec.policy)
            self.dl.shard_id = shard_id
            self.dl.directory = ShardDirectory(on_change=self._dir_change)
        kw = {"provenance": "summary", "tracer": self.tracer}
        kw.update(spec.engine_kwargs)
        self.eng = Engine(self.clock, **kw)
        self.pool = ThreadExecutorPool(self.clock, name=f"shard{shard_id}",
                                       tracer=self.tracer)
        self.svc = FalkonService(
            self.clock,
            FalkonConfig(dispatch_overhead=spec.dispatch_overhead,
                         serialize_dispatch=spec.serialize_dispatch,
                         drp=DRPConfig(max_executors=spec.executors,
                                       alloc_latency=spec.alloc_latency,
                                       alloc_chunk=spec.executors)),
            name=f"falkon{shard_id}", data_layer=self.dl, pool=self.pool,
            tracer=self.tracer)
        self.eng.add_site(f"falkon{shard_id}", FalkonProvider(self.svc),
                          capacity=spec.executors, data_layer=self.dl)
        self.eng.shard_id = shard_id
        self.eng._federation = self
        self.eng._hold_excess = True       # keep excess ready work stealable
        # boundary bookkeeping
        self._refs: dict[int, DataFuture] = {}    # fid -> local future
        self._owned: set[int] = set()             # fids this shard reports
        self._fid_by_out: dict[int, int] = {}     # local out.id -> fid
        self._done_batch: list = []
        self._done_flush = False
        self._dir_batch: list = []
        self._dir_flush = False
        self._load_flush = False
        self._stopping = False
        self._bp_waiters: list = []               # engine reads this directly

    # -- engine federation hooks (all O(1)) -----------------------------
    def notify_backlog(self, eng) -> None:
        self._queue_load()

    def notify_idle(self, eng) -> None:
        self._queue_load()

    def _wake_backpressure(self) -> None:
        if self._bp_waiters:
            waiters, self._bp_waiters = self._bp_waiters, []
            for cb in waiters:
                cb()

    def _queue_load(self) -> None:
        if not self._load_flush:
            self._load_flush = True
            self.clock.schedule(0.0, self._send_load)

    def _send_load(self) -> None:
        self._load_flush = False
        self.transport.send(
            ("load", len(self.eng._pending),
             self.eng.balancer.idle_slots(self.clock.now())))

    # -- serve loop -----------------------------------------------------
    def serve(self) -> None:
        self.clock.hold()                 # service token: idle != finished
        self.transport.start(self._on_msg, self._on_eof)
        self.transport.send(("ready", self.shard_id))
        self.clock.run()
        self.svc.shutdown()
        try:
            self.transport.send(("stats", self.stats_snapshot()))
        except Exception:
            pass                          # parent already gone: exit quietly
        self.transport.close()

    def _on_eof(self) -> None:
        # parent died (or closed the boundary): release the service token
        # so the run loop drains in-flight work and exits
        if not self._stopping:
            self._stopping = True
            self.clock.release()

    # -- message handling (clock thread) --------------------------------
    def _on_msg(self, msg) -> None:
        tag = msg[0]
        if tag == "submit":
            for env in msg[1]:
                self._submit_env(env)
        elif tag == "steal":
            self._steal(msg[1], msg[2])
        elif tag == "drop":
            for fid in msg[1]:
                self._refs.pop(fid, None)
        elif tag == "shutdown":
            if not self._stopping:
                self._stopping = True
                self.clock.release()

    def _on_resolve(self, envs: list) -> None:
        for env in envs:
            self._refs.pop(env[0], None)  # the fence: no more Refs for fid
        self.mailbox._deliver(envs)

    def _submit_env(self, env) -> None:
        fid, name, fn, args, duration, app, key, inputs = env
        dargs = []
        for a in args:
            if type(a) is Ref:
                f = self._refs.get(a.fid)
                if f is None:
                    f = DataFuture(name=f"ref{a.fid}")
                    self._refs[a.fid] = f
                    self.mailbox.register_proxy(a.fid, f)
                dargs.append(f)
            else:
                dargs.append(a)
        objs = None
        if inputs and self.shared is not None:
            objs = tuple(self.shared.file(n, s) for n, s in inputs)
        out = self.eng.submit(name, fn, dargs, duration=duration, app=app,
                              key=key, inputs=objs)
        self._refs[fid] = out
        self._owned.add(fid)
        self._fid_by_out[out.id] = fid
        out.on_done(partial(self._task_done, fid))

    def _task_done(self, fid: int, fut: DataFuture) -> None:
        self._fid_by_out.pop(fut.id, None)
        if fid not in self._owned:
            return                        # stolen away: the thief reports it
        self._owned.discard(fid)
        if fut.failed:
            self._done_batch.append((fid, False, fut._error))
        else:
            self._done_batch.append((fid, True, fut.get()))
        if not self._done_flush:
            self._done_flush = True
            self.clock.schedule(0.0, self._flush_done)

    def _flush_done(self) -> None:
        self._done_flush = False
        batch, self._done_batch = self._done_batch, []
        if not batch:
            return
        backlog = len(self.eng._pending)
        idle = self.eng.balancer.idle_slots(self.clock.now())
        try:
            self.transport.send(("done", batch, backlog, idle))
        except Exception:
            # some payload refused to pickle: degrade just that task to a
            # TaskFailure instead of killing the shard
            safe = []
            for fid, ok, payload in batch:
                try:
                    pickle.dumps(payload)
                    safe.append((fid, ok, payload))
                except Exception:
                    safe.append((fid, False, TaskFailure(
                        f"unpicklable task payload: {payload!r:.120}")))
            self.transport.send(("done", safe, backlog, idle))

    def _steal(self, req_id: int, n: int) -> None:
        batch = self.eng._pending.steal(n) if n > 0 else []
        envs = []
        for task, _excl in batch:
            fid = self._fid_by_out.pop(task.output.id, None)
            if fid is None:               # not parent-tracked: run it here
                self.eng._dispatch(task)
                continue
            self._owned.discard(fid)
            # local dependents keep resolving when the thief's result is
            # forwarded back through the mailbox
            self.mailbox.register_proxy(fid, task.output)
            values = [a.get() if isinstance(a, DataFuture) else a
                      for a in task.args]
            envs.append((fid, task.name, task.fn, values, task.duration,
                         task.app, task.key,
                         tuple((o.name, o.size)
                               for o in (task.inputs or ()))))
        self.transport.send(("stolen", req_id, envs,
                             len(self.eng._pending)))

    def _dir_change(self, op: str, name: str, shard: int) -> None:
        self._dir_batch.append((op, name))
        if not self._dir_flush:
            self._dir_flush = True
            self.clock.schedule(0.0, self._flush_dir)

    def _flush_dir(self) -> None:
        self._dir_flush = False
        batch, self._dir_batch = self._dir_batch, []
        if batch:
            self.transport.send(("dir", batch))

    def stats_snapshot(self) -> dict:
        """Picklable end-of-run telemetry the parent merges (§14)."""
        return {
            "shard": self.shard_id,
            "tasks_completed": self.eng.tasks_completed,
            "tasks_failed": self.eng.tasks_failed,
            "pool": self.pool.stats_snapshot(),
            "mailbox": self.mailbox.metrics(),
            "transport": self.transport.metrics(),
            "tracer": self.tracer.snapshot() if self.tracer else None,
            "data": self.dl.metrics() if self.dl else None,
        }


# ---------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------

class ProcessFederation:
    """Drive one workflow over N shard *processes* (DESIGN.md §14).

    Duck-types the `Engine` surface the DSL uses (`submit`, `run`,
    `clock`, aggregate counters), like `FederatedEngine`, but each shard
    is an OS process built from a `ShardSpec`, so N dispatchers means N
    GILs on the real execution path.  The parent owns every driver-side
    `DataFuture` and one clock hold per in-flight task — `run()` returns
    exactly when all submitted work has resolved (completed, failed, or
    failed-over after a shard death).

    Example::

        fed = ProcessFederation(4, ShardSpec(executors=2))
        futs = [fed.submit("t", body_sleep, [0.001]) for _ in range(1000)]
        fed.run()
        fed.shutdown()                    # collects per-shard telemetry

    ``transport="pipe"`` (default) uses multiprocessing pipes;
    ``"socket"`` uses length-prefixed frames over loopback TCP.  Steal
    coordination is parent-side with the same ``victim_policy`` choices
    as `WorkStealer` (``"load"`` / ``"directory"``).
    """

    def __init__(self, n_shards: int, spec: ShardSpec | None = None,
                 clock: RealClock | None = None,
                 partitioner: Callable[[str, int], int] | None = None,
                 steal: bool = True, victim_policy: str = "load",
                 min_batch: int = 2, max_batch: int = 4096,
                 transport: str = "pipe", tracer: Tracer | None = None,
                 mp_context: str = "spawn",
                 retry_policy: RetryPolicy | None = None):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        if victim_policy not in ("load", "directory"):
            raise ValueError(f"unknown victim_policy {victim_policy!r}")
        if transport not in ("pipe", "socket"):
            raise ValueError(f"unknown transport {transport!r}; "
                             f"expected 'pipe' or 'socket'")
        self.clock = clock or RealClock()
        if not getattr(self.clock, "threadsafe_post", False):
            raise ValueError("ProcessFederation needs a thread-safe clock "
                             "(RealClock); SimClock runs stay in-process")
        self.n_shards = n_shards
        self.spec = spec or ShardSpec()
        self.partitioner = partitioner or hash_partitioner
        self._partition_on_inputs = getattr(self.partitioner,
                                            "wants_inputs", False)
        self.steal = steal
        self.victim_policy = victim_policy
        self.min_batch = max(1, min_batch)
        self.max_batch = max_batch
        self.tracer = tracer or Tracer(sample_every=64)
        # driver-side bookkeeping (all clock-thread or pre-run only)
        self.tasks_submitted = 0
        self._completed = 0
        self._failed = 0
        self._per_shard_completed = [0] * n_shards
        self.cross_shard_edges = 0
        self._futs: dict[int, DataFuture] = {}       # fid -> driver future
        self._fid_shard: dict[int, int] = {}         # fid -> owning shard
        # shard-death failover (DESIGN.md §14/§15): with a retry budget,
        # tasks lost to a dead shard are re-encoded from their retained
        # raw submit context and re-routed to a surviving shard instead
        # of failing the workflow.  `max_retries=0` restores fail-fast
        # (and skips the retention entirely — no extra memory).
        self.retry_policy = retry_policy or RetryPolicy()
        self._raw: dict[int, tuple] = {}             # fid -> submit context
        self._retries: dict[int, int] = {}           # fid -> failovers used
        self.tasks_failed_over = 0
        self._fwd: dict[int, set[int]] = {}          # fid -> Ref'd shards
        self._inflight_inputs = [dict() for _ in range(n_shards)]
        self._dir = ShardDirectory()                 # parent replica
        self._load = [(0, self.spec.executors)] * n_shards
        self._dead: set[int] = set()
        self._ready_shards: set[int] = set()
        self._await_ready = False
        self._closing = False
        self._stats: dict[int, dict] = {}
        self._stats_pending: set[int] = set()
        # parent-coordinated stealing
        self._steal_reqs: dict[int, tuple[int, int]] = {}
        self._steal_busy: set[int] = set()           # victims mid-request
        self._req_counter = itertools.count(1)
        self.steals = 0
        self.tasks_stolen = 0
        self.restage_bytes_est = 0.0
        self.batch_stat = StreamStat(cap=256)        # tasks per steal batch
        self.restage_stat = StreamStat(cap=256)      # restage bytes/batch
        # per-shard outboxes, flushed one pipe write per clock drain
        self._ob_submit = [[] for _ in range(n_shards)]
        self._ob_resolve = [[] for _ in range(n_shards)]
        self._ob_drop = [[] for _ in range(n_shards)]
        self._ob_flush = [False] * n_shards
        self._transports: list[Optional[ProcessTransport]] = \
            [None] * n_shards
        self._pre_attach: list[list] = [[] for _ in range(n_shards)]
        self._procs: list = []
        self._listener = None
        self._spawn(transport, mp_context)

    # -- process bring-up ----------------------------------------------
    def _spawn(self, transport: str, mp_context: str) -> None:
        import multiprocessing as mp
        ctx = mp.get_context(mp_context)
        if transport == "pipe":
            for i in range(self.n_shards):
                parent_conn, child_conn = ctx.Pipe(duplex=True)
                t = ProcessTransport(parent_conn)
                t.bind(self.clock, None)
                self._transports[i] = t
                p = ctx.Process(target=_shard_main,
                                args=(i, self.spec, ("pipe", child_conn)),
                                daemon=True, name=f"shard{i}")
                p.start()
                child_conn.close()
                t.start(partial(self._on_msg, i), partial(self._on_eof, i))
                self._procs.append(p)
        else:
            self._listener = socket.create_server(("127.0.0.1", 0))
            host, port = self._listener.getsockname()
            for i in range(self.n_shards):
                p = ctx.Process(target=_shard_main,
                                args=(i, self.spec, ("tcp", host, port)),
                                daemon=True, name=f"shard{i}")
                p.start()
                self._procs.append(p)
            threading.Thread(target=self._accept_loop, daemon=True,
                             name="procfed-accept").start()

    def _accept_loop(self) -> None:
        # inbound sockets identify themselves with their first frame; the
        # attach itself happens on the clock thread
        for _ in range(self.n_shards):
            try:
                sock, _addr = self._listener.accept()
            except OSError:
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _SockConn(sock)
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                continue
            if msg[0] != "ready":
                conn.close()
                continue
            self.clock.post(partial(self._attach, msg[1],
                                    ProcessTransport(conn)))

    def _attach(self, sid: int, t: ProcessTransport) -> None:
        t.bind(self.clock, None)
        self._transports[sid] = t
        t.start(partial(self._on_msg, sid), partial(self._on_eof, sid))
        self._ready_shards.add(sid)
        self._check_ready()
        pre, self._pre_attach[sid] = self._pre_attach[sid], []
        for m in pre:
            t.send(m)

    # -- outbox ---------------------------------------------------------
    def _send(self, sid: int, msg) -> None:
        t = self._transports[sid]
        if t is None:
            self._pre_attach[sid].append(msg)
        else:
            t.send(msg)

    def _schedule_flush(self, sid: int) -> None:
        if not self._ob_flush[sid]:
            self._ob_flush[sid] = True
            self.clock.schedule(0.0, partial(self._flush_outbox, sid))

    def _flush_outbox(self, sid: int) -> None:
        # submits before resolves before drops: a resolve/drop only exists
        # once its fid resolved driver-side, after which no submit carries
        # a Ref for it — so this grouping preserves the fence invariant
        self._ob_flush[sid] = False
        if sid in self._dead:
            self._ob_submit[sid].clear()
            self._ob_resolve[sid].clear()
            self._ob_drop[sid].clear()
            return
        if self._ob_submit[sid]:
            batch, self._ob_submit[sid] = self._ob_submit[sid], []
            self._send(sid, ("submit", batch))
        if self._ob_resolve[sid]:
            batch, self._ob_resolve[sid] = self._ob_resolve[sid], []
            self._send(sid, ("resolve", batch))
        if self._ob_drop[sid]:
            batch, self._ob_drop[sid] = self._ob_drop[sid], []
            self._send(sid, ("drop", batch))

    # -- submit ---------------------------------------------------------
    def submit(self, name: str, fn=None, args: list | None = None,
               duration: float | None = None, app: str | None = None,
               durable: bool = False, key: str | None = None,
               vmap_key=None, inputs=None) -> DataFuture:
        """Engine-compatible submit.  `fn` and literal args must pickle
        (same contract as `ProcessExecutorPool`); pending-future args are
        encoded as `Ref` markers and resolved cross-process.  `durable`
        and `vmap_key` are accepted for signature compatibility but have
        no process-shard implementation yet."""
        args = args or []
        if key is None:
            key = f"{name}#{self.tasks_submitted}"
        self.tasks_submitted += 1
        tin = ()
        if inputs is not None:
            tin = inputs if type(inputs) is tuple \
                else inputs_of(inputs, *args)
        if self._partition_on_inputs:
            shard = self.partitioner(key, self.n_shards, tin)
        else:
            shard = self.partitioner(key, self.n_shards)
        shard = self._route(shard)
        out = DataFuture(name=name)
        if shard is None:
            self._failed += 1
            out.set_error(TaskFailure("no live shard", kind="host"))
            return out
        fid = out.id
        enc, failed_up = self._encode_args(args, shard)
        if failed_up is not None:
            self._failed += 1
            out.set_error(failed_up)
            return out
        env = (fid, name, fn, enc, duration, app, key,
               tuple((o.name, o.size) for o in tin))
        self._futs[fid] = out
        self._fid_shard[fid] = shard
        if self.retry_policy.max_retries > 0:
            # retain the raw submit context (live future args included) so
            # a shard death can re-encode against the survivors' view
            self._raw[fid] = (name, fn, args, duration, app, key, tin)
        if tin:
            self._inflight_inputs[shard][fid] = env[7]
        self.clock.hold()
        self._ob_submit[shard].append(env)
        self._schedule_flush(shard)
        return out

    def _encode_args(self, args: list, shard: int):
        """Encode call args for the wire: resolved futures inline their
        value, pending futures become `Ref` markers with a resolve
        forward registered toward `shard`.  Returns (enc, failed_up)."""
        enc = []
        for a in args:
            if isinstance(a, DataFuture):
                if a.done:
                    if a.failed:
                        return None, a._error
                    enc.append(a.get())
                else:
                    tgt = self._fwd.get(a.id)
                    if tgt is None:
                        self._fwd[a.id] = tgt = set()
                        a.on_done(self._forward)
                    if shard not in tgt:
                        tgt.add(shard)
                        if self._fid_shard.get(a.id) != shard:
                            self.cross_shard_edges += 1
                    enc.append(Ref(a.id))
            else:
                enc.append(a)
        return enc, None

    def _route(self, shard: int) -> int | None:
        """Remap a partition target off dead shards, deterministically."""
        if shard not in self._dead:
            return shard
        for k in range(1, self.n_shards):
            cand = (shard + k) % self.n_shards
            if cand not in self._dead:
                return cand
        return None

    def _forward(self, fut: DataFuture) -> None:
        """A fid some shard holds a Ref for just resolved: fan the resolve
        envelope out to every registered shard (the fence message)."""
        targets = self._fwd.pop(fut.id, None)
        if not targets:
            return
        if fut.failed:
            err = fut._error
            try:
                pickle.dumps(err)
            except Exception:
                err = TaskFailure(repr(err))
            env = (fut.id, False, err)
        else:
            env = (fut.id, True, fut.get())
        for sid in targets:
            if sid not in self._dead:
                self._ob_resolve[sid].append(env)
                self._schedule_flush(sid)

    # -- inbound messages (clock thread) --------------------------------
    def _on_msg(self, sid: int, msg) -> None:
        tag = msg[0]
        if tag == "done":
            self._on_done(sid, msg[1], msg[2], msg[3])
        elif tag == "load":
            self._load[sid] = (msg[1], msg[2])
            self._maybe_steal()
        elif tag == "dir":
            for op, name in msg[1]:
                if op == "add":
                    self._dir.add(name, sid)
                else:
                    self._dir.drop(name, sid)
        elif tag == "stolen":
            self._on_stolen(sid, msg[1], msg[2], msg[3])
        elif tag == "ready":
            self._ready_shards.add(sid)
            self._check_ready()
        elif tag == "stats":
            self._stats[sid] = msg[1]
            if sid in self._stats_pending:
                self._stats_pending.discard(sid)
                self.clock.release()

    def _on_done(self, sid: int, batch: list, backlog: int,
                 idle: int) -> None:
        for fid, ok, payload in batch:
            fut = self._futs.pop(fid, None)
            owner = self._fid_shard.pop(fid, sid)
            self._raw.pop(fid, None)
            self._retries.pop(fid, None)
            self._inflight_inputs[owner].pop(fid, None)
            if fut is None:
                continue
            # tell the reporting shard it may retire its local handle,
            # unless a resolve envelope (which also retires it) is due
            targets = self._fwd.get(fid)
            if not targets or sid not in targets:
                self._ob_drop[sid].append(fid)
                self._schedule_flush(sid)
            if ok:
                self._completed += 1
                self._per_shard_completed[sid] += 1
                fut.set(payload)
            else:
                self._failed += 1
                fut.set_error(payload)
            self.clock.release()
        self._load[sid] = (backlog, idle)
        self._maybe_steal()

    # -- steal coordination ---------------------------------------------
    def _maybe_steal(self) -> None:
        if not self.steal or self._closing:
            return
        for thief in range(self.n_shards):
            if thief in self._dead:
                continue
            tb, ti = self._load[thief]
            if tb > 0 or ti <= 0:
                continue
            victim = self._pick_victim(thief)
            if victim is None:
                continue
            vb, vi = self._load[victim]
            n = min(vb // 2, self.max_batch)
            if n < 1:
                continue
            req = next(self._req_counter)
            self._steal_reqs[req] = (victim, thief)
            self._steal_busy.add(victim)
            # optimistic load update so one pass doesn't aim every idle
            # thief at the same victim; the reply re-syncs it
            self._load[victim] = (vb - n, vi)
            self._load[thief] = (n, ti)
            self._send(victim, ("steal", req, n))

    def _pick_victim(self, thief: int) -> int | None:
        cands = [s for s in range((self.n_shards))
                 if s != thief and s not in self._dead
                 and s not in self._steal_busy
                 and self._load[s][0] >= max(self.min_batch, 2)]
        if not cands:
            return None
        if self.victim_policy == "load":
            return max(cands, key=lambda s: self._load[s][0])
        maxload = max(self._load[s][0] for s in cands)
        floor = max(self.min_batch, maxload // 2)
        best, best_rank = None, None
        for s in cands:
            if self._load[s][0] < floor:
                continue
            rank = (self._restage_score(s, thief), -self._load[s][0])
            if best is None or rank < best_rank:
                best, best_rank = s, rank
        return best

    def _restage_score(self, victim: int, thief: int) -> float:
        """Average restage bytes over a bounded sample of the victim's
        most recent in-flight inputs, priced on the directory replica."""
        m = self._inflight_inputs[victim]
        if not m:
            return 0.0
        total, k = 0.0, 0
        for fid in reversed(m):
            for name, size in m[fid]:
                if self._dir.holds(name, victim) \
                        and not self._dir.holds(name, thief):
                    total += size
            k += 1
            if k >= 8:
                break
        return total / k

    def _on_stolen(self, victim: int, req_id: int, envs: list,
                   backlog: int) -> None:
        info = self._steal_reqs.pop(req_id, None)
        self._steal_busy.discard(victim)
        self._load[victim] = (backlog, self._load[victim][1])
        if not envs:
            self._maybe_steal()
            return
        thief = info[1] if info else None
        if thief is None or thief in self._dead:
            thief = self._route(victim)
        if thief is None:
            for env in envs:
                fut = self._futs.pop(env[0], None)
                self._fid_shard.pop(env[0], None)
                self._raw.pop(env[0], None)
                self._retries.pop(env[0], None)
                if fut is not None and not fut.done:
                    self._failed += 1
                    fut.set_error(TaskFailure("no live shard for stolen "
                                              "task", kind="host"))
                    self.clock.release()
            return
        restage = 0.0
        for env in envs:
            fid = env[0]
            self._fid_shard[fid] = thief
            # the victim kept a local handle (its dependents); make sure
            # the thief's resolution is forwarded back to retire it
            tgt = self._fwd.get(fid)
            if tgt is None:
                fut = self._futs.get(fid)
                if fut is not None:
                    self._fwd[fid] = tgt = set()
                    fut.on_done(self._forward)
            if tgt is not None:
                tgt.add(victim)
            if env[7]:
                self._inflight_inputs[victim].pop(fid, None)
                self._inflight_inputs[thief][fid] = env[7]
                for name, size in env[7]:
                    if self._dir.holds(name, victim) \
                            and not self._dir.holds(name, thief):
                        restage += size
            self._ob_submit[thief].append(env)
        self._schedule_flush(thief)
        now = self.clock.now()
        self.steals += 1
        self.tasks_stolen += len(envs)
        self.batch_stat.observe(now, len(envs))
        self.restage_bytes_est += restage
        self.restage_stat.observe(now, restage)
        self.tracer.event("steal", now, len(envs))
        self._maybe_steal()

    # -- shard death -----------------------------------------------------
    def _on_eof(self, sid: int) -> None:
        if self._closing:
            # expected exit; just don't hang stats collection on it
            if sid in self._stats_pending:
                self._stats_pending.discard(sid)
                self.clock.release()
            return
        self._shard_died(sid)

    def _shard_died(self, sid: int) -> None:
        if sid in self._dead:
            return
        self._dead.add(sid)
        self._ready_shards.discard(sid)
        t = self._transports[sid]
        if t is not None:
            t.close()
        self.tracer.event("shard_death", self.clock.now(), 1.0)
        doomed = [fid for fid, s in self._fid_shard.items() if s == sid]
        failed_over = 0
        for fid in doomed:
            # failover first (DESIGN.md §14): within the retry budget and
            # with a surviving shard, re-encode the retained submit context
            # and re-route — the driver future (and its dependents' Refs)
            # carries over; the clock hold from submit stays outstanding.
            if self._resubmit(fid, sid):
                failed_over += 1
                continue
            fut = self._futs.pop(fid, None)
            self._fid_shard.pop(fid, None)
            self._raw.pop(fid, None)
            self._retries.pop(fid, None)
            if fut is not None and not fut.done:
                self._failed += 1
                fut.set_error(TaskFailure(
                    f"shard {sid} process died with task in flight",
                    kind="host"))
                self.clock.release()
        if failed_over:
            self.tasks_failed_over += failed_over
            self.tracer.event("task_failover", self.clock.now(),
                              float(failed_over))
        self._inflight_inputs[sid].clear()
        for req, (victim, thief) in list(self._steal_reqs.items()):
            if victim == sid or thief == sid:
                del self._steal_reqs[req]
                self._steal_busy.discard(victim)
        self._ob_submit[sid].clear()
        self._ob_resolve[sid].clear()
        self._ob_drop[sid].clear()
        self._pre_attach[sid].clear()
        self._load[sid] = (0, 0)
        self._check_ready()
        self._maybe_steal()

    def _resubmit(self, fid: int, dead_sid: int) -> bool:
        """Driver-side re-submission of a task lost to a dead shard,
        bounded by ``retry_policy.max_retries``.  Returns True when the
        task was re-routed; False means the caller should fail it fast
        (no retained context, budget exhausted, no survivor, or an
        upstream dependency has itself failed)."""
        raw = self._raw.get(fid)
        if raw is None:
            return False
        used = self._retries.get(fid, 0)
        if used >= self.retry_policy.max_retries:
            return False
        fut = self._futs.get(fid)
        if fut is None or fut.done:
            return False
        target = self._route(dead_sid)
        if target is None:
            return False
        name, fn, args, duration, app, key, tin = raw
        enc, failed_up = self._encode_args(args, target)
        if failed_up is not None:
            # an upstream failed while this task sat on the dead shard:
            # surface that error, as the shard itself would have
            self._futs.pop(fid, None)
            self._fid_shard.pop(fid, None)
            self._raw.pop(fid, None)
            self._retries.pop(fid, None)
            self._failed += 1
            fut.set_error(failed_up)
            self.clock.release()
            return True  # handled: do not also fail with kind="host"
        self._retries[fid] = used + 1
        self._fid_shard[fid] = target
        env = (fid, name, fn, enc, duration, app, key,
               tuple((o.name, o.size) for o in tin))
        if tin:
            self._inflight_inputs[target][fid] = env[7]
        self._ob_submit[target].append(env)
        self._schedule_flush(target)
        return True

    # -- run / shutdown ---------------------------------------------------
    def _check_ready(self) -> None:
        if self._await_ready and \
                len(self._ready_shards) + len(self._dead) >= self.n_shards:
            self._await_ready = False
            self.clock.release()

    def wait_ready(self) -> None:
        """Block until every shard process has booted and said hello (or
        died trying).  Call before timing a workload so interpreter
        spawn cost stays out of the measured window; call it before the
        first `submit` (it runs the clock loop briefly)."""
        if len(self._ready_shards) + len(self._dead) >= self.n_shards:
            return
        self._await_ready = True
        self.clock.hold()
        self.clock.run()

    def run(self) -> None:
        """Block until every submitted task has resolved (one clock hold
        per in-flight task; shard deaths release theirs by failing)."""
        self.clock.run()

    def shutdown(self, timeout: float = 10.0) -> None:
        """Stop shard processes and collect their telemetry snapshots."""
        if self._closing:
            return
        self._closing = True
        for sid in range(self.n_shards):
            if sid in self._dead:
                continue
            self._flush_outbox(sid)
            self._stats_pending.add(sid)
            self.clock.hold()
            self._send(sid, ("shutdown",))
        if self._stats_pending:
            self.clock.run()               # drains the ("stats", ...) replies
        for p in self._procs:
            p.join(timeout=timeout)
        for p in self._procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=2.0)
        for t in self._transports:
            if t is not None:
                t.close()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        for sid in sorted(self._stats):
            tsnap = self._stats[sid].get("tracer")
            if tsnap:
                self.tracer.merge_snapshot(tsnap)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- aggregates -------------------------------------------------------
    @property
    def tasks_completed(self) -> int:
        return self._completed

    @property
    def tasks_failed(self) -> int:
        return self._failed

    def stats(self) -> dict:
        return {
            "submitted": self.tasks_submitted,
            "completed": self._completed,
            "failed": self._failed,
            "shards": self.n_shards,
            "per_shard_completed": list(self._per_shard_completed),
            "cross_shard_edges": self.cross_shard_edges,
            "failed_over": self.tasks_failed_over,
            "makespan": self.clock.now(),
        }

    def metrics(self) -> dict:
        """Bounded federation snapshot, merged across processes: child
        pool `StreamStat`s fold through `merge`, counters add."""
        io, run = StreamStat(cap=256), StreamStat(cap=256)
        tasks_run = 0
        for snap in self._stats.values():
            p = snap.get("pool") or {}
            tasks_run += p.get("tasks_run", 0)
            if "io_s" in p:
                io.merge(StreamStat.from_snapshot(p["io_s"]))
            if "run_s" in p:
                run.merge(StreamStat.from_snapshot(p["run_s"]))
        return {
            "shards": self.n_shards,
            "dead_shards": sorted(self._dead),
            "submitted": self.tasks_submitted,
            "completed": self._completed,
            "failed": self._failed,
            "failed_over": self.tasks_failed_over,
            "cross_shard_edges": self.cross_shard_edges,
            "stealer": {
                "victim_policy": self.victim_policy,
                "steals": self.steals,
                "tasks_stolen": self.tasks_stolen,
                "restage_bytes_est": self.restage_bytes_est,
                "batch": self.batch_stat.summary(),
                "restage_per_batch": self.restage_stat.summary(),
            },
            "pool": {"tasks_run": tasks_run, "io_s": io.summary(),
                     "run_s": run.summary()},
            "transports": [t.metrics() if t is not None else None
                           for t in self._transports],
            "directory_objects": len(self._dir),
        }

    def report(self) -> RunReport:
        """`RunReport` over the parent tracer after child snapshots were
        merged in `shutdown` (exact counters and event totals are
        federation-wide; sampled spans stay per-process)."""
        return build_report(self.tracer, makespan=self.clock.now())
