"""Durable workflow service (DESIGN.md §15) — multi-tenant submission +
crash recovery over the existing engine.

`WorkflowService` is the thin layer that turns "one in-memory engine"
into "a system many users submit to and a crash cannot erase":

  * every task's status transitions are journaled through the engine's
    `journal` hook into a sqlite `JobStore` (WAL mode, batched writes off
    the hot path);
  * each `open()` returns a `WorkflowHandle` whose procedures submit
    under a ``<wf_id>::``-prefixed dataflow-stable key and under the
    tenant's app bucket, so per-app fair share (stride-scheduled
    `ReadyQueue` draining) divides pool throughput by `share=` weights;
  * re-opening a workflow against a store that already holds rows
    *resumes* it: durably-done values resolve immediately through a
    `ResumeView` (the RestartLog seam) and only the remaining frontier
    re-runs.

Example — run, crash (or just exit), resume::

    store = JobStore("runs.db")
    svc = WorkflowService(Engine(clock), store)
    h = svc.open("etl")                   # or re-open after a crash
    stage = h.wf.atomic(fn=work, name="stage")
    h.seal(h.wf.gather([stage(i) for i in range(1000)]))
    svc.run()                             # resumed keys restore instantly
    results = h.result(); print(h.restored, "restored")

Works over a `FederatedEngine` too (the journal and resume view are
shared by every shard; keys are shard-agnostic).  `ProcessFederation` is
not supported — its tasks run in child processes whose engines cannot
reach the parent's store.
"""
from __future__ import annotations

from typing import Any

from repro.core.futures import DataFuture
from repro.core.jobstore import JobStore, Journal
from repro.core.restart_log import physical_refs
from repro.core.workflow import Workflow

__all__ = ["WorkflowService", "WorkflowHandle", "ResumeView"]

_MISS = object()


class ResumeView:
    """Durably-completed values, presented through the `RestartLog` seam
    (``lookup``/``append``) so the engine's restore path needs no new
    code.  ``append`` is a no-op — the journal is the persistence path.
    Per-workflow restore hits are tallied for `WorkflowHandle.restored`.
    """

    def __init__(self):
        self._done: dict[str, Any] = {}
        self.hits: dict[str, int] = {}

    def add(self, done: dict[str, Any]) -> None:
        self._done.update(done)

    def lookup(self, key: str):
        value = self._done.get(key, _MISS)
        if value is _MISS:
            return False, None
        for ref in physical_refs(value):
            if not ref.exists():
                return False, None
        wf_id, _, _ = key.partition("::")
        self.hits[wf_id] = self.hits.get(wf_id, 0) + 1
        return True, value

    def append(self, key: str, value) -> None:
        pass

    def __len__(self):
        return len(self._done)


class WorkflowHandle:
    """One tenant workflow opened through the service.

    ``wf`` is the `Workflow` DSL object to build the program on; `seal`
    registers the program's final output future so the workflow's
    durable status flips to done/failed (and the journal tail flushes)
    the moment it resolves.
    """

    def __init__(self, service: "WorkflowService", wf_id: str,
                 wf: Workflow, run_id: int):
        self.service = service
        self.wf_id = wf_id
        self.wf = wf
        self.run_id = run_id
        self._out: DataFuture | None = None

    def seal(self, out: DataFuture) -> DataFuture:
        """Declare `out` the workflow's final output; returns it."""
        self._out = out
        out.on_done(self._finished)
        return out

    def _finished(self, f: DataFuture) -> None:
        # clock thread: flush the journal tail so the terminal rows are
        # queued before the status row, then mark the workflow itself
        self.service.journal.flush()
        self.service.store.set_workflow_status(
            self.wf_id, "failed" if f.failed else "done")

    def result(self):
        if self._out is None:
            raise RuntimeError(f"workflow {self.wf_id!r} was never sealed")
        return self._out.get()

    @property
    def restored(self) -> int:
        """Tasks resolved from the store instead of re-running."""
        return self.service.resume_view.hits.get(self.wf_id, 0)

    def counts(self) -> dict[str, int]:
        """Durable per-status row counts (post-`sync` view)."""
        return JobStore.peek(self.service.store.path, self.wf_id)


class WorkflowService:
    """Multi-tenant, durable submission API over an `Engine` or
    `FederatedEngine` (see module docstring).

    The service owns the engine's `journal` and `restart_log` seams and
    enables `fair_share`; it refuses an engine whose seams are already
    occupied rather than silently replacing them.  `durability` and
    `journal_batch` pass through to `JobStore.journal`.
    """

    def __init__(self, engine, store: JobStore, fair_share: bool = True,
                 durability: str = "terminal", journal_batch: int = 64,
                 tracer=None):
        self.engine = engine
        self.store = store
        self.resume_view = ResumeView()
        tracer = tracer if tracer is not None \
            else getattr(engine, "tracer", None)
        self.journal: Journal = store.journal(
            batch=journal_batch, durability=durability, tracer=tracer,
            clock=engine.clock)
        self._handles: dict[str, WorkflowHandle] = {}
        shards = getattr(engine, "shards", None)
        for eng in (shards if shards is not None else [engine]):
            if eng.journal is not None or eng.restart_log is not None:
                raise ValueError(
                    "engine already has a journal/restart_log attached; "
                    "the service must own both seams")
            eng.journal = self.journal
            eng.restart_log = self.resume_view
            eng.fair_share = fair_share

    # ------------------------------------------------------------------
    def open(self, name: str, wf_id: str | None = None,
             app: str | None = None, share: float = 1.0,
             resume: bool = True) -> WorkflowHandle:
        """Open (or re-open) a workflow; returns its `WorkflowHandle`.

        With ``resume=True`` the store's durable state for `wf_id` is
        folded into the resume view first, so re-building the same
        program restores completed tasks.  `share` is the tenant's
        fair-share weight (relative to other apps' weights).
        """
        wf_id = wf_id or name
        if wf_id in self._handles:
            raise ValueError(f"workflow {wf_id!r} already open")
        if "::" in wf_id:
            raise ValueError("wf_id must not contain '::'")
        run_id = self.store.begin_run(wf_id, name=name)
        restorable = 0
        if resume:
            state = self.store.load(wf_id)
            restorable = len(state.done)
            self.resume_view.add(state.done)
        app = app or wf_id
        for eng in self._engines():
            eng.app_shares[app] = share
        wf = Workflow(name, self.engine, key_prefix=f"{wf_id}::",
                      default_app=app)
        handle = WorkflowHandle(self, wf_id, wf, run_id)
        self._handles[wf_id] = handle
        tr = self.journal.tracer
        if tr is not None and restorable:
            tr.event("wf_resume", self.engine.clock.now(),
                     float(restorable))
        return handle

    def _engines(self):
        shards = getattr(self.engine, "shards", None)
        return shards if shards is not None else [self.engine]

    # ------------------------------------------------------------------
    def run(self) -> None:
        """Drive the engine until the graph drains, then make everything
        journaled so far durable (`Journal.flush` + `JobStore.sync`)."""
        self.engine.run()
        self.sync()

    def sync(self) -> None:
        """Flush the journal tail and block until the store is durable
        (append-log landed; sqlite folds at the next barrier)."""
        self.journal.flush()
        self.store.sync()
        tr = self.journal.tracer
        if tr is not None:
            tr.gauge("tasks_restored",
                     sum(self.resume_view.hits.values()))
            tr.gauge("journal_rows", self.journal.rows_queued)
            tr.gauge("journal_duplicates", self.journal.sm.duplicates)

    def status(self, wf_id: str) -> dict:
        """Durable view of one workflow: status, runs, per-status counts."""
        self.sync()
        state = self.store.load(wf_id)
        return {"wf_id": wf_id, "run_id": state.run_id,
                "counts": state.counts, "done": len(state.done),
                "failed": len(state.failed)}

    def close(self) -> None:
        """Flush + sync and detach from the engine (store stays open)."""
        self.sync()
        for eng in self._engines():
            if eng.journal is self.journal:
                eng.journal = None
            if eng.restart_log is self.resume_view:
                eng.restart_log = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
