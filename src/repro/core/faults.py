"""Fault model + retry policy (paper §3.12).

* transient faults: retried in place (paper: GridFTP-busy style)
* host faults: Falkon suspends the executor for `suspend_time` after
  `host_fail_threshold` consecutive failures ("stale NFS handle" pattern)
* site faults: after `site_fail_threshold` failures at a site, the task is
  handed back for rescheduling at a *different* site
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable


class TaskFailure(Exception):
    """A task-body failure carrying its fault class (paper §3.12):
    ``kind`` is ``"transient"`` (retried in place), ``"host"`` (counts
    toward executor suspension), or ``"site"`` (rescheduled at a different
    site).  Raise it from a task body — or let any other exception map to
    transient — e.g. ``raise TaskFailure("stale NFS handle", kind="host")``.
    """

    def __init__(self, msg: str, kind: str = "transient"):
        super().__init__(msg)
        self.kind = kind  # transient | host | site


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_retries: int = 3
    host_fail_threshold: int = 2     # consecutive failures -> suspend host
    host_suspend_time: float = 60.0  # seconds (paper: configurable)
    site_fail_threshold: int = 3     # same-site failures -> reschedule away
    backoff: float = 0.0             # optional retry delay


class FaultInjector:
    """Deterministic failure injection for tests/benchmarks."""

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.rules: list[Callable] = []

    def fail_probability(self, p: float, kind: str = "transient",
                         only_task: str | None = None):
        def rule(task_name: str, host: str, attempt: int):
            if only_task and only_task not in task_name:
                return None
            if self.rng.random() < p:
                return TaskFailure(f"injected {kind} fault", kind)
            return None
        self.rules.append(rule)
        return self

    def fail_host(self, host: str, n_times: int, kind: str = "host"):
        state = {"left": n_times}

        def rule(task_name: str, task_host: str, attempt: int):
            if task_host == host and state["left"] > 0:
                state["left"] -= 1
                return TaskFailure(f"injected fault on {host}", kind)
            return None
        self.rules.append(rule)
        return self

    def fail_first_n(self, task_substr: str, n: int, kind: str = "transient"):
        state = {"left": n}

        def rule(task_name: str, host: str, attempt: int):
            if task_substr in task_name and state["left"] > 0:
                state["left"] -= 1
                return TaskFailure(f"injected fault in {task_name}", kind)
            return None
        self.rules.append(rule)
        return self

    def check(self, task_name: str, host: str, attempt: int):
        for rule in self.rules:
            err = rule(task_name, host, attempt)
            if err is not None:
                raise err
