"""Fault model + retry policy (paper §3.12).

* transient faults: retried in place (paper: GridFTP-busy style)
* host faults: Falkon suspends the executor for `suspend_time` after
  `host_fail_threshold` consecutive failures ("stale NFS handle" pattern)
* site faults: after `site_fail_threshold` failures at a site, the task is
  handed back for rescheduling at a *different* site
* revocations: ``kind="revoked"`` marks an administrative requeue (a
  drained service handing queued tasks back, DESIGN.md §13) — the engine
  re-places the task elsewhere without charging a retry or denting the
  site score

Site-correlated, time-windowed scenarios (`fail_site_window`) model the
paper's operational reality — a whole site going bad mid-campaign — and
drive the health-monitor benchmark.  A rule with ``latency=`` models
fail-slow faults (hangs/timeouts): the failed attempt occupies its
executor for `latency` seconds instead of the task's nominal duration
(the Falkon sim path evaluates such rules at dispatch time).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable


class TaskFailure(Exception):
    """A task-body failure carrying its fault class (paper §3.12):
    ``kind`` is ``"transient"`` (retried in place), ``"host"`` (counts
    toward executor suspension), ``"site"`` (rescheduled at a different
    site), or ``"revoked"`` (administrative drain requeue — no retry
    charge).  Raise it from a task body — or let any other exception map
    to transient — e.g. ``raise TaskFailure("stale NFS", kind="host")``.
    ``latency`` (optional) is the seconds the failing attempt holds its
    executor before the failure surfaces — fail-slow/timeout faults; the
    simulated Falkon path honors it when the rule is evaluated at
    dispatch time."""

    def __init__(self, msg: str, kind: str = "transient",
                 latency: float | None = None):
        super().__init__(msg)
        self.kind = kind  # transient | host | site | revoked
        self.latency = latency

    def __reduce__(self):
        # Exception's default reduce keeps only `args` (the message), so a
        # TaskFailure crossing a process boundary — a shard process
        # reporting a failed task (DESIGN.md §14) — would silently revert
        # to kind="transient" and lose its fail-slow latency
        return (TaskFailure,
                (self.args[0] if self.args else "", self.kind, self.latency))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_retries: int = 3
    host_fail_threshold: int = 2     # consecutive failures -> suspend host
    host_suspend_time: float = 60.0  # seconds (paper: configurable)
    site_fail_threshold: int = 3     # same-site failures -> reschedule away
    backoff: float = 0.0             # optional retry delay


class FaultInjector:
    """Deterministic failure injection for tests/benchmarks.

    Rules are callables ``rule(task_name, host, attempt) -> TaskFailure |
    None``; a rule carrying ``wants_site = True`` is additionally passed
    the site name (``rule(task_name, host, attempt, site)``), which is how
    site-correlated scenarios match tasks dispatched through providers
    that never set a host.  Time-windowed rules need the run's clock —
    pass ``clock=`` (or set ``inj.clock``) before registering one."""

    def __init__(self, seed: int = 0, clock=None):
        self.rng = random.Random(seed)
        self.rules: list[Callable] = []
        self.clock = clock
        # True once any registered rule wants dispatch-time (fail-slow)
        # evaluation; the engine copies this onto the per-task fault check
        self.timed = False

    def fail_probability(self, p: float, kind: str = "transient",
                         only_task: str | None = None):
        def rule(task_name: str, host: str, attempt: int):
            if only_task and only_task not in task_name:
                return None
            if self.rng.random() < p:
                return TaskFailure(f"injected {kind} fault", kind)
            return None
        self.rules.append(rule)
        return self

    def fail_host(self, host: str, n_times: int, kind: str = "host"):
        state = {"left": n_times}

        def rule(task_name: str, task_host: str, attempt: int):
            if task_host == host and state["left"] > 0:
                state["left"] -= 1
                return TaskFailure(f"injected fault on {host}", kind)
            return None
        self.rules.append(rule)
        return self

    def fail_first_n(self, task_substr: str, n: int, kind: str = "transient"):
        state = {"left": n}

        def rule(task_name: str, host: str, attempt: int):
            if task_substr in task_name and state["left"] > 0:
                state["left"] -= 1
                return TaskFailure(f"injected fault in {task_name}", kind)
            return None
        self.rules.append(rule)
        return self

    def fail_site_window(self, site: str, p: float,
                         start: float = 0.0, end: float = float("inf"),
                         kind: str = "transient",
                         latency: float | None = None,
                         only_task: str | None = None):
        """Site-correlated, time-windowed fault scenario: tasks attempted
        at `site` between clock times ``[start, end)`` fail with
        probability `p`.  ``latency=`` makes them fail-slow (the attempt
        occupies its executor that long before failing — the simulated
        Falkon path evaluates such rules at dispatch time, so the window
        applies to attempt *start*).  Matches the site name passed by the
        engine, or a ``{site}-host*`` host prefix for direct callers.
        Requires a bound clock."""
        if self.clock is None:
            raise ValueError("fail_site_window needs a clock: "
                             "FaultInjector(seed, clock=clock)")
        clock = self.clock
        prefix = site + "-host"

        def rule(task_name: str, host: str, attempt: int,
                 task_site: str = ""):
            if task_site != site and not host.startswith(prefix):
                return None
            if only_task and only_task not in task_name:
                return None
            now = clock.now()
            if not (start <= now < end):
                return None
            if self.rng.random() < p:
                return TaskFailure(f"injected {kind} fault at {site}",
                                   kind, latency=latency)
            return None

        rule.wants_site = True
        if latency is not None:
            self.timed = True
        self.rules.append(rule)
        return self

    def check(self, task_name: str, host: str, attempt: int,
              site: str = ""):
        for rule in self.rules:
            if getattr(rule, "wants_site", False):
                err = rule(task_name, host, attempt, site)
            else:
                err = rule(task_name, host, attempt)
            if err is not None:
                raise err
