"""Execution providers (paper §3.11) — the middle layer of the scheduler.

Providers implement the abstract provider interface: `submit(task,
when_done)` with `when_done(ok, value, error)` called exactly once per
submission.  The two pool-shaped providers (local host, simulated batch
scheduler) share `WorkerPoolProvider`, which owns the run queue / slot
accounting that the seed duplicated in both classes:

  * LocalProvider           — run on the submit host
  * BatchSchedulerProvider  — simulated PBS/Condor: serial submission rate +
                              scheduler latency + node pool (the GRAM+PBS
                              baseline of Figs 6/12/13/14)
  * FalkonProvider          — the Falkon service (multi-level scheduling)
  * ClusteringProvider      — wraps any provider, bundling small tasks within
                              a clustering window (§3.13)
"""
from __future__ import annotations

from collections import deque
from functools import partial
from typing import TYPE_CHECKING, Callable

from repro.core.faults import TaskFailure
from repro.core.futures import DataFuture
from repro.core.simclock import Clock
from repro.core.task import Task, execute_task, sim_duration

if TYPE_CHECKING:
    from repro.core.falkon import FalkonService


class Provider:
    """Abstract execution provider (paper §3.11): `submit(task, when_done)`
    with ``when_done(ok, value, error)`` called exactly once per
    submission.  Implementations wrap a local pool, a simulated batch
    scheduler, the Falkon service, or another provider (clustering).

    Example — register any provider as an engine site::

        eng.add_site("cluster", BatchSchedulerProvider(clock, nodes=32),
                     capacity=32)
    """

    name = "provider"

    def submit(self, task: Task, when_done: Callable) -> None:
        raise NotImplementedError


class WorkerPoolProvider(Provider):
    """Shared worker-pool core: a FIFO run queue drained into a fixed number
    of execution slots.

    Subclasses control *admission* (when a submitted task reaches the run
    queue — immediately for the local host, after a gateway throttle plus
    scheduler latency for a batch system).  Draining is O(1) per task: each
    completion frees one slot and pulls the queue head; no scans.

    Simulated by default: a slot occupies the clock for the task's declared
    `duration` and the body executes at the scheduled completion.  Pass
    ``pool=`` (a `ThreadExecutorPool` / `ProcessExecutorPool`,
    DESIGN.md §10, or a `DeviceExecutorPool`, DESIGN.md §11 — any object
    with the ``submit(task, done, stage=None)`` seam) to run bodies on
    real workers instead — the slot is held for the *measured* run and
    durations are ignored::

        prov = LocalProvider(clock, 8, pool=ThreadExecutorPool(clock, 8))
    """

    name = "pool"

    def __init__(self, clock: Clock, slots: int, pool=None):
        self.clock = clock
        self.slots = slots
        self.pool = pool
        if pool is not None and pool.autoscale:
            pool.resize(slots)
        self._running = 0
        self._queue: deque = deque()

    # admission policy — subclasses may delay this
    def submit(self, task: Task, when_done: Callable) -> None:
        self._admit(task, when_done)

    def _admit(self, task: Task, when_done: Callable) -> None:
        self._queue.append((task, when_done))
        self._pump()

    def _pump(self) -> None:
        queue = self._queue
        clock = self.clock
        pool = self.pool
        while queue and self._running < self.slots:
            task, when_done = queue.popleft()
            self._running += 1
            task.start_time = clock.now()
            if pool is not None:
                # real execution: the body runs on a worker; the measured
                # completion re-enters on the clock thread
                pool.submit(task, partial(self._finish_real, task, when_done))
            else:
                clock.schedule(sim_duration(task),
                               partial(self._finish, task, when_done))

    def _finish(self, task: Task, when_done: Callable) -> None:
        ok, value, err = execute_task(task)
        self._running -= 1
        when_done(ok, value, err)
        self._pump()

    def _finish_real(self, task: Task, when_done: Callable,
                     ok: bool, value, err, io_s: float,
                     run_s: float) -> None:
        self._running -= 1
        when_done(ok, value, err)
        self._pump()


class LocalProvider(WorkerPoolProvider):
    """Immediate local execution (the paper's local-host provider).

    Example::

        eng = Engine(clock)
        eng.add_site("localhost", LocalProvider(clock, concurrency=4),
                     capacity=4)
    """

    name = "local"

    def __init__(self, clock: Clock, concurrency: int = 1, pool=None):
        super().__init__(clock, concurrency, pool=pool)


class BatchSchedulerProvider(WorkerPoolProvider):
    """Simulated conventional batch scheduler (PBS / Condor).

    Models the paper's measured behavior: a serial job-submission throttle
    (GRAM gateway: ~1/5 jobs/s in §5.4.3; PBS ~1-2 jobs/s in Fig 12) plus a
    per-job scheduler latency, over a fixed node pool.

    Admissions are coalesced into *gateway-window waves* (PBS scheduling
    cycles): the seed scheduled one clock event per task through the
    gateway, which inflated the event heap at 10^6 tasks.  Per-job
    admission times (`gate + sched_latency`) are quantized onto wave
    boundaries: a wave opens at the first pending admission time and fires
    one clock event `admit_window` later (default `sched_latency / 8`),
    admitting every job whose per-job time falls inside the window — under
    backlog that is `admit_window x submit_rate` jobs per clock event.  A
    job is admitted no earlier than its per-job time and at most
    `admit_window` late, so the serial-gateway pacing that distinguishes
    e.g. PBS from Condor 6.7.2 (Fig 6/12) is preserved to within 1/8 of
    the scheduler latency; with `sched_latency == 0` waves are singletons
    and the per-job timing is exact.
    """

    name = "batch"

    def __init__(self, clock: Clock, nodes: int, submit_rate: float = 1.0,
                 sched_latency: float = 60.0,
                 admit_window: float | None = None, pool=None):
        super().__init__(clock, nodes, pool=pool)
        self.submit_interval = 1.0 / submit_rate
        self.sched_latency = sched_latency
        self.admit_window = (sched_latency / 8.0 if admit_window is None
                             else admit_window)
        self._gateway_free_at = 0.0
        self._wave: list | None = None
        self._wave_deadline = 0.0
        self.admission_events = 0   # clock events spent on admission

    def submit(self, task: Task, when_done: Callable) -> None:
        now = self.clock.now()
        # serial submission gateway (throttled)
        gate = max(now, self._gateway_free_at)
        self._gateway_free_at = gate + self.submit_interval
        admit_at = gate + self.sched_latency
        if self._wave is None or admit_at > self._wave_deadline:
            wave: list = []
            self._wave = wave
            self._wave_deadline = admit_at + self.admit_window
            self.admission_events += 1
            self.clock.schedule(self._wave_deadline - now,
                                partial(self._admit_wave, wave))
        self._wave.append((task, when_done))

    def _admit_wave(self, wave: list) -> None:
        if wave is self._wave:
            self._wave = None
        self._queue.extend(wave)
        self._pump()


class FalkonProvider(Provider):
    """Adapter registering a `FalkonService` as an engine site::

        svc = FalkonService(clock, FalkonConfig())
        eng.add_site("pod0", FalkonProvider(svc), capacity=64)
    """

    name = "falkon"

    def __init__(self, service: "FalkonService"):
        self.service = service

    def submit(self, task: Task, when_done: Callable) -> None:
        self.service.submit(task, when_done)


class ClusteringProvider(Provider):
    """Dynamic clustering (§3.13): accumulate ready tasks for a clustering
    window, then submit them as one bundle paying one per-job overhead.
    No prior knowledge of the workflow graph is needed."""

    name = "clustering"

    def __init__(self, clock: Clock, inner: Provider, window: float = 1.0,
                 bundle_size: int = 8):
        self.clock = clock
        self.inner = inner
        self.window = window
        self.bundle_size = bundle_size
        self._pending: deque = deque()
        self._flush_scheduled = False

    def submit(self, task: Task, when_done: Callable) -> None:
        self._pending.append((task, when_done))
        if len(self._pending) >= self.bundle_size:
            self._flush()
        elif not self._flush_scheduled:
            self._flush_scheduled = True
            self.clock.schedule(self.window, self._window_flush)

    def _window_flush(self):
        self._flush_scheduled = False
        if self._pending:
            self._flush()

    def _flush(self):
        pending = self._pending
        bundle = [pending.popleft()
                  for _ in range(min(self.bundle_size, len(pending)))]
        if not bundle:
            return
        tasks = [t for t, _ in bundle]
        total = sum(sim_duration(t) for t in tasks)
        # the bundle stages the union of its members' inputs once, so
        # clustering composes with a data-layer Falkon (staging costs and
        # cache accounting are not silently dropped)
        inputs = {}
        for t in tasks:
            for obj in t.inputs:
                inputs[obj.name] = obj

        def run_bundle(*_):
            results = []
            for t, _cb in bundle:
                ok, value, err = execute_task(t)
                results.append((ok, value, err))
            return results

        meta = Task(name=f"bundle[{len(bundle)}]", fn=run_bundle, args=[],
                    output=DataFuture(), duration=total, app=tasks[0].app,
                    retries=0, durable=False, key="",
                    inputs=tuple(inputs.values()))
        meta.fault_check = None

        def done(ok, results, err):
            if not ok or results is None:
                for _t, cb in bundle:
                    cb(False, None, err or TaskFailure("bundle failed"))
                return
            for (t, cb), (ok_i, v_i, e_i) in zip(bundle, results):
                cb(ok_i, v_i, e_i)

        self.inner.submit(meta, done)
        if self._pending:
            self._flush()
