"""Device-native task clustering: vmap-bundling of small JAX tasks.

The paper's clustering (§3.13) amortizes batch-scheduler submission overhead
by bundling small jobs.  On an accelerator the analogous per-task cost is
*dispatch + kernel launch* of many small jitted computations; the adaptation
fuses ready tasks that share a callable and argument shapes into ONE batched
device call via `jax.vmap` — one launch, one dispatch, full-width compute.

Two consumers share the bundle-execution core in this module
(`execute_bundle` + `vmap_signature`):

  * `VmapClusteringProvider` — a provider for simulated/engine-driven runs:
    bundles form on the clock thread and execute inline (works under
    `SimClock`).
  * `DeviceExecutorPool` (`repro.core.devicepool`, DESIGN.md §11) — the
    real pool behind `FalkonService(pool=...)`: bundles execute on a
    dispatcher thread and measured completions re-enter through
    `Clock.post_release`.

Signature identity is GC-safe: callables are keyed through
`repro.core.task.stable_fn_key`, never raw ``id(fn)`` — a collected
callable's address can be reused by a new function, and an id-keyed bundle
or jit cache would then silently fuse (or run) the wrong callable.

benchmarks/vmap_clustering.py and benchmarks/device_batching.py measure the
amortization exactly like the paper's Fig 6 measures PBS-overhead
amortization.
"""
from __future__ import annotations

from time import perf_counter
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import StreamStat
from repro.core.providers import Provider
from repro.core.simclock import Clock
from repro.core.task import Task, arg_signature, execute_task, stable_fn_key


def vmap_signature(fn: Callable, args: list) -> tuple:
    """Tasks sharing this signature can be fused into one vmapped call.

    The callable component is a `stable_fn_key` serial (GC-safe), not
    ``id(fn)``; the argument component is the structural
    `arg_signature` (shapes + dtypes), so tasks with the same callable
    but unstackable argument shapes land in different bundles instead of
    failing the stack at execution time."""
    return (stable_fn_key(fn), arg_signature(args))


def resolve_args(task) -> list:
    """Argument values of a dispatched task (futures are resolved)."""
    return [a.get() if hasattr(a, "get") and hasattr(a, "on_done") else a
            for a in task.args]


def _split_result(results, n: int) -> list:
    """Un-batch a vmapped call's output pytree into n per-task results."""
    leaves, treedef = jax.tree_util.tree_flatten(results)
    if not leaves:
        return [results] * n
    return [jax.tree_util.tree_unflatten(treedef, [leaf[i] for leaf in leaves])
            for i in range(n)]


def execute_bundle(fn: Callable, tasks: list, vmapped_cache: dict):
    """Run same-signature tasks as one jitted+vmapped device call.

    Returns ``(results, exec_s, n_fused)``: `results` is a per-task list
    of ``(ok, value, error)`` aligned with `tasks`, `exec_s` the measured
    wall seconds of the execution (the fused device call, or the sum of
    per-task executions on the fallback path), and `n_fused` how many
    tasks went through the batched call (0 when it was not used).  Fault
    checks run per task first — a failing check fails only that task and
    excludes it from the batch.  Any error in the fused path (unstackable
    args, non-vmappable body) degrades to per-task `execute_task`, never
    to a lost completion.

    `vmapped_cache` maps ``(stable_fn_key, in_axes)`` to the compiled
    ``jit(vmap(fn))`` so steady-state bundles pay zero retrace.
    """
    n = len(tasks)
    results: list = [None] * n
    live: list[int] = []
    for i, t in enumerate(tasks):
        chk = getattr(t, "fault_check", None)
        if chk is not None:
            try:
                chk(t)
            except BaseException as err:  # noqa: BLE001 — per-task failure
                results[i] = (False, None, err)
                continue
        live.append(i)
    if not live:
        return results, 0.0, 0
    if len(live) == 1:
        i = live[0]
        t0 = perf_counter()
        results[i] = _execute_unchecked(tasks[i])
        return results, perf_counter() - t0, 0
    t0 = perf_counter()
    try:
        arg_lists = [resolve_args(tasks[i]) for i in live]
        n_args = len(arg_lists[0])
        # args identical across the bundle broadcast (in_axes=None)
        # instead of being stacked — no 256x weight copies
        shared = [all(al[i] is arg_lists[0][i] for al in arg_lists)
                  for i in range(n_args)]
        in_axes = tuple(None if s else 0 for s in shared)

        def stack(items):
            if all(isinstance(a, np.ndarray) for a in items):
                return jnp.asarray(np.stack(items))  # one h2d transfer
            return jnp.stack(items)

        stacked = [arg_lists[0][i] if shared[i]
                   else stack([al[i] for al in arg_lists])
                   for i in range(n_args)]
        vkey = (stable_fn_key(fn), in_axes)
        vfn = vmapped_cache.get(vkey)
        if vfn is None:
            vfn = jax.jit(jax.vmap(fn, in_axes=in_axes))
            vmapped_cache[vkey] = vfn
        out = jax.device_get(vfn(*stacked))
        for i, r in zip(live, _split_result(out, len(live))):
            results[i] = (True, r, None)
        return results, perf_counter() - t0, len(live)
    except BaseException:  # noqa: BLE001 — degrade to per-task execution
        t0 = perf_counter()
        for i in live:
            results[i] = _execute_unchecked(tasks[i])
        return results, perf_counter() - t0, 0


def _execute_unchecked(task):
    """`execute_task` minus the fault check (already run by the bundle)."""
    fn = getattr(task, "fn", None)
    if fn is None:
        return True, getattr(task, "sim_value", None), None
    try:
        return True, fn(*resolve_args(task)), None
    except BaseException as err:  # noqa: BLE001 — engine handles retries
        return False, None, err


class VmapClusteringProvider(Provider):
    """Bundle ready tasks with identical (callable, shapes) signatures into a
    single vmapped execution.  Falls back to per-task execution for
    singletons or non-batchable tasks.

    Bundles key on the task's user `vmap_key` *and* the structural
    `vmap_signature` — the signature already embeds the callable's stable
    identity, so there is exactly one level of keying.  Measured execution
    seconds are recorded per task into bounded `StreamStat`s (`io_stat`,
    `run_stat`) with the same meaning as the real pools' metrics
    (DESIGN.md §10), so singleton fallbacks show up in throughput metrics
    instead of vanishing.
    """

    name = "vmap-cluster"

    def __init__(self, clock: Clock, window: float = 0.0,
                 max_bundle: int = 1024):
        self.clock = clock
        self.window = window
        self.max_bundle = max_bundle
        self._pending: dict[Any, list] = {}
        self._flush_scheduled = False
        self.bundles_executed = 0
        self.tasks_executed = 0
        self.fused_tasks = 0
        self._vmapped_cache: dict = {}
        # measured execution seconds per task, same shape as the pool
        # metrics (io is zero here: no staging path on this provider)
        self.io_stat = StreamStat(cap=256)
        self.run_stat = StreamStat(cap=256)

    def submit(self, task: Task, when_done: Callable) -> None:
        if task.vmap_key is None or task.fn is None:
            t0 = perf_counter()
            ok, v, e = execute_task(task)
            self._observe(perf_counter() - t0)
            self.tasks_executed += 1
            when_done(ok, v, e)
            return
        key = (task.vmap_key, vmap_signature(task.fn, resolve_args(task)))
        bucket = self._pending.get(key)
        if bucket is None:
            self._pending[key] = bucket = []
        bucket.append((task, when_done))
        if len(bucket) >= self.max_bundle:
            self._flush_key(key)
        elif not self._flush_scheduled:
            self._flush_scheduled = True
            self.clock.schedule(self.window, self.flush)

    def _observe(self, run_s: float, io_s: float = 0.0) -> None:
        now = self.clock.now()
        self.io_stat.observe(now, io_s)
        self.run_stat.observe(now, run_s)

    def flush(self):
        self._flush_scheduled = False
        for key in list(self._pending):
            self._flush_key(key)

    def _flush_key(self, key):
        bundle = self._pending.pop(key, [])
        if not bundle:
            return
        self.bundles_executed += 1
        self.tasks_executed += len(bundle)
        tasks = [t for t, _ in bundle]
        results, exec_s, n_fused = execute_bundle(tasks[0].fn, tasks,
                                                  self._vmapped_cache)
        self.fused_tasks += n_fused
        per_task = exec_s / max(1, len(bundle))
        for (t, cb), (ok, v, e) in zip(bundle, results):
            self._observe(per_task)
            cb(ok, v, e)

    def metrics(self) -> dict:
        """Bounded snapshot — safe at any task count."""
        return {
            "tasks": self.tasks_executed,
            "bundles": self.bundles_executed,
            "fused_tasks": self.fused_tasks,
            "io_s": self.io_stat.summary(),
            "run_s": self.run_stat.summary(),
        }
