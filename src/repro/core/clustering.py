"""TPU-native task clustering: vmap-bundling of small JAX tasks.

The paper's clustering (§3.13) amortizes batch-scheduler submission overhead
by bundling small jobs.  On TPU the analogous per-task cost is *dispatch +
kernel launch* of many small jitted computations; the TPU-native adaptation
fuses ready tasks that share a callable and argument shapes into ONE batched
device call via `jax.vmap` — one launch, one dispatch, full-width compute.

benchmarks/microbench.py measures the amortization exactly like the paper's
Fig 6 measures PBS-overhead amortization.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.providers import Provider
from repro.core.simclock import Clock
from repro.core.task import Task, execute_task


def vmap_signature(fn: Callable, args: list) -> tuple:
    """Tasks sharing this signature can be fused into one vmapped call."""
    shapes = tuple(
        (tuple(np.shape(a)), str(np.asarray(a).dtype) if not np.isscalar(a)
         else type(a).__name__)
        for a in args)
    return (id(fn), shapes)


class VmapClusteringProvider(Provider):
    """Bundle ready tasks with identical (callable, shapes) signatures into a
    single vmapped execution.  Falls back to per-task execution for
    singletons or non-batchable tasks."""

    name = "vmap-cluster"

    def __init__(self, clock: Clock, window: float = 0.0,
                 max_bundle: int = 1024):
        self.clock = clock
        self.window = window
        self.max_bundle = max_bundle
        self._pending: dict[Any, list] = defaultdict(list)
        self._flush_scheduled = False
        self.bundles_executed = 0
        self.tasks_executed = 0
        self._vmapped_cache: dict = {}

    def submit(self, task: Task, when_done: Callable) -> None:
        key = task.vmap_key
        if key is None or task.fn is None:
            ok, v, e = execute_task(task)
            when_done(ok, v, e)
            return
        self._pending[(key, id(task.fn))].append((task, when_done))
        if len(self._pending[(key, id(task.fn))]) >= self.max_bundle:
            self._flush_key((key, id(task.fn)))
        elif not self._flush_scheduled:
            self._flush_scheduled = True
            self.clock.schedule(self.window, self.flush)

    def flush(self):
        self._flush_scheduled = False
        for key in list(self._pending):
            self._flush_key(key)

    def _flush_key(self, key):
        bundle = self._pending.pop(key, [])
        if not bundle:
            return
        self.bundles_executed += 1
        self.tasks_executed += len(bundle)
        if len(bundle) == 1:
            task, cb = bundle[0]
            ok, v, e = execute_task(task)
            cb(ok, v, e)
            return
        tasks = [t for t, _ in bundle]
        fn = tasks[0].fn
        try:
            arg_lists = [
                [a.get() if hasattr(a, "on_done") else a for a in t.args]
                for t in tasks
            ]
            n_args = len(arg_lists[0])
            # args identical across the bundle broadcast (in_axes=None)
            # instead of being stacked — no 256x weight copies
            shared = [all(al[i] is arg_lists[0][i] for al in arg_lists)
                      for i in range(n_args)]
            in_axes = tuple(None if s else 0 for s in shared)

            def stack(items):
                if all(isinstance(a, np.ndarray) for a in items):
                    return jnp.asarray(np.stack(items))  # one h2d transfer
                return jnp.stack(items)

            stacked = [arg_lists[0][i] if shared[i]
                       else stack([al[i] for al in arg_lists])
                       for i in range(n_args)]
            vkey = (id(fn), in_axes)
            vfn = self._vmapped_cache.get(vkey)
            if vfn is None:
                vfn = jax.jit(jax.vmap(fn, in_axes=in_axes))
                self._vmapped_cache[vkey] = vfn
            results = vfn(*stacked)
            results = jax.device_get(results)
            for (t, cb), r in zip(bundle, list(results)):
                cb(True, r, None)
        except BaseException as err:  # noqa: BLE001 - fall back per-task
            for t, cb in bundle:
                ok, v, e = execute_task(t)
                cb(ok, v, e)
