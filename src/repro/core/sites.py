"""Execution sites + score-based load balancing (paper §3.13).

Each site carries a responsiveness score: increased on successful, fast
turnarounds; decreased on exceptions.  Dispatch is proportional to score and
available capacity — the same heuristic that produced the paper's Fig 11
218/262 split across ANL_TG / UC_TP.

Two extensions over the paper's balancer:

  * **data affinity** — a site backed by a cache-aware data layer
    (DESIGN.md §7) can register it via `set_affinity`; `pick` then boosts
    sites whose executors already hold a task's declared inputs, with the
    boost priced against the `StagingCostModel` (the bonus is exactly the
    shared-vs-local read-time advantage, scaled by covered bytes).  The
    no-inputs path — and any balancer with no registered affinity — is
    behaviorally identical to the score-only heuristic.
  * **steal interface** — `idle_slots` reports free, non-suspended
    capacity so a federation-level `WorkStealer` (DESIGN.md §8) can decide
    thief eligibility without reaching into per-site state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass
class SiteStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    busy_time: float = 0.0


class Site:
    """One execution site: a provider plus capacity, app validity, and the
    responsiveness score the balancer steers by.  Created for you by
    `Engine.add_site`::

        site = eng.add_site("anl_tg", FalkonProvider(svc), capacity=64,
                            apps={"moldyn"})
    """

    def __init__(self, name: str, provider, capacity: int,
                 apps: set[str] | None = None, score: float = 1.0):
        self.name = name
        self.provider = provider
        self.capacity = capacity
        self.apps = apps  # None = everything installed
        self.score = score
        self.outstanding = 0
        # predicted seconds of work currently outstanding on this site
        # (DESIGN.md §11): maintained by the engine when the balancer is
        # `duration_aware`, from `duration=` specs — explicit, callable,
        # or filled by a `DurationPredictor` — so `pick` can price
        # compute *before* running it; stays 0.0 otherwise
        self.outstanding_work = 0.0
        self.stats = SiteStats()
        # drain/blacklist seam (DESIGN.md §13): `pick` and `idle_slots`
        # skip a site while now < suspended_until.  The engine's retry
        # path sets it for fixed backoffs; a `HealthMonitor` drives it
        # from observed windowed error rates.
        self.suspended_until = 0.0
        # degraded-state weight multiplier, set by the health monitor
        # (1.0 = no effect; `pick` multiplies it into the site weight)
        self.derate = 1.0
        # monitor-maintained label ("healthy" | "degraded" | "drained" |
        # "blacklisted") — informational; scheduling reads only
        # `suspended_until` and `derate`
        self.health_state = "healthy"

    # -- paper: score up on success, down on exceptions ---------------------
    def on_success(self, turnaround: float):
        self.stats.completed += 1
        self.stats.busy_time += turnaround
        self.score = min(100.0, self.score * 1.05 + 0.1)

    def on_failure(self):
        self.stats.failed += 1
        self.score = max(0.05, self.score * 0.5)

    def valid_for(self, app: str | None) -> bool:
        return self.apps is None or app is None or app in self.apps

    def free_slots(self) -> int:
        return max(0, self.capacity - self.outstanding)


class LoadBalancer:
    """Pick the valid site with the largest score-weighted free capacity.

    Site candidates are served from a per-app index so per-task dispatch
    does not rescan every registered site (the seed's `pick` and the
    engine's multi-site check were both O(sites) per task).  A site's
    `apps` set is treated as fixed once the site is registered.

    Cache-staleness contract: `add_site` invalidates the *entire* per-app
    index, so a site added mid-run is visible to the very next
    `sites_for`/`pick` call — callers must not hold candidate lists across
    an `add_site` (the engine refetches per placement, so it never does).

    Determinism contract: `sites_for` preserves registration order (list
    append, no dict iteration), and `pick` breaks weight ties toward the
    earliest-registered site — replays under `SimClock` are stable and do
    not depend on hash seeds or insertion luck.
    """

    def __init__(self, sites: list[Site], duration_aware: bool = False):
        self.sites = list(sites)
        self._by_app: dict = {}
        # site name -> data layer (DESIGN.md §7) for the affinity term;
        # empty dict == affinity disabled, pick is the score-only heuristic
        self._affinity: dict = {}
        # duration-aware pricing (DESIGN.md §11): when on, the engine
        # maintains `Site.outstanding_work` (predicted seconds queued, from
        # `duration=` specs or the `DurationPredictor`) and `pick` folds it
        # into the load term, so 100 one-second tasks and 100 millisecond
        # tasks stop looking like equal backlog.  Off (the default) the
        # weight formula is byte-identical to the score-only heuristic.
        self.duration_aware = duration_aware

    def add_site(self, site: Site):
        self.sites.append(site)
        # full invalidation, not per-app patching: every cached candidate
        # list may be missing the new site (its apps set may be None ==
        # "everything"), so all of them are stale the moment it registers
        self._by_app.clear()

    def set_affinity(self, site_name: str, data_layer) -> None:
        """Register the data layer backing a site so `pick` can weigh data
        affinity (route to the site whose executors hold a task's inputs,
        priced against the layer's `StagingCostModel`)."""
        self._affinity[site_name] = data_layer

    def sites_for(self, app: str | None) -> list[Site]:
        """Valid sites for an app (cached; app cardinality is workflow-level
        and small, so the cache is bounded).  The cache is invalidated
        wholesale by `add_site`, covering sites added mid-run."""
        cands = self._by_app.get(app)
        if cands is None:
            cands = [s for s in self.sites if s.valid_for(app)]
            self._by_app[app] = cands
        return cands

    def pick(self, app: str | None, now: float,
             require_room: bool = False, slack: float = 2.0,
             inputs=None) -> Optional[Site]:
        # affinity engages only when the task declares inputs AND a data
        # layer is registered; otherwise the loop below is byte-identical
        # in behavior to the score-only balancer
        aff = self._affinity if inputs else None
        dur = self.duration_aware
        best, best_w = None, -1.0
        for s in self.sites_for(app):
            if now < s.suspended_until:
                continue
            if require_room and s.outstanding >= s.capacity * slack:
                continue
            # queue-depth-aware proportional weight: equilibrium backlog is
            # proportional to score x capacity, so fast/large sites get more
            # jobs (paper Fig 11) even when every site is saturated; the
            # duration-aware term adds *predicted seconds* of queued work,
            # so a site holding few-but-long tasks yields to one holding
            # many-but-tiny tasks when the predictions say it should
            load = s.outstanding + (s.outstanding_work if dur else 0.0)
            # `derate` folds the health monitor's degraded state into the
            # weight (1.0 when healthy — multiplication is exact identity,
            # so a monitor-less run is byte-identical)
            w = s.score * s.derate * s.capacity / (1.0 + load)
            if aff:
                dl = aff.get(s.name)
                if dl is not None:
                    w *= _affinity_boost(dl, inputs)
            # strict >: ties break toward the earliest-registered site
            # (sites_for preserves registration order), so replays are
            # deterministic under SimClock
            if w > best_w:
                best, best_w = s, w
        return best

    def idle_slots(self, now: float, app: str | None = None) -> int:
        """Free, non-suspended capacity across (valid) sites — the steal
        interface (DESIGN.md §8): a federation's `WorkStealer` treats a
        shard as a thief candidate only when this is positive.  O(valid
        sites), which is per-shard and small."""
        free = 0
        for s in self.sites_for(app):
            if now >= s.suspended_until:
                free += s.free_slots()
        return free

    def any_valid(self, app: str | None) -> bool:
        return bool(self.sites_for(app))


def _affinity_boost(dl, inputs) -> float:
    """Multiplicative weight bonus for a site whose data layer already
    holds (part of) the task's inputs, priced against the staging cost
    model: with full coverage the weight scales by exactly the
    shared-read vs local-read time ratio for the input set, with partial
    coverage by the covered fraction of that advantage.  Cost is
    O(inputs) dict probes; no executor scans."""
    total = 0.0
    covered = 0.0
    for obj in inputs:
        total += obj.size
        if dl.holds(obj.name):
            covered += obj.size
    if total <= 0.0 or covered <= 0.0:
        return 1.0
    cost = dl.cost
    local = cost.local_read_time(total)
    advantage = cost.shared_read_time(total) / max(local, 1e-12)
    if advantage <= 1.0:
        return 1.0
    return 1.0 + (covered / total) * (advantage - 1.0)
