"""Execution sites + score-based load balancing (paper §3.13).

Each site carries a responsiveness score: increased on successful, fast
turnarounds; decreased on exceptions.  Dispatch is proportional to score and
available capacity — the same heuristic that produced the paper's Fig 11
218/262 split across ANL_TG / UC_TP.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass
class SiteStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    busy_time: float = 0.0


class Site:
    def __init__(self, name: str, provider, capacity: int,
                 apps: set[str] | None = None, score: float = 1.0):
        self.name = name
        self.provider = provider
        self.capacity = capacity
        self.apps = apps  # None = everything installed
        self.score = score
        self.outstanding = 0
        self.stats = SiteStats()
        self.suspended_until = 0.0

    # -- paper: score up on success, down on exceptions ---------------------
    def on_success(self, turnaround: float):
        self.stats.completed += 1
        self.stats.busy_time += turnaround
        self.score = min(100.0, self.score * 1.05 + 0.1)

    def on_failure(self):
        self.stats.failed += 1
        self.score = max(0.05, self.score * 0.5)

    def valid_for(self, app: str | None) -> bool:
        return self.apps is None or app is None or app in self.apps

    def free_slots(self) -> int:
        return max(0, self.capacity - self.outstanding)


class LoadBalancer:
    """Pick the valid site with the largest score-weighted free capacity.

    Site candidates are served from a per-app index so per-task dispatch
    does not rescan every registered site (the seed's `pick` and the
    engine's multi-site check were both O(sites) per task).  The index is
    rebuilt lazily after `add_site`; a site's `apps` set is treated as
    fixed once the site is registered.
    """

    def __init__(self, sites: list[Site]):
        self.sites = list(sites)
        self._by_app: dict = {}

    def add_site(self, site: Site):
        self.sites.append(site)
        self._by_app.clear()

    def sites_for(self, app: str | None) -> list[Site]:
        """Valid sites for an app (cached; app cardinality is workflow-level
        and small, so the cache is bounded)."""
        cands = self._by_app.get(app)
        if cands is None:
            cands = [s for s in self.sites if s.valid_for(app)]
            self._by_app[app] = cands
        return cands

    def pick(self, app: str | None, now: float,
             require_room: bool = False, slack: float = 2.0) -> Optional[Site]:
        best, best_w = None, -1.0
        for s in self.sites_for(app):
            if now < s.suspended_until:
                continue
            if require_room and s.outstanding >= s.capacity * slack:
                continue
            # queue-depth-aware proportional weight: equilibrium backlog is
            # proportional to score x capacity, so fast/large sites get more
            # jobs (paper Fig 11) even when every site is saturated
            w = s.score * s.capacity / (1.0 + s.outstanding)
            if w > best_w:
                best, best_w = s, w
        return best

    def any_valid(self, app: str | None) -> bool:
        return bool(self.sites_for(app))
