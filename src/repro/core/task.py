"""Task records, dataflow keying, and task-body execution.

Lowest layer of the scheduler stack (see DESIGN.md §1): a task is a
lightweight record — no OS thread, Karajan-style — carrying its callable,
argument futures, output future, and retry/provenance bookkeeping.  Both the
engine and every provider operate on these records; execution of the body
(`execute_task`) and simulated-duration lookup (`sim_duration`) live here so
providers and the Falkon service share one implementation.
"""
from __future__ import annotations

import hashlib
import itertools
import weakref
from typing import Optional

from repro.core.futures import DataFuture

_task_ids = itertools.count()


class FnKeyRegistry:
    """Stable, GC-safe identity keys for callables.

    ``id(fn)`` is only unique while `fn` is alive: once collected, a new
    callable can land at the same address, so any cache keyed on raw ids
    (vmap bundles, compiled-function caches, prediction caches) can
    silently serve results for the *wrong* callable.  This registry hands
    out monotonically increasing serials and invalidates an id's entry the
    moment its callable dies (weakref finalizer), so a reused address gets
    a fresh serial.  Callables that cannot be weak-referenced (builtins,
    some C extensions) are pinned with a strong reference instead — their
    id can then never be reused while the registry lives.

    Single-threaded by contract: call only from the clock thread (the
    same contract every scheduler object follows, DESIGN.md §10).
    """

    __slots__ = ("_serial", "_by_id")

    def __init__(self):
        self._serial = itertools.count()
        self._by_id: dict = {}     # id(fn) -> (serial, weakref-or-strong-ref)

    def __len__(self) -> int:
        return len(self._by_id)

    def key(self, fn) -> int:
        i = id(fn)
        ent = self._by_id.get(i)
        if ent is not None:
            serial, ref = ent
            target = ref() if isinstance(ref, weakref.ref) else ref
            if target is fn:
                return serial
        serial = next(self._serial)
        try:
            ref = weakref.ref(fn, self._make_reaper(i))
        except TypeError:
            ref = fn                       # un-weakrefable: pin it
        self._by_id[i] = (serial, ref)
        return serial

    def _make_reaper(self, i: int):
        by_id = self._by_id

        def reap(dead_ref):
            # only drop the entry if it still belongs to the dead callable
            # — the id may already have been reused and re-registered
            ent = by_id.get(i)
            if ent is not None and ent[1] is dead_ref:
                del by_id[i]

        return reap


_fn_keys = FnKeyRegistry()


def stable_fn_key(fn) -> int:
    """Process-wide stable identity key for a callable (see
    `FnKeyRegistry`).  Unlike ``id(fn)``, the key is never reused for a
    different callable, so it is safe in long-lived signature caches."""
    return _fn_keys.key(fn)


def arg_signature(args) -> tuple:
    """Structural signature of a call's argument values: per-argument
    ``(shape, dtype-or-type-name)``.  Array-likes (numpy/JAX, anything
    with `.shape`) contribute shape + dtype; scalars and other literals
    contribute their type name.  Two calls with equal signatures can be
    stacked along a new leading axis and executed as one vmapped call."""
    sig = []
    for a in args:
        shape = getattr(a, "shape", None)
        if shape is not None:
            sig.append((tuple(shape), str(getattr(a, "dtype", ""))))
        else:
            sig.append(((), type(a).__name__))
    return tuple(sig)


class Task:
    """One schedulable unit: a lightweight record, not an OS thread.

    Carries the callable (`fn`, None for pure-simulation tasks), argument
    futures (`args`), the output `DataFuture`, declared file `inputs` for
    the data layer, and retry/provenance bookkeeping.  Engines create these
    via `Engine.submit`; providers and the Falkon service consume them.

    Example (normally done for you by `Engine.submit`)::

        t = Task("double", lambda x: 2 * x, [21], DataFuture(),
                 duration=None, app=None, retries=0, durable=False, key="")
        ok, value, err = execute_task(t)      # -> (True, 42, None)
    """

    # "span"/"path0" are observability fields (DESIGN.md §12), deliberately
    # NOT initialized in __init__: the tracing-off hot path never touches
    # them, and the engine assigns both at submit/ready time when a tracer
    # is attached ("path0" encodes parent critical path minus ready time).
    __slots__ = ("id", "name", "key", "fn", "args", "output", "duration",
                 "sim_value", "app", "attempt", "retries_left", "site",
                 "host", "created_time", "submit_time", "start_time",
                 "durable", "fault_check", "_falkon_done", "vmap_key",
                 "site_failures", "inputs", "span", "path0")

    def __init__(self, name: str, fn, args, output: DataFuture,
                 duration: float | None, app: str | None,
                 retries: int, durable: bool, key: str,
                 inputs: tuple = ()):
        self.id = next(_task_ids)
        self.name = name
        self.key = key
        self.fn = fn
        self.args = args
        self.output = output
        self.duration = duration
        self.sim_value = None
        self.app = app
        self.attempt = 0
        self.retries_left = retries
        self.site = None
        self.host = ""
        self.created_time = 0.0
        self.submit_time = 0.0
        self.start_time = 0.0
        self.durable = durable
        self.fault_check = None
        self.vmap_key = None
        # declared file inputs (DataObject tuple) — the data layer's
        # cache-aware dispatch keys on these; empty for compute-only tasks
        self.inputs = inputs
        # lazily allocated on first failure: a dict per task is measurable
        # overhead at 10^6 tasks and almost all tasks never fail
        self.site_failures: Optional[dict] = None


def task_key(name: str, args: list) -> str:
    """Dataflow-stable key for restart-log lookups (paper §3.12).

    Derived from the task name and the *identity* of its inputs (future
    names, array fingerprints, literal reprs) — not from graph position — so
    a modified-and-restarted program still resolves unchanged flows.
    """
    parts = [name]
    for a in args:
        if isinstance(a, DataFuture):
            parts.append(f"f:{a.name or a.id}")
        elif hasattr(a, "shape") and hasattr(a, "dtype"):
            # arrays: cheap structural fingerprint (repr would format the
            # whole buffer)
            parts.append(f"arr:{a.shape}:{a.dtype}:{id(a)}")
        else:
            parts.append(repr(a))
    return name + "#" + hashlib.sha1("|".join(parts).encode()).hexdigest()[:12]


def sim_duration(task) -> float:
    d = getattr(task, "duration", None)
    return float(d) if d else 0.0


def execute_task(task):
    """Run a task body, returning (ok, value, error).

    Pure-simulation tasks (no callable, no fault check) take the early path:
    they dominate the paper-figure benchmarks and must cost O(ns), not a
    try/except plus an argument scan.
    """
    chk = getattr(task, "fault_check", None)
    fn = getattr(task, "fn", None)
    if chk is None and fn is None:
        return True, getattr(task, "sim_value", None), None
    if chk is not None:
        try:
            chk(task)
        except BaseException as err:  # noqa: BLE001
            return False, None, err
    if fn is None:
        return True, getattr(task, "sim_value", None), None
    try:
        args = [a.get() if hasattr(a, "get") and hasattr(a, "on_done") else a
                for a in task.args]
        return True, fn(*args), None
    except BaseException as err:  # noqa: BLE001 - engine handles retries
        return False, None, err
