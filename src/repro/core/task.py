"""Task records, dataflow keying, and task-body execution.

Lowest layer of the scheduler stack (see DESIGN.md §1): a task is a
lightweight record — no OS thread, Karajan-style — carrying its callable,
argument futures, output future, and retry/provenance bookkeeping.  Both the
engine and every provider operate on these records; execution of the body
(`execute_task`) and simulated-duration lookup (`sim_duration`) live here so
providers and the Falkon service share one implementation.
"""
from __future__ import annotations

import hashlib
import itertools
from typing import Optional

from repro.core.futures import DataFuture

_task_ids = itertools.count()


class Task:
    """One schedulable unit: a lightweight record, not an OS thread.

    Carries the callable (`fn`, None for pure-simulation tasks), argument
    futures (`args`), the output `DataFuture`, declared file `inputs` for
    the data layer, and retry/provenance bookkeeping.  Engines create these
    via `Engine.submit`; providers and the Falkon service consume them.

    Example (normally done for you by `Engine.submit`)::

        t = Task("double", lambda x: 2 * x, [21], DataFuture(),
                 duration=None, app=None, retries=0, durable=False, key="")
        ok, value, err = execute_task(t)      # -> (True, 42, None)
    """

    __slots__ = ("id", "name", "key", "fn", "args", "output", "duration",
                 "sim_value", "app", "attempt", "retries_left", "site",
                 "host", "created_time", "submit_time", "start_time",
                 "durable", "fault_check", "_falkon_done", "vmap_key",
                 "site_failures", "inputs")

    def __init__(self, name: str, fn, args, output: DataFuture,
                 duration: float | None, app: str | None,
                 retries: int, durable: bool, key: str,
                 inputs: tuple = ()):
        self.id = next(_task_ids)
        self.name = name
        self.key = key
        self.fn = fn
        self.args = args
        self.output = output
        self.duration = duration
        self.sim_value = None
        self.app = app
        self.attempt = 0
        self.retries_left = retries
        self.site = None
        self.host = ""
        self.created_time = 0.0
        self.submit_time = 0.0
        self.start_time = 0.0
        self.durable = durable
        self.fault_check = None
        self.vmap_key = None
        # declared file inputs (DataObject tuple) — the data layer's
        # cache-aware dispatch keys on these; empty for compute-only tasks
        self.inputs = inputs
        # lazily allocated on first failure: a dict per task is measurable
        # overhead at 10^6 tasks and almost all tasks never fail
        self.site_failures: Optional[dict] = None


def task_key(name: str, args: list) -> str:
    """Dataflow-stable key for restart-log lookups (paper §3.12).

    Derived from the task name and the *identity* of its inputs (future
    names, array fingerprints, literal reprs) — not from graph position — so
    a modified-and-restarted program still resolves unchanged flows.
    """
    parts = [name]
    for a in args:
        if isinstance(a, DataFuture):
            parts.append(f"f:{a.name or a.id}")
        elif hasattr(a, "shape") and hasattr(a, "dtype"):
            # arrays: cheap structural fingerprint (repr would format the
            # whole buffer)
            parts.append(f"arr:{a.shape}:{a.dtype}:{id(a)}")
        else:
            parts.append(repr(a))
    return name + "#" + hashlib.sha1("|".join(parts).encode()).hexdigest()[:12]


def sim_duration(task) -> float:
    d = getattr(task, "duration", None)
    return float(d) if d else 0.0


def execute_task(task):
    """Run a task body, returning (ok, value, error).

    Pure-simulation tasks (no callable, no fault check) take the early path:
    they dominate the paper-figure benchmarks and must cost O(ns), not a
    try/except plus an argument scan.
    """
    chk = getattr(task, "fault_check", None)
    fn = getattr(task, "fn", None)
    if chk is None and fn is None:
        return True, getattr(task, "sim_value", None), None
    if chk is not None:
        try:
            chk(task)
        except BaseException as err:  # noqa: BLE001
            return False, None, err
    if fn is None:
        return True, getattr(task, "sim_value", None), None
    try:
        args = [a.get() if hasattr(a, "get") and hasattr(a, "on_done") else a
                for a in task.args]
        return True, fn(*args), None
    except BaseException as err:  # noqa: BLE001 - engine handles retries
        return False, None, err
