"""Single-assignment data futures (Karajan §3.9).

A `DataFuture` is a placeholder resolved exactly once; consumers register
callbacks instead of blocking threads — Karajan's lightweight-thread model.
The deliberately small footprint is measured by benchmarks/scalability.py
(paper Fig 9: ~800 B/thread Karajan, ~3.2 KB/node Swift).
"""
from __future__ import annotations

import itertools
from typing import Any, Callable

_ids = itertools.count()


class FutureError(Exception):
    pass


class DataFuture:
    __slots__ = ("id", "name", "_value", "_error", "_state", "_callbacks")

    PENDING, RESOLVED, FAILED = 0, 1, 2

    def __init__(self, name: str = ""):
        self.id = next(_ids)
        self.name = name
        self._value: Any = None
        self._error: BaseException | None = None
        self._state = self.PENDING
        self._callbacks: list[Callable] = []

    @property
    def resolved(self) -> bool:
        return self._state == self.RESOLVED

    @property
    def failed(self) -> bool:
        return self._state == self.FAILED

    @property
    def done(self) -> bool:
        return self._state != self.PENDING

    def set(self, value: Any) -> None:
        if self._state != self.PENDING:
            raise FutureError(f"future {self.name or self.id} already set")
        self._value = value
        self._state = self.RESOLVED
        cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            cb(self)

    def set_error(self, err: BaseException) -> None:
        if self._state != self.PENDING:
            raise FutureError(f"future {self.name or self.id} already set")
        self._error = err
        self._state = self.FAILED
        cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            cb(self)

    def get(self) -> Any:
        if self._state == self.RESOLVED:
            return self._value
        if self._state == self.FAILED:
            raise self._error
        raise FutureError(f"future {self.name or self.id} not resolved")

    def on_done(self, cb: Callable[["DataFuture"], None]) -> None:
        if self._state != self.PENDING:
            cb(self)
        else:
            self._callbacks.append(cb)

    def __repr__(self):
        st = {0: "pending", 1: "resolved", 2: "failed"}[self._state]
        return f"<Future {self.name or self.id} {st}>"


def resolved(value: Any, name: str = "") -> DataFuture:
    f = DataFuture(name)
    f.set(value)
    return f


def when_all(futures: list[DataFuture], cb: Callable[[], None]) -> None:
    """Invoke cb once every future is done (resolved or failed)."""
    remaining = [len(futures)]
    if not futures:
        cb()
        return

    def one(_):
        remaining[0] -= 1
        if remaining[0] == 0:
            cb()

    for f in futures:
        f.on_done(one)
