"""Single-assignment data futures (Karajan §3.9).

A `DataFuture` is a placeholder resolved exactly once; consumers register
callbacks instead of blocking threads — Karajan's lightweight-thread model.
The deliberately small footprint is measured by benchmarks/scalability.py
(paper Fig 9: ~800 B/thread Karajan, ~3.2 KB/node Swift).
"""
from __future__ import annotations

import itertools
from typing import Any, Callable

_ids = itertools.count()


class FutureError(Exception):
    pass


class DataFuture:
    """Single-assignment future: set exactly once, consumed via callbacks.

    The engine's dataflow edges are these — a task's output is a
    `DataFuture`, and downstream tasks list it in their `args` to declare
    the dependency.  No thread ever blocks: `on_done` registers a callback
    (fired immediately if already resolved), `get()` reads a resolved
    value or raises the stored error.

    Example::

        f = DataFuture(name="x")
        f.on_done(lambda fut: print("got", fut.get()))
        f.set(42)                      # fires the callback
        assert f.resolved and f.get() == 42
    """

    # __weakref__ so lifetime contracts (DESIGN.md §9: resolved frontiers
    # are GC-able) can be observed without retaining the future.  "path" is
    # the critical-path length up to this future (DESIGN.md §12) — always
    # initialized so the traced engine reads it as a plain attribute on
    # its hot path; only meaningful when a tracer stamps it at completion
    __slots__ = ("id", "name", "_value", "_error", "_state", "_callbacks",
                 "path", "__weakref__")

    PENDING, RESOLVED, FAILED = 0, 1, 2

    def __init__(self, name: str = ""):
        self.id = next(_ids)
        self.name = name
        self._value: Any = None
        self._error: BaseException | None = None
        self._state = self.PENDING
        self.path = 0.0
        # callback storage is shape-polymorphic to keep the per-future
        # footprint small at 10^6-future scale (DESIGN.md §9): None (no
        # callbacks, the transient majority), a bare callable (exactly one
        # — the dataflow-chain common case), or a list (fan-out)
        self._callbacks: Any = None

    @property
    def resolved(self) -> bool:
        return self._state == self.RESOLVED

    @property
    def failed(self) -> bool:
        return self._state == self.FAILED

    @property
    def done(self) -> bool:
        return self._state != self.PENDING

    def _fire(self) -> None:
        """Detach and invoke the registered callbacks (shape-polymorphic:
        None / bare callable / list — must mirror `on_done`)."""
        cbs, self._callbacks = self._callbacks, None
        if cbs is not None:
            if type(cbs) is list:
                for cb in cbs:
                    cb(self)
            else:
                cbs(self)

    def set(self, value: Any) -> None:
        if self._state != self.PENDING:
            raise FutureError(f"future {self.name or self.id} already set")
        self._value = value
        self._state = self.RESOLVED
        self._fire()

    def set_error(self, err: BaseException) -> None:
        if self._state != self.PENDING:
            raise FutureError(f"future {self.name or self.id} already set")
        self._error = err
        self._state = self.FAILED
        self._fire()

    def get(self) -> Any:
        if self._state == self.RESOLVED:
            return self._value
        if self._state == self.FAILED:
            raise self._error
        raise FutureError(f"future {self.name or self.id} not resolved")

    def on_done(self, cb: Callable[["DataFuture"], None]) -> None:
        if self._state != self.PENDING:
            cb(self)
        elif self._callbacks is None:
            self._callbacks = cb
        elif type(self._callbacks) is list:
            self._callbacks.append(cb)
        else:
            self._callbacks = [self._callbacks, cb]

    def __repr__(self):
        st = {0: "pending", 1: "resolved", 2: "failed"}[self._state]
        return f"<Future {self.name or self.id} {st}>"


def resolved(value: Any, name: str = "") -> DataFuture:
    """A future already resolved to `value` — lifts a literal into the
    dataflow graph (e.g. ``wf.foreach(resolved([1, 2, 3]), body)``)."""
    f = DataFuture(name)
    f.set(value)
    return f


class CompletionCounter:
    """Counting completion sink (DESIGN.md §9).

    Observes futures without retaining references to them: `add` registers
    a bound-method callback on the future and keeps only counters — once a
    future resolves it is reachable solely through whoever else holds it,
    so resolved frontiers are GC-able even when millions of futures flow
    through one counter.  This is what `when_all` and windowed `foreach`
    expansion are built on.

    `on_each(future)` fires at each completion (the caller reads the value
    and drops the reference); `close(on_drain)` declares that no more
    futures will be added — `on_drain` fires once the completion count
    catches up with the add count (immediately if it already has).  The
    first failure's error is retained in `first_error`.
    """

    __slots__ = ("added", "done", "failed", "first_error", "_on_each",
                 "_drain_cb", "_closed")

    def __init__(self, on_each: Callable[[DataFuture], None] | None = None):
        self.added = 0
        self.done = 0
        self.failed = 0
        self.first_error: BaseException | None = None
        self._on_each = on_each
        self._drain_cb: Callable[[], None] | None = None
        self._closed = False

    @property
    def pending(self) -> int:
        return self.added - self.done

    def add(self, fut: DataFuture) -> None:
        self.added += 1
        fut.on_done(self._one)

    def _one(self, f: DataFuture) -> None:
        self.done += 1
        if f.failed:
            self.failed += 1
            if self.first_error is None:
                self.first_error = f._error
        if self._on_each is not None:
            self._on_each(f)
        if self._closed and self.done == self.added:
            cb, self._drain_cb = self._drain_cb, None
            if cb is not None:
                cb()

    def close(self, on_drain: Callable[[], None]) -> None:
        self._closed = True
        if self.done == self.added:
            on_drain()
        else:
            self._drain_cb = on_drain


def when_all(futures, cb: Callable[[], None]) -> None:
    """Invoke cb once every future is done (resolved or failed).

    Accepts any iterable; consumes it once and holds no references to the
    futures (only counters — see `CompletionCounter`), so the caller's own
    lifetime management decides when resolved futures are freed.
    """
    counter = CompletionCounter()
    for f in futures:
        counter.add(f)
    counter.close(cb)
