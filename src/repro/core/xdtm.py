"""XDTM: two-level dataset typing & mapping (paper §3.2, §3.5).

Logical datasets are typed structures independent of physical layout;
*mappers* resolve logical structure -> physical members at runtime, which is
what enables dynamic workflow expansion (`foreach` over data whose members
are only known after an upstream task ran — the Montage overlap table).

Mappers provided (mirroring the paper's run_mapper / csv_mapper / file
mapper, plus the TPU-framework addition):

  * FileSystemMapper — groups files in a directory by prefix + suffix set
    (the fMRI `run_mapper`: volume = .img + .hdr pair)
  * CSVMapper — maps a delimited table into a list of typed records
    (the Montage `csv_mapper` for the overlap list)
  * ShardMapper — maps a logical global array to physical .npz shard files
    (the XDTM idea applied to checkpoints / data-parallel arrays: logical
    type = global shape, mapping = shard layout)
"""
from __future__ import annotations

import dataclasses
import os
import re
import sys
from typing import Any, Callable

# numpy is imported lazily: it is only needed by `ShardMapper` and by
# typechecks against numpy scalars, and a pure-scheduler process (the
# streaming-expansion benchmarks, DESIGN.md §9) should not pay ~35 MB of
# RSS for an import it never uses.


# ---------------------------------------------------------------------------
# logical type system (C-style syntax for XML-Schema-backed types, §3.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Primitive:
    name: str  # int | float | string | boolean | file


@dataclasses.dataclass(frozen=True)
class Struct:
    name: str
    fields: tuple[tuple[str, Any], ...]  # (field name, type)


@dataclasses.dataclass(frozen=True)
class ArrayOf:
    item: Any


INT = Primitive("int")
FLOAT = Primitive("float")
STRING = Primitive("string")
FILE = Primitive("file")


def typecheck(value: Any, t: Any) -> bool:
    if isinstance(t, Primitive):
        # numpy scalars only exist if numpy is already imported, so the
        # fallback probe via sys.modules never triggers the import itself
        if t.name == "int":
            if isinstance(value, int):
                return True
            np = sys.modules.get("numpy")
            return np is not None and isinstance(value, np.integer)
        if t.name == "float":
            if isinstance(value, (int, float)):
                return True
            np = sys.modules.get("numpy")
            return np is not None and isinstance(value, np.floating)
        if t.name == "string":
            return isinstance(value, str)
        if t.name == "file":
            return isinstance(value, (str, PhysicalRef))
        return True
    if isinstance(t, Struct):
        if not isinstance(value, dict):
            return False
        return all(f in value and typecheck(value[f], ft)
                   for f, ft in t.fields)
    if isinstance(t, ArrayOf):
        return isinstance(value, (list, tuple)) and all(
            typecheck(v, t.item) for v in value)
    return True


@dataclasses.dataclass(frozen=True)
class PhysicalRef:
    """Pointer to physical data (file path + optional slice metadata)."""
    path: str
    meta: tuple = ()

    def exists(self) -> bool:
        return os.path.exists(self.path)


# ---------------------------------------------------------------------------
# mappers
# ---------------------------------------------------------------------------

class Mapper:
    """Resolve logical dataset -> physical members.  Called at *runtime*
    (dynamic workflow expansion, §3.6)."""

    logical_type: Any = None

    def members(self) -> list[Any]:
        raise NotImplementedError


class ListMapper(Mapper):
    """Map a logical dataset onto an in-memory list::

        ds = Dataset(ArrayOf(INT), ListMapper([1, 2, 3]))
        wf.foreach(ds, body)           # members resolved at expansion time
    """

    def __init__(self, items: list, logical_type: Any = None):
        self._items = list(items)
        self.logical_type = logical_type or ArrayOf(None)

    def members(self) -> list[Any]:
        return list(self._items)


class FileSystemMapper(Mapper):
    """Paper's run_mapper: group files sharing a prefix by suffix set.

    members() -> list of dicts {suffix: PhysicalRef} (e.g. volume =
    {"img": ..., "hdr": ...}), ordered by the trailing index in the name.
    """

    def __init__(self, location: str, prefix: str,
                 suffixes: tuple[str, ...] = ("img", "hdr")):
        self.location = location
        self.prefix = prefix
        self.suffixes = suffixes
        self.logical_type = ArrayOf(Struct("Volume", tuple(
            (s, FILE) for s in suffixes)))

    def members(self) -> list[dict]:
        rx = re.compile(re.escape(self.prefix) + r"[._-]?(\d+)\.(\w+)$")
        groups: dict[str, dict] = {}
        if not os.path.isdir(self.location):
            return []
        for fn in sorted(os.listdir(self.location)):
            m = rx.match(fn)
            if not m or m.group(2) not in self.suffixes:
                continue
            groups.setdefault(m.group(1), {})[m.group(2)] = PhysicalRef(
                os.path.join(self.location, fn))
        return [groups[k] for k in sorted(groups, key=int)
                if len(groups[k]) == len(self.suffixes)]


class CSVMapper(Mapper):
    """Paper's csv_mapper (Montage overlap table, Fig 2/3)."""

    def __init__(self, file: str, header: bool = True, hdelim: str = "|",
                 skip: int = 0, types: Struct | None = None):
        self.file = file
        self.header = header
        self.hdelim = hdelim
        self.skip = skip
        self.types = types
        self.logical_type = ArrayOf(types)

    def members(self) -> list[dict]:
        path = self.file.path if isinstance(self.file, PhysicalRef) else self.file
        with open(path) as f:
            lines = [ln.rstrip("\n") for ln in f if ln.strip()]
        cols = None
        out = []
        body = lines
        if self.header:
            cols = [c.strip() for c in body[0].split(self.hdelim)]
            body = body[1 + self.skip:]
        for ln in body:
            vals = [v.strip() for v in ln.split(self.hdelim)]
            if cols is None:
                cols = [f"c{i}" for i in range(len(vals))]
            rec = dict(zip(cols, vals))
            if self.types is not None:
                for fname, ftype in self.types.fields:
                    if fname in rec and isinstance(ftype, Primitive):
                        if ftype.name == "int":
                            rec[fname] = int(rec[fname])
                        elif ftype.name == "float":
                            rec[fname] = float(rec[fname])
            out.append(rec)
        return out


class ShardMapper(Mapper):
    """Logical global array <-> physical .npz shards (XDTM for the TPU
    framework: the logical type is the global shape/dtype; the mapping is the
    shard layout).  Used by the checkpointer."""

    def __init__(self, directory: str, name: str, global_shape: tuple,
                 dtype: str, n_shards: int, shard_axis: int = 0):
        self.directory = directory
        self.name = name
        self.global_shape = tuple(global_shape)
        self.dtype = dtype
        self.n_shards = n_shards
        self.shard_axis = shard_axis

    def shard_path(self, i: int) -> str:
        return os.path.join(self.directory,
                            f"{self.name}.shard{i:04d}of{self.n_shards:04d}.npz")

    def members(self) -> list[PhysicalRef]:
        return [PhysicalRef(self.shard_path(i), meta=("shard", i))
                for i in range(self.n_shards)]

    def save(self, array) -> list[PhysicalRef]:
        import numpy as np
        os.makedirs(self.directory, exist_ok=True)
        parts = np.array_split(array, self.n_shards, axis=self.shard_axis)
        refs = []
        for i, part in enumerate(parts):
            np.savez(self.shard_path(i), data=part)
            refs.append(PhysicalRef(self.shard_path(i), meta=("shard", i)))
        return refs

    def load(self):
        import numpy as np
        parts = [np.load(self.shard_path(i))["data"]
                 for i in range(self.n_shards)]
        return np.concatenate(parts, axis=self.shard_axis)


# ---------------------------------------------------------------------------
# logical dataset handle
# ---------------------------------------------------------------------------

class Dataset:
    """A logical dataset bound to a mapper (paper line 26-27:
    ``Run bold1<run_mapper; location=..., prefix=...>``)."""

    def __init__(self, mapper: Mapper, name: str = ""):
        self.mapper = mapper
        self.name = name

    def members(self) -> list[Any]:
        return self.mapper.members()

    def __repr__(self):
        return f"<Dataset {self.name} via {type(self.mapper).__name__}>"
