"""SwiftScript-style workflow DSL (paper §3.1-3.7), embedded in Python.

* atomic procedures    — typed interfaces to callables (paper lines 7-12)
* compound procedures  — plain Python composition over futures (lines 13-25)
* foreach              — *dynamic* parallel iteration: the collection may be
  a future or a mapped Dataset whose members are only known at runtime
  (paper §3.6, the Montage overlap table) — expansion happens on resolution.
  `window=` switches to streaming expansion (DESIGN.md §9): a bounded
  frontier refilled as body futures resolve, throttled by the engine's
  submit-side backpressure; `reduce=`/`keep_results=False` fold results
  instead of retaining them
* when                 — conditional execution on runtime data
* then                 — continuation on a future's value (monadic bind);
  the building block for deferring pipeline stages to resolution time

Implicit parallelism: procedures return futures immediately; data
dependencies alone order execution (pipelining, §3.13).

The DSL is engine-shape-agnostic: a `Workflow` binds to anything exposing
the engine submission surface (`submit(...)` returning a `DataFuture`,
`run()`, `clock`) — a single `Engine` or a multi-shard `FederatedEngine`
(DESIGN.md §8).  In particular `foreach` expands at *runtime* through
`engine.submit`, so over a federation each expanded body task is
partitioned to a shard as it is created, and cross-shard data
dependencies are carried by the federation's mailbox proxies with no
change to workflow code.
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING, Any, Callable, Union

from repro.core.datastore import inputs_of
from repro.core.engine import Engine
from repro.core.futures import (CompletionCounter, DataFuture, resolved,
                                when_all)
from repro.core.task import task_key
from repro.core.xdtm import Dataset, Mapper, typecheck

if TYPE_CHECKING:
    from repro.core.federation import FederatedEngine
    AnyEngine = Union[Engine, "FederatedEngine"]


class Procedure:
    """An atomic procedure: a typed, dispatchable interface to a callable.

    `inputs` declares the procedure's file inputs for the data layer
    (DESIGN.md §7): a `DataObject`, an iterable of them, or a callable
    mapping the call arguments to either — so a foreach body can name
    per-item files (`inputs=lambda mol: (archive, mol_file[mol])`).
    """

    def __init__(self, wf: "Workflow", fn: Callable | None, name: str,
                 duration: float | Callable | None = None,
                 app: str | None = None, durable: bool = False,
                 input_types: tuple = (), vmap_key=None, inputs=None):
        self.wf = wf
        self.fn = fn
        self.name = name
        self.duration = duration
        # a workflow opened through the service carries a default app (its
        # tenant id) so every procedure lands in that tenant's ReadyQueue
        # bucket — the unit fair-share schedules over (DESIGN.md §15)
        self.app = app or wf.default_app or name
        self.durable = durable
        self.input_types = input_types
        self.vmap_key = vmap_key
        # materialize non-callable declarations once: a one-shot iterator
        # (generator) would silently yield () on every call after the first
        self.inputs = inputs if inputs is None or callable(inputs) \
            else inputs_of(inputs)

    def __call__(self, *args) -> DataFuture:
        if self.input_types:
            for a, t in zip(args, self.input_types):
                if not isinstance(a, DataFuture) and t is not None:
                    if not typecheck(a, t):
                        raise TypeError(
                            f"{self.name}: argument {a!r} fails type {t}")
        dur = self.duration
        if callable(dur):
            # per-call durations (`duration=lambda mol: cost[mol]`): resolve
            # against the raw call args at submit time.  Futures among the
            # args are passed through unresolved — a duration spec that
            # needs runtime *values* should key on the literal args instead.
            dur = dur(*args)
        inputs = self.inputs
        if inputs is not None and type(inputs) is not tuple:
            inputs = inputs_of(inputs, *args)   # callable spec: map call args
        wf = self.wf
        key = wf.stable_key(self.name, args) \
            if wf.key_prefix is not None else None
        return wf.engine.submit(
            self.name, self.fn, list(args), duration=dur, app=self.app,
            durable=self.durable, key=key, vmap_key=self.vmap_key,
            inputs=inputs)


class Workflow:
    """SwiftScript-style DSL over any engine (paper §3.1–3.7).

    Binds to anything exposing the engine submission surface — a single
    `Engine` or a multi-shard `FederatedEngine` — and provides `atomic`
    procedures, dynamic `foreach`, `then` continuations, `when`
    conditionals, and `gather` joins; all return futures and run when
    `run()` drives the clock.

    Example::

        wf = Workflow("demo", engine)

        @wf.atomic
        def square(x):
            return x * x

        total = wf.gather([square(i) for i in range(10)])
        wf.run()
        assert total.get() == [i * i for i in range(10)]
    """

    def __init__(self, name: str, engine: "AnyEngine",
                 key_prefix: str | None = None,
                 default_app: str | None = None):
        self.name = name
        self.engine = engine
        # resumable handles (DESIGN.md §15): a non-None `key_prefix`
        # namespaces every procedure call with a dataflow-stable key
        # (``prefix + task_key(name, args)``, occurrence-disambiguated),
        # so re-building the same program against a `JobStore`-backed
        # resume view restores durably completed tasks instead of
        # re-running them.  `WorkflowService.open` sets this to
        # ``"<wf_id>::"``; `default_app` tags submissions for per-tenant
        # fair share.
        self.key_prefix = key_prefix
        self.default_app = default_app
        self._occurrences: dict[str, int] = {}

    def stable_key(self, name: str, args) -> str:
        """Dataflow-stable unique key for one procedure call: content
        fingerprint plus an occurrence counter, so two calls with the
        same (name, args) get distinct durable rows while a deterministic
        re-build maps the n-th duplicate to the same key it had before
        the crash."""
        base = self.key_prefix + task_key(name, list(args))
        occ = self._occurrences
        n = occ.get(base)
        if n is None:
            occ[base] = 1
            return base
        occ[base] = n + 1
        return f"{base}~{n}"

    # ------------------------------------------------------------------
    def atomic(self, fn: Callable | None = None, *, name: str | None = None,
               duration: float | None = None, app: str | None = None,
               durable: bool = False, input_types: tuple = (),
               vmap_key=None, inputs=None):
        """Decorator: define an atomic procedure."""

        def wrap(f):
            return Procedure(self, f, name or (f.__name__ if f else "task"),
                             duration=duration, app=app, durable=durable,
                             input_types=input_types, vmap_key=vmap_key,
                             inputs=inputs)

        if fn is not None:
            return wrap(fn)
        return wrap

    def sim_proc(self, name: str, duration: float, app: str | None = None,
                 inputs=None):
        """Procedure with a simulated duration and no body (benchmarks)."""
        return Procedure(self, None, name, duration=duration, app=app,
                         inputs=inputs)

    # ------------------------------------------------------------------
    def foreach(self, collection, body: Callable[[Any], Any],
                name: str = "foreach", window: int | None = None,
                reduce: Callable[[Any, Any], Any] | None = None,
                init: Any = None,
                keep_results: bool | None = None) -> DataFuture:
        """Parallel iteration with runtime expansion (paper §3.4/3.6).

        `collection` may be: a list, a generator, a Dataset (mapper resolved
        lazily at expansion time), or a DataFuture resolving to either.
        `body(item)` runs at expansion time and may submit tasks (returning
        futures); the result future resolves to the list of all body results.
        An exception raised by `body` fails the result future instead of
        escaping into the clock callback that triggered expansion.

        **Windowed (streaming) expansion** (DESIGN.md §9): with ``window=k``
        at most k body items are in flight at once — expansion refills from
        the collection (consumed lazily, so a generator is never
        materialized) as body futures resolve, bounding memory by the
        frontier instead of the graph.  The refill loop additionally keys on
        the engine's submit-side backpressure signal (``engine.saturated()``)
        so the standing frontier tracks pool capacity: while the engine has
        ≥ slack x pool capacity in flight, refills pause (never below one
        outstanding item, so progress is guaranteed).  ``window=None`` (the
        default) is the eager path, behaviorally unchanged.

        **Streaming reduction**: ``reduce=fn`` folds each body result into
        an accumulator (seeded with ``init``) instead of retaining the
        result list; the output future resolves to the final accumulator.
        ``keep_results=False`` without a reducer resolves to the count of
        completed items.  With ``window=``, the fold is applied in
        *completion* order (deterministic under `SimClock`, but only equal
        to the eager member-order fold for commutative/associative
        reducers); eager mode folds in member order.  The first body-future
        failure fails the output (streaming mode stops refilling; in-flight
        items still run to completion).
        """
        if keep_results is None:
            keep_results = reduce is None
        if reduce is not None and keep_results:
            raise ValueError("reduce= implies keep_results=False")
        if window is not None and window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        out = DataFuture(name=name)
        coll_f = collection if isinstance(collection, DataFuture) \
            else resolved(collection)

        def members_of(coll):
            if isinstance(coll, (Dataset, Mapper)):
                return coll.members()           # dynamic mapping (§3.6)
            return coll

        def expand(f: DataFuture):
            if f.failed:
                out.set_error(f._error)
                return
            try:
                members = list(members_of(f.get()))
                results = [body(m) for m in members]
            except Exception as err:  # noqa: BLE001 — fail the future,
                out.set_error(err)        # don't escape the clock callback
                return
            futs = [r for r in results if isinstance(r, DataFuture)]

            def finish():
                bad = [ff for ff in futs if ff.failed]
                if bad:
                    out.set_error(bad[0]._error)
                    return
                vals = (r.get() if isinstance(r, DataFuture) else r
                        for r in results)
                if keep_results:
                    out.set(list(vals))
                elif reduce is not None:
                    acc = init
                    try:
                        for v in vals:          # member order (eager mode)
                            acc = reduce(acc, v)
                    except Exception as err:  # noqa: BLE001 — a raising
                        out.set_error(err)        # reducer fails the future
                        return                    # (like the windowed path)
                    out.set(acc)
                else:
                    out.set(sum(1 for _ in vals))

            when_all(futs, finish)

        def expand_windowed(f: DataFuture):
            if f.failed:
                out.set_error(f._error)
                return
            try:
                items = iter(members_of(f.get()))
            except Exception as err:  # noqa: BLE001
                out.set_error(err)
                return
            st = _WindowState(self.engine, out, body, items, window,
                              reduce, init, keep_results)
            st.refill()

        coll_f.on_done(expand_windowed if window is not None else expand)
        return out

    # ------------------------------------------------------------------
    def then(self, fut, fn: Callable[[Any], Any],
             name: str = "then") -> DataFuture:
        """Continuation: run ``fn(value)`` when `fut` resolves; a future
        returned by `fn` is flattened into the result (monadic bind).

        This is dynamic expansion (§3.6) at task granularity, and the
        building block for *deferred graph construction* (DESIGN.md §9): a
        `foreach` body can submit only its first pipeline stage and grow
        the rest via `then` as stages resolve, so even a deep per-item
        pipeline contributes O(stage) — not O(pipeline) — tasks to the
        standing frontier.  Upstream failure propagates without calling
        `fn`; an exception in `fn` fails the result future.
        """
        out = DataFuture(name=name)
        src = fut if isinstance(fut, DataFuture) else resolved(fut)

        def cont(f: DataFuture):
            if f.failed:
                out.set_error(f._error)
                return
            try:
                res = fn(f._value)
            except Exception as err:  # noqa: BLE001
                out.set_error(err)
                return
            if isinstance(res, DataFuture):
                res.on_done(lambda r: out.set_error(r._error) if r.failed
                            else out.set(r._value))
            else:
                out.set(res)

        src.on_done(cont)
        return out

    # ------------------------------------------------------------------
    def when(self, cond, then_fn: Callable[[], Any],
             else_fn: Callable[[], Any] | None = None,
             name: str = "when") -> DataFuture:
        """Conditional execution on runtime data (paper §3.6, Montage
        sub-region co-add decision).  An exception raised by the taken
        branch fails the result future.  `when` is `then` with a branch
        select: same failure propagation, same future flattening."""
        return self.then(
            cond,
            lambda v: then_fn() if v else (else_fn() if else_fn else None),
            name=name)

    # ------------------------------------------------------------------
    def gather(self, futures, name: str = "gather",
               reduce: Callable[[Any, Any], Any] | None = None,
               init: Any = None,
               keep_results: bool | None = None) -> DataFuture:
        """Join a collection of futures into one.

        Default: resolves to the list of all values (first failure fails
        the join).  Bounded accumulation (DESIGN.md §9): with ``reduce=``
        the values are folded into an accumulator in completion order and
        with ``keep_results=False`` alone the join resolves to a count — in
        both modes `futures` may be any iterable (consumed once, lazily)
        and no reference to the futures or their values is retained, so a
        streaming producer's resolved futures stay GC-able.
        """
        if keep_results is None:
            keep_results = reduce is None
        if reduce is not None and keep_results:
            raise ValueError("reduce= implies keep_results=False")
        out = DataFuture(name=name)

        if keep_results:
            futures = list(futures)

            def finish():
                bad = [f for f in futures if f.failed]
                if bad:
                    out.set_error(bad[0]._error)
                else:
                    out.set([f.get() for f in futures])

            when_all(futures, finish)
            return out

        acc_box = [init]

        def on_each(f: DataFuture):
            if f.failed or out.done or reduce is None:
                return                          # first_error is retained
            try:
                acc_box[0] = reduce(acc_box[0], f._value)
            except Exception as err:  # noqa: BLE001 — a raising reducer
                out.set_error(err)              # fails the join immediately

        counter = CompletionCounter(on_each)

        def drained():
            if out.done:
                return                          # reducer already failed it
            if counter.first_error is not None:
                out.set_error(counter.first_error)
            elif reduce is not None:
                out.set(acc_box[0])
            else:
                out.set(counter.done - counter.failed)

        for f in futures:
            counter.add(f)
        counter.close(drained)
        return out

    def run(self):
        self.engine.run()


class _WindowState:
    """Refill loop for one windowed `foreach` expansion (DESIGN.md §9).

    Holds the iterator, the in-flight count, and the accumulator — never
    the resolved futures (completion callbacks are bound methods; a body
    future that resolves drops its only reference into this state).  The
    standing frontier is at most `window`, shrinking toward one outstanding
    item while the engine reports submit-side saturation.
    """

    __slots__ = ("engine", "out", "body", "items", "window", "reduce",
                 "init", "keep", "outstanding", "submitted", "delivered",
                 "exhausted", "stopped", "acc", "results", "_refilling",
                 "_saturated", "_add_waiter", "_waiting")

    def __init__(self, engine, out, body, items, window, reduce, init, keep):
        self.engine = engine
        self.out = out
        self.body = body
        self.items = items
        self.window = window
        self.reduce = reduce
        self.acc = init
        self.keep = keep
        self.outstanding = 0
        self.submitted = 0
        self.delivered = 0
        self.exhausted = False
        self.stopped = False           # failed: no more refills
        self.results: list | None = [] if keep else None
        self._refilling = False
        # duck-typed backpressure probe: anything exposing the engine
        # submission surface works; `saturated()` / the waiter hook are
        # optional (without them the window alone bounds the frontier and
        # refills ride body completions)
        self._saturated = getattr(engine, "saturated", None)
        self._add_waiter = getattr(engine, "add_backpressure_waiter", None)
        self._waiting = False

    # -- one item ------------------------------------------------------
    def _submit_next(self) -> bool:
        try:
            item = next(self.items)
        except StopIteration:
            self.exhausted = True
            return False
        except Exception as err:  # noqa: BLE001 — lazy collections may
            self._fail(err)           # raise mid-iteration
            return False
        idx = self.submitted
        self.submitted += 1
        if self.results is not None:
            self.results.append(None)          # slot filled at completion
        try:
            res = self.body(item)
        except Exception as err:  # noqa: BLE001
            self._fail(err)
            return False
        if isinstance(res, DataFuture):
            self.outstanding += 1
            res.on_done(self._one_done if self.results is None
                        else lambda f, i=idx: self._one_done(f, i))
        else:
            self._deliver(res, idx)
        return True

    # -- completion ----------------------------------------------------
    def _one_done(self, f: DataFuture, idx: int | None = None) -> None:
        self.outstanding -= 1
        if self.stopped:
            return                     # late completion after a failure
        if f.failed:
            self._fail(f._error)
            return
        self._deliver(f._value, idx)
        self.refill()

    def _deliver(self, value, idx) -> None:
        self.delivered += 1
        if self.results is not None:
            self.results[idx] = value          # member order, like eager
        elif self.reduce is not None:
            try:
                self.acc = self.reduce(self.acc, value)
            except Exception as err:  # noqa: BLE001
                self._fail(err)

    def _fail(self, err: BaseException) -> None:
        if not self.stopped:
            self.stopped = True
            self.items = iter(())      # drop the collection reference
            self.out.set_error(err)

    def _wake(self) -> None:
        self._waiting = False
        self.refill()

    # -- the refill loop -----------------------------------------------
    def refill(self) -> None:
        if self._refilling:
            return                     # re-entrant completion (already-
        self._refilling = True         # resolved body future): outer loop
        try:                           # continues the fill
            while (not self.stopped and not self.exhausted
                   and self.outstanding < self.window):
                if self.outstanding > 0 and self._saturated is not None \
                        and self._saturated():
                    # backpressure: frontier tracks pool capacity.  Park a
                    # waiter so expansion resumes the moment a completion
                    # frees room — without it, a window's worth of body
                    # pipelines moves in lockstep cohorts (refills only at
                    # whole-pipeline completions) and the pool starves
                    # through each cohort's serial phases.
                    if self._add_waiter is not None and not self._waiting:
                        self._waiting = True
                        self._add_waiter(self._wake)
                    break
                if not self._submit_next():
                    break
        finally:
            self._refilling = False
        if self.exhausted and self.outstanding == 0 and not self.stopped:
            self.stopped = True
            if self.results is not None:
                out_val, self.results = self.results, None
            elif self.reduce is not None:
                out_val = self.acc
            else:
                out_val = self.delivered
            self.out.set(out_val)
