"""SwiftScript-style workflow DSL (paper §3.1-3.7), embedded in Python.

* atomic procedures    — typed interfaces to callables (paper lines 7-12)
* compound procedures  — plain Python composition over futures (lines 13-25)
* foreach              — *dynamic* parallel iteration: the collection may be
  a future or a mapped Dataset whose members are only known at runtime
  (paper §3.6, the Montage overlap table) — expansion happens on resolution
* when                 — conditional execution on runtime data

Implicit parallelism: procedures return futures immediately; data
dependencies alone order execution (pipelining, §3.13).

The DSL is engine-shape-agnostic: a `Workflow` binds to anything exposing
the engine submission surface (`submit(...)` returning a `DataFuture`,
`run()`, `clock`) — a single `Engine` or a multi-shard `FederatedEngine`
(DESIGN.md §8).  In particular `foreach` expands at *runtime* through
`engine.submit`, so over a federation each expanded body task is
partitioned to a shard as it is created, and cross-shard data
dependencies are carried by the federation's mailbox proxies with no
change to workflow code.
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING, Any, Callable, Union

from repro.core.datastore import inputs_of
from repro.core.engine import Engine
from repro.core.futures import DataFuture, resolved, when_all
from repro.core.xdtm import Dataset, Mapper, typecheck

if TYPE_CHECKING:
    from repro.core.federation import FederatedEngine
    AnyEngine = Union[Engine, "FederatedEngine"]


class Procedure:
    """An atomic procedure: a typed, dispatchable interface to a callable.

    `inputs` declares the procedure's file inputs for the data layer
    (DESIGN.md §7): a `DataObject`, an iterable of them, or a callable
    mapping the call arguments to either — so a foreach body can name
    per-item files (`inputs=lambda mol: (archive, mol_file[mol])`).
    """

    def __init__(self, wf: "Workflow", fn: Callable | None, name: str,
                 duration: float | Callable | None = None,
                 app: str | None = None, durable: bool = False,
                 input_types: tuple = (), vmap_key=None, inputs=None):
        self.wf = wf
        self.fn = fn
        self.name = name
        self.duration = duration
        self.app = app or name
        self.durable = durable
        self.input_types = input_types
        self.vmap_key = vmap_key
        # materialize non-callable declarations once: a one-shot iterator
        # (generator) would silently yield () on every call after the first
        self.inputs = inputs if inputs is None or callable(inputs) \
            else inputs_of(inputs)

    def __call__(self, *args) -> DataFuture:
        if self.input_types:
            for a, t in zip(args, self.input_types):
                if not isinstance(a, DataFuture) and t is not None:
                    if not typecheck(a, t):
                        raise TypeError(
                            f"{self.name}: argument {a!r} fails type {t}")
        dur = self.duration
        if callable(dur):
            dur = None  # resolved at dispatch; keep simple: static durations
        inputs = self.inputs
        if inputs is not None and type(inputs) is not tuple:
            inputs = inputs_of(inputs, *args)   # callable spec: map call args
        return self.wf.engine.submit(
            self.name, self.fn, list(args), duration=dur, app=self.app,
            durable=self.durable, vmap_key=self.vmap_key, inputs=inputs)


class Workflow:
    def __init__(self, name: str, engine: "AnyEngine"):
        self.name = name
        self.engine = engine

    # ------------------------------------------------------------------
    def atomic(self, fn: Callable | None = None, *, name: str | None = None,
               duration: float | None = None, app: str | None = None,
               durable: bool = False, input_types: tuple = (),
               vmap_key=None, inputs=None):
        """Decorator: define an atomic procedure."""

        def wrap(f):
            return Procedure(self, f, name or (f.__name__ if f else "task"),
                             duration=duration, app=app, durable=durable,
                             input_types=input_types, vmap_key=vmap_key,
                             inputs=inputs)

        if fn is not None:
            return wrap(fn)
        return wrap

    def sim_proc(self, name: str, duration: float, app: str | None = None,
                 inputs=None):
        """Procedure with a simulated duration and no body (benchmarks)."""
        return Procedure(self, None, name, duration=duration, app=app,
                         inputs=inputs)

    # ------------------------------------------------------------------
    def foreach(self, collection, body: Callable[[Any], Any],
                name: str = "foreach") -> DataFuture:
        """Parallel iteration with runtime expansion (paper §3.4/3.6).

        `collection` may be: a list, a Dataset (mapper resolved lazily at
        expansion time), or a DataFuture resolving to either.  `body(item)`
        runs at expansion time and may submit tasks (returning futures); the
        result future resolves to the list of all body results.
        """
        out = DataFuture(name=name)
        coll_f = collection if isinstance(collection, DataFuture) \
            else resolved(collection)

        def expand(f: DataFuture):
            if f.failed:
                out.set_error(f._error)
                return
            coll = f.get()
            if isinstance(coll, Dataset):
                members = coll.members()        # dynamic mapping (§3.6)
            elif isinstance(coll, Mapper):
                members = coll.members()
            else:
                members = list(coll)
            results = [body(m) for m in members]
            futs = [r for r in results if isinstance(r, DataFuture)]

            def finish():
                bad = [ff for ff in futs if ff.failed]
                if bad:
                    out.set_error(bad[0]._error)
                    return
                out.set([r.get() if isinstance(r, DataFuture) else r
                         for r in results])

            when_all(futs, finish)

        coll_f.on_done(expand)
        return out

    # ------------------------------------------------------------------
    def when(self, cond, then_fn: Callable[[], Any],
             else_fn: Callable[[], Any] | None = None,
             name: str = "when") -> DataFuture:
        """Conditional execution on runtime data (paper §3.6, Montage
        sub-region co-add decision)."""
        out = DataFuture(name=name)
        cond_f = cond if isinstance(cond, DataFuture) else resolved(cond)

        def branch(f: DataFuture):
            if f.failed:
                out.set_error(f._error)
                return
            res = then_fn() if f.get() else (else_fn() if else_fn else None)
            if isinstance(res, DataFuture):
                res.on_done(lambda r: out.set_error(r._error) if r.failed
                            else out.set(r.get()))
            else:
                out.set(res)

        cond_f.on_done(branch)
        return out

    # ------------------------------------------------------------------
    def gather(self, futures: list[DataFuture], name: str = "gather") \
            -> DataFuture:
        out = DataFuture(name=name)

        def finish():
            bad = [f for f in futures if f.failed]
            if bad:
                out.set_error(bad[0]._error)
            else:
                out.set([f.get() for f in futures])

        when_all(list(futures), finish)
        return out

    def run(self):
        self.engine.run()
