"""Device-batched executor pool: fuse tiny tasks into vmapped dispatches.

The paper's task clustering (§3.13) amortizes per-job batch-scheduler
overhead; on an accelerator the analogous cost is per-task dispatch + kernel
launch.  `DeviceExecutorPool` closes that loop behind the same
``submit(task, done, stage=None)`` seam as the real pools
(`repro.core.realpool`, DESIGN.md §10/§11): ready tasks sharing a
`vmap_signature` accumulate into bundles, and each bundle executes as ONE
jitted+vmapped device call — one launch for K tiny tasks — on a dedicated
dispatcher thread.  Per-task completions fan back through
`Clock.post_release`, and the measured bundle time is attributed per task
into bounded `StreamStat`s.

Wiring is identical to the other pools::

    clock = RealClock()
    pool = DeviceExecutorPool(clock, max_bundle=256)
    svc = FalkonService(clock, cfg, pool=pool)
    eng = Engine(clock)
    eng.add_site("dev", FalkonProvider(svc), capacity=256)
    ... submit tasks with vmap_key=... ...
    eng.run(); svc.shutdown()

Batching contract: a task is *batchable* when it carries both a callable
and a ``vmap_key`` (the same opt-in `VmapClusteringProvider` uses — the
user asserts the body is a pure, vmappable JAX function).  Everything else
runs as a measured singleton on the dispatcher thread, so mixed workloads
need no special casing.  Composition with the rest of the stack is
unchanged: DRP provisioning still sizes the *logical* executor set (the
pool is fixed — one device does not grow threads), the data layer's
`stage` closures run on the dispatcher thread inside the measured staging
time, and `foreach(window=)` / federation operate above the pool seam.

Threading contract (DESIGN.md §10): `submit` and `flush` run on the clock
thread; dispatcher threads touch only the bundle queue, the vmapped-jit
cache, and `post`/`release`; completions and all counters run back on the
clock thread.
"""
from __future__ import annotations

import queue
import threading
from functools import partial
from time import perf_counter
from typing import Any, Callable, Optional

from repro.core.clustering import execute_bundle, resolve_args, vmap_signature
from repro.core.metrics import StreamStat
from repro.core.realpool import _require_threadsafe_clock
from repro.core.simclock import Clock

_STOP = object()


class DeviceExecutorPool:
    """Real pool whose dispatch loop fuses same-signature tasks into one
    vmapped device call (DESIGN.md §11).

    Knobs: `max_bundle` caps the fuse width (a full bucket flushes
    immediately); `linger` is the bundling window in clock seconds —
    with the default ``0.0`` a flush is scheduled behind the current
    event cascade, so every task dispatched in one scheduler pump (up to
    the site's throttle) lands in the same bundle without adding latency;
    `dispatchers` is the number of device-feeding threads (one per device
    stream; the default 1 matches a single accelerator's serial launch
    queue).

    Measured, not priced: `done(ok, value, err, io_s, run_s)` receives the
    staging seconds observed for that task and its share of the bundle's
    measured execution time (`bundle_s / K`).  `device_s` accumulates the
    total seconds the dispatcher spent inside device execution — the
    numerator of the benchmark's "device-bound, not dispatcher-bound"
    fraction (benchmarks/device_batching.py).
    """

    autoscale = False

    def __init__(self, clock: Clock, max_bundle: int = 256,
                 linger: float = 0.0, dispatchers: int = 1,
                 name: str = "device", tracer=None):
        if max_bundle < 1:
            raise ValueError("max_bundle must be >= 1")
        _require_threadsafe_clock(clock, name)
        self.clock = clock
        self.name = name
        # observability (DESIGN.md §12): each fused bundle emits one
        # `bundle_fused` event (value = tasks fused); clock thread only
        self.tracer = tracer
        self.max_bundle = max_bundle
        self.linger = linger
        self._pending: dict[Any, list] = {}
        self._flush_scheduled = False
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._vmapped_cache: dict = {}
        self._shutdown = False
        self._threads = [
            threading.Thread(target=self._loop, daemon=True,
                             name=f"{name}-dispatch{i}")
            for i in range(max(1, dispatchers))
        ]
        for t in self._threads:
            t.start()
        # counters/summaries — mutated on the clock thread only
        self.tasks_run = 0
        self.bundles_run = 0
        self.fused_tasks = 0
        self.device_s = 0.0                  # measured execution seconds
        self.io_stat = StreamStat(cap=256)   # measured staging s per task
        self.run_stat = StreamStat(cap=256)  # attributed execution s per task
        self.bundle_stat = StreamStat(cap=256)  # tasks per bundle

    def size(self) -> int:
        return len(self._threads)

    def resize(self, n: int) -> None:
        """Fixed-size by design: DRP allocations size the *logical*
        executor set, not device streams (`autoscale` is False, so the
        service never calls this on the real path)."""

    # -- the seam (clock thread) ----------------------------------------
    def submit(self, task, done: Callable,
               stage: Optional[Callable[[], None]] = None) -> None:
        """Hand one task to the dispatcher.  Batchable tasks (callable +
        `vmap_key`) accumulate per `vmap_signature` until `max_bundle` or
        the `linger` flush; others ship immediately as singletons.
        `done(ok, value, err, io_s, run_s)` is called back on the clock
        thread, once per task."""
        if self._shutdown:
            raise RuntimeError(f"pool {self.name!r} is shut down")
        self.clock.hold()
        if task.vmap_key is None or task.fn is None:
            self._q.put([(task, done, stage)])
            return
        key = (task.vmap_key, vmap_signature(task.fn, resolve_args(task)))
        bucket = self._pending.get(key)
        if bucket is None:
            self._pending[key] = bucket = []
        bucket.append((task, done, stage))
        if len(bucket) >= self.max_bundle:
            del self._pending[key]
            self._q.put(bucket)
        elif not self._flush_scheduled:
            self._flush_scheduled = True
            self.clock.schedule(self.linger, self.flush)

    def flush(self) -> None:
        """Ship every pending bucket to the dispatcher (clock thread)."""
        self._flush_scheduled = False
        if not self._pending:
            return
        pending, self._pending = self._pending, {}
        for bundle in pending.values():
            self._q.put(bundle)

    def shutdown(self, wait: bool = True) -> None:
        """Stop the dispatcher threads (after their queued bundles) and
        join them.  Call after `run()` returns; queued work has completed."""
        if self._shutdown:
            return
        self._shutdown = True
        for _ in self._threads:
            self._q.put(_STOP)
        if wait:
            for t in self._threads:
                t.join()
        self._threads.clear()

    # -- dispatcher side -------------------------------------------------
    def _loop(self) -> None:
        while True:
            bundle = self._q.get()
            if bundle is _STOP:
                return
            self._run_bundle(bundle)

    def _run_bundle(self, bundle: list) -> None:
        # real staging copies first, per task, inside the measured io time
        # (exactly where the simulated path adds priced staging I/O);
        # a staging failure fails that task and drops it from the batch
        io_ss = [0.0] * len(bundle)
        staged_err: dict[int, BaseException] = {}
        for i, (_task, _done, stage) in enumerate(bundle):
            if stage is None:
                continue
            t0 = perf_counter()
            try:
                stage()
            except BaseException as err:  # noqa: BLE001 — staging failure
                staged_err[i] = err
            io_ss[i] = perf_counter() - t0
        live = [i for i in range(len(bundle)) if i not in staged_err]
        tasks = [bundle[i][0] for i in live]
        if tasks:
            results, exec_s, n_fused = execute_bundle(
                tasks[0].fn, tasks, self._vmapped_cache)
        else:
            results, exec_s, n_fused = [], 0.0, 0
        # measured bundle time attributed evenly across the tasks that
        # actually executed; staged-failure tasks carry only their io time
        out: list = [None] * len(bundle)
        run_ss = [0.0] * len(bundle)
        per_task = exec_s / max(1, len(live))
        for i, err in staged_err.items():
            out[i] = (False, None, err)
        for i, res in zip(live, results):
            out[i] = res
            run_ss[i] = per_task
        # one posted completion per bundle; the post lands before any
        # hold token is returned, so the loop can never observe
        # "no holds, no events" mid-handoff
        self.clock.post(partial(self._complete_bundle, bundle, out,
                                io_ss, run_ss, exec_s, n_fused))
        for _ in bundle:
            self.clock.release()

    # -- back on the clock thread ----------------------------------------
    def _complete_bundle(self, bundle, out, io_ss, run_ss, exec_s,
                         n_fused) -> None:
        now = self.clock.now()
        self.bundles_run += 1
        self.device_s += exec_s
        self.bundle_stat.observe(now, len(bundle))
        self.fused_tasks += n_fused
        if self.tracer is not None and n_fused:
            self.tracer.event("bundle_fused", now, n_fused)
        tr = self.tracer
        for (task, done, _stage), (ok, v, err), io_s, run_s in zip(
                bundle, out, io_ss, run_ss):
            self.tasks_run += 1
            self.io_stat.observe(now, io_s)
            self.run_stat.observe(now, run_s)
            if tr is not None and not ok:
                # worker-level failure signal (DESIGN.md §13), same kind
                # the thread/process pools emit
                tr.event("worker_error", now)
            done(ok, v, err, io_s, run_s)

    def metrics(self) -> dict:
        """Bounded snapshot — safe at any task count."""
        return {
            "dispatchers": self.size(),
            "tasks_run": self.tasks_run,
            "bundles_run": self.bundles_run,
            "fused_tasks": self.fused_tasks,
            "device_s": self.device_s,
            "bundle_size": self.bundle_stat.summary(),
            "io_s": self.io_stat.summary(),
            "run_s": self.run_stat.summary(),
        }
