"""Multi-engine federation: shard one workflow across N engines
(DESIGN.md §8).

The paper scales one Swift/Karajan engine feeding one Falkon service; its
own application campaigns (§5 — MolDyn, fMRI) want *many* cooperating
engines.  The binding constraint is the dispatcher: Falkon's measured 487
tasks/s (§4) is a per-service ceiling, so past ~500 short tasks/s one
engine cannot keep any pool busy no matter how large.  Federation shards
the dataflow graph across N `Engine` shards — each a full engine with its
own `LoadBalancer`, sites, and (typically) one Falkon service per pod —
giving N dispatchers, with three cross-shard mechanisms:

  * `Mailbox`          — cross-shard future delivery: a consumer shard
                         blocks on a local proxy that resolves in one
                         coalesced clock event when the producing shard
                         completes (optionally after a delivery latency),
                         never on the producer's internal state.
  * `WorkStealer`      — migrates *pending-ready* tasks (the engine's held
                         ready queue) from overloaded shards to idle ones:
                         steal-half of the victim's deque in one bounded
                         batch, amortized O(1) per task, O(shards) per
                         steal event, never a per-task scan.
  * `ShardedDataLayer` — the data-diffusion holder index (§7) shards with
                         the engines: per-shard holder maps plus a small
                         cross-shard `ShardDirectory`, so locality-driven
                         dispatch keeps working after a steal — a migrated
                         task re-routes to holders in its *new* shard or
                         pays the staging cost `StagingCostModel` prices
                         (the stealer reports those restage bytes through
                         bounded `StreamStat` metrics).

Scale contracts: per-task federation overhead is O(1) (one partitioner
hash, one ownership-dict update, O(args) proxy checks); steal passes cost
O(shards + batch); mailbox flushes are one event per delivery window; all
federation metrics are bounded counters / `StreamStat` reservoirs.
Everything is deterministic under `SimClock` — the default partitioner
uses crc32, not Python's seeded `hash`.

`FederatedEngine` duck-types `Engine` (`submit`, `run`, `clock`,
`tasks_completed`, `stats`), so `Workflow` — including `foreach`
expansion at runtime — runs over a federation transparently.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Optional
from zlib import crc32

from repro.core.datastore import (DataLayer, ShardDirectory, SharedStore,
                                  StagingCostModel, inputs_of)
from repro.core.engine import Engine
from repro.core.futures import DataFuture
from repro.core.metrics import StreamStat
from repro.core.simclock import Clock, SimClock

__all__ = [
    "FederatedEngine", "Mailbox", "MailboxTransport", "QueueTransport",
    "WorkStealer", "ShardedDataLayer",
    "hash_partitioner", "skewed_partitioner", "inputs_partitioner",
]


def hash_partitioner(key: str, n_shards: int) -> int:
    """Default partitioner: stable hash of the task key.  crc32, not
    `hash()` — Python string hashing is per-process randomized and would
    break SimClock replay determinism."""
    return crc32(key.encode()) % n_shards


def skewed_partitioner(heavy_frac: float, heavy_shard: int = 0) -> Callable:
    """A deliberately imbalanced partitioner: `heavy_frac` of all keys land
    on `heavy_shard`, the rest spread over the other shards.  Used by the
    federation benchmark/tests to exercise work stealing."""
    cut = int(heavy_frac * 1000)

    def part(key: str, n_shards: int) -> int:
        h = crc32(key.encode())
        if h % 1000 < cut or n_shards <= 1:
            return heavy_shard % n_shards
        other = (h // 1000) % (n_shards - 1)
        return other if other < heavy_shard else other + 1

    return part


def inputs_partitioner(key: str, n_shards: int, inputs: tuple = ()) -> int:
    """Affinity-aware partitioner (ROADMAP: affinity partitioning, first
    half): tasks are keyed on their declared `DataObject` inputs, so tasks
    sharing an input land on the same shard — that shard's data layer
    caches the file once instead of every shard staging its own replica,
    and cross-shard restaging after steals drops with it.

    The anchor is the *largest* declared input (the one worth co-locating
    for), ties broken by name; tasks with no declared inputs fall back to
    the crc32 key hash, identical to `hash_partitioner`.  O(inputs) per
    task, deterministic (crc32, not `hash()`).
    """
    if inputs:
        anchor = max(inputs, key=lambda o: (o.size, o.name))
        return crc32(anchor.name.encode()) % n_shards
    return crc32(key.encode()) % n_shards


# `FederatedEngine.submit` passes the task's normalized input tuple only to
# partitioners that declare they want it, so plain `(key, n)` partitioners
# keep working unchanged.
inputs_partitioner.wants_inputs = True


class MailboxTransport:
    """Delivery mechanism behind a `Mailbox` (DESIGN.md §10).

    The default (no transport) models delivery: a coalesced clock flush
    after a simulated latency.  A transport replaces the model with a real
    hand-off — messages cross its medium and are *delivered* on the
    consumer's clock thread via the `deliver` callback the mailbox binds.
    """

    def bind(self, clock: Clock, deliver: Callable) -> None:
        raise NotImplementedError

    def send(self, msg) -> None:
        raise NotImplementedError


class QueueTransport(MailboxTransport):
    """Queue-backed in-process transport (DESIGN.md §10): messages cross a
    thread-safe `queue.SimpleQueue` and are drained on the consumer's clock
    thread through `Clock.post`, one coalesced drain per burst.

    Under `RealClock` this is true cross-thread-capable delivery (the post
    wakes the event loop even mid-wait); under `SimClock` the same code
    path runs deterministically (`post` degrades to `schedule(0, ...)`),
    which is how the delivery/failure tests pin its semantics.  Example::

        fed = FederatedEngine(4, clock=RealClock(), transport="queue")
    """

    def __init__(self):
        import queue as _queue
        import threading as _threading
        self._q = _queue.SimpleQueue()
        self._empty = _queue.Empty
        self._lock = _threading.Lock()
        self._wake_pending = False
        self._clock: Clock | None = None
        self._deliver: Callable | None = None
        self.sends = 0
        self.drains = 0

    def bind(self, clock: Clock, deliver: Callable) -> None:
        self._clock = clock
        self._deliver = deliver

    def send(self, msg) -> None:
        self._q.put(msg)
        # coalesce wakeups: one drain event per burst.  The drain clears
        # the flag *before* reading the queue, so a sender that observes
        # the flag still set is guaranteed its message is picked up by the
        # drain that clears it.  `sends` is bumped under the same lock:
        # send() runs on producer threads under RealClock, and an unlocked
        # += loses increments under contention.
        with self._lock:
            self.sends += 1
            if self._wake_pending:
                return
            self._wake_pending = True
        self._clock.post(self._drain)

    def _drain(self) -> None:
        with self._lock:
            self._wake_pending = False
        self.drains += 1          # clock thread only — no lock needed
        batch = []
        while True:
            try:
                batch.append(self._q.get_nowait())
            except self._empty:
                break
        if batch:
            self._deliver(batch)


class Mailbox:
    """Cross-shard completion delivery for one consumer shard.

    Producers post (proxy, source-future) pairs at resolution time; each
    message is delivered no earlier than `latency` simulated seconds after
    its post (the modeled inter-pod transport time) and messages that come
    due at the same flush share one clock event — a same-instant burst of
    cross-shard completions costs one event, not one per edge, while a
    message posted late in an open window still waits its *own* full
    latency (the flush re-schedules for the not-yet-due tail).  Failures
    propagate: a failed source fails its proxies, and the consumer
    engine's upstream-failure path handles the rest.

    With a `MailboxTransport` attached (e.g. `QueueTransport`,
    DESIGN.md §10) delivery is *real* instead of modeled: `post` hands the
    message to the transport and the transport's drain delivers it on the
    consumer's clock thread; `latency` is then whatever the transport
    actually takes and the parameter is ignored.
    """

    def __init__(self, clock: Clock, shard_id: int, latency: float = 0.0,
                 transport: MailboxTransport | None = None, tracer=None):
        self.clock = clock
        self.shard_id = shard_id
        self.latency = latency
        self.transport = transport
        self.tracer = tracer
        if transport is not None:
            transport.bind(clock, self._deliver)
        self._queue: deque = deque()    # (ready_at, proxy, src), time-sorted
        self._flush_at = None
        self.messages = 0
        self.flushes = 0
        self.batch_stat = StreamStat(cap=256)   # messages per flush
        # fid -> local future, for *envelope* delivery: a transport that
        # crosses a process boundary cannot carry future objects, so the
        # producer side sends (fid, ok, payload) and the consumer registers
        # the future awaiting each fid here (DESIGN.md §14).  Entries are
        # popped on delivery, so the map is bounded by in-flight envelopes.
        self._awaiting: dict[int, DataFuture] = {}

    def post(self, proxy: DataFuture, src: DataFuture) -> None:
        self.messages += 1
        if self.transport is not None:
            self.transport.send((proxy, src))
            return
        now = self.clock.now()
        # posts arrive in clock order, so the deque stays sorted by ready_at
        self._queue.append((now + self.latency, proxy, src))
        if self._flush_at is None:
            self._flush_at = now + self.latency
            self.clock.schedule(self.latency, self._flush)

    def register_proxy(self, fid: int, fut: DataFuture) -> None:
        """Bind a local future to a remote fid: the next `(fid, ok,
        payload)` envelope delivered through this mailbox resolves it."""
        self._awaiting[fid] = fut

    def _deliver(self, batch: list) -> None:
        """Transport drain target: resolve a batch of delivered messages on
        the consumer's clock thread (same failure propagation as `_flush`).

        Two message shapes: in-process transports carry `(proxy, src)`
        future pairs; process-boundary transports carry pickle-safe
        `(fid, ok, payload)` envelopes resolved against `register_proxy`
        registrations (unknown fids are ignored — the registration may
        have been dropped by a shard death)."""
        for msg in batch:
            if len(msg) == 2:
                proxy, src = msg
                if src.failed:
                    proxy.set_error(src._error)
                else:
                    proxy.set(src.get())
            else:
                # envelopes never pass through post(), so count them here
                self.messages += 1
                fid, ok, payload = msg
                fut = self._awaiting.pop(fid, None)
                if fut is None or fut.done:
                    continue
                if ok:
                    fut.set(payload)
                else:
                    fut.set_error(payload)
        self.flushes += 1
        self.batch_stat.observe(self.clock.now(), len(batch))
        if self.tracer is not None:
            self.tracer.event("mailbox_flush", self.clock.now(), len(batch))

    def _flush(self) -> None:
        self._flush_at = None
        queue = self._queue
        now = self.clock.now()
        # deliver everything already due; resolving proxies can trigger
        # submissions that post new messages — those land behind the due
        # prefix with a strictly later ready_at, so the loop terminates
        batch = 0
        while queue and queue[0][0] <= now + 1e-12:
            _, proxy, src = queue.popleft()
            batch += 1
            if src.failed:
                proxy.set_error(src._error)
            else:
                proxy.set(src.get())
        self.flushes += 1
        self.batch_stat.observe(now, batch)
        if self.tracer is not None:
            self.tracer.event("mailbox_flush", now, batch)
        if queue and (self._flush_at is None or queue[0][0] < self._flush_at):
            # undelivered tail (posted mid-window): wake when its own
            # latency elapses.  A mid-flush post may already have scheduled
            # a wake, but possibly later than this head needs — an extra
            # earlier event is harmless (a flush delivers only what is due)
            self._flush_at = queue[0][0]
            self.clock.schedule(max(0.0, queue[0][0] - now), self._flush)

    def metrics(self) -> dict:
        return {
            "messages": self.messages,
            "flushes": self.flushes,
            "batch": self.batch_stat.summary(),
        }


class WorkStealer:
    """Steal-half work migration between federation shards.

    A steal pass runs as one coalesced clock event (flag-guarded `poke`),
    scans the O(shards) load vector, and for each idle thief (no held
    backlog, free balancer capacity — the `LoadBalancer.idle_slots` steal
    interface) migrates half of the most-loaded shard's pending-ready
    deque, bounded by `max_batch`, in one batch.  Tasks are popped from
    the *back* of the victim's deque (newest-ready first), so the victim
    keeps draining its oldest work in order; migration itself is
    `thief._dispatch(task)` — the thief's balancer, throttle, and data
    layer take over from there.

    Steal-induced restage cost: with a `ShardedDataLayer` attached, each
    migrated task's inputs are priced against the cross-shard directory
    (held in the victim shard but not the thief's -> restage bytes) and
    reported through a bounded `StreamStat` — an O(inputs) lookup per
    migrated task, no executor or task scans.

    Health interplay (DESIGN.md §13): a drained/blacklisted shard is never
    a thief — thief eligibility requires `LoadBalancer.idle_slots` > 0 and
    that already skips suspended sites.  It *is* the natural victim: its
    unplaceable ready work accumulates in `_pending` (via `notify_backlog`)
    and migrates to healthy shards, which is how the federation routes
    around a bad shard with no health-specific code here.
    """

    def __init__(self, clock: Clock, min_batch: int = 2,
                 max_batch: int = 4096, interval: float = 0.0,
                 victim_policy: str = "load"):
        if victim_policy not in ("load", "directory"):
            raise ValueError(f"unknown victim_policy {victim_policy!r}; "
                             f"expected 'load' or 'directory'")
        self.clock = clock
        self.min_batch = max(1, min_batch)
        self.max_batch = max_batch
        self.interval = interval
        self.victim_policy = victim_policy
        self.fed: Optional["FederatedEngine"] = None
        self._scheduled = False
        self.steals = 0              # batches migrated
        self.tasks_stolen = 0
        self.passes = 0              # rebalance events (incl. no-ops)
        self.restage_bytes_est = 0.0
        self.batch_stat = StreamStat(cap=256)     # tasks per steal batch
        self.restage_stat = StreamStat(cap=256)   # restage bytes per batch

    def attach(self, fed: "FederatedEngine") -> None:
        self.fed = fed

    def poke(self) -> None:
        """Request a steal pass; coalesced — at most one scheduled at a
        time, so pokes are O(1) however often load changes."""
        if not self._scheduled:
            self._scheduled = True
            self.clock.schedule(self.interval, self._rebalance)

    def _rebalance(self) -> None:
        fed = self.fed
        if fed is None:
            self._scheduled = False
            return
        self.passes += 1
        now = self.clock.now()
        shards = fed.shards
        sdl = fed.data_layer
        for thief in shards:
            if thief._pending or thief.balancer.idle_slots(now) <= 0:
                continue
            victim = self._pick_victim(shards, thief, sdl)
            if victim is None or victim is thief \
                    or len(victim._pending) < self.min_batch:
                continue
            n = min(len(victim._pending) // 2, self.max_batch)
            if n <= 0:
                continue
            batch = victim._pending.steal(n)
            moved = []
            restage = 0.0
            for task, excl in batch:
                # heterogeneous shards: only migrate what the thief can run
                if not thief.balancer.any_valid(task.app):
                    victim._pending.append((task, excl))
                    continue
                moved.append(task)
                if sdl is not None and task.inputs:
                    restage += sdl.restage_estimate(
                        task.inputs, victim.shard_id, thief.shard_id)
            if not moved:
                continue
            self.steals += 1
            self.tasks_stolen += len(moved)
            self.batch_stat.observe(now, len(moved))
            tr = getattr(fed, "tracer", None)
            if tr is not None:
                tr.event("steal", now, len(moved))
            if sdl is not None:
                self.restage_bytes_est += restage
                self.restage_stat.observe(now, restage)
            for task in moved:
                # exclude_site names are victim-local; the thief's balancer
                # places (or holds) the task fresh
                thief._dispatch(task)
        self._scheduled = False

    # -- victim selection ----------------------------------------------
    def _pick_victim(self, shards, thief, sdl):
        """Choose which shard the thief steals from.

        ``"load"`` (default) is the original policy, byte-identical under
        SimClock: the single most-loaded shard.  ``"directory"`` is
        locality-aware (needs a `ShardedDataLayer`): among shards whose
        backlog is within 2x of the maximum (so stealing still fixes the
        imbalance), prefer the one whose sampled pending inputs the thief
        would re-stage *least*, priced through the cross-shard directory.
        Cost: O(shards) + O(candidates x sample x inputs) directory
        probes per steal pass — bounded by the sample cap, never a full
        queue scan."""
        if self.victim_policy == "load" or sdl is None:
            return max(shards, key=lambda s: len(s._pending))
        maxload = max(len(s._pending) for s in shards)
        floor = max(self.min_batch, maxload // 2)
        best, best_cost = None, None
        for s in shards:
            if s is thief or len(s._pending) < floor:
                continue
            cost = self._restage_sample(s, thief, sdl)
            # ties (incl. the all-zero case) break toward higher load,
            # which is what makes the policy degrade to "load" gracefully
            rank = (cost, -len(s._pending))
            if best is None or rank < best_cost:
                best, best_cost = s, rank
        return best

    def _restage_sample(self, victim, thief, sdl) -> float:
        """Average restage bytes over a bounded sample of the victim's
        newest pending-ready tasks (the ones a steal would take)."""
        sample = victim._pending.peek(8)
        if not sample:
            return 0.0
        total = 0.0
        for task in sample:
            if task.inputs:
                total += sdl.restage_estimate(
                    task.inputs, victim.shard_id, thief.shard_id)
        return total / len(sample)

    def metrics(self) -> dict:
        return {
            "victim_policy": self.victim_policy,
            "steals": self.steals,
            "tasks_stolen": self.tasks_stolen,
            "passes": self.passes,
            "restage_bytes_est": self.restage_bytes_est,
            "batch": self.batch_stat.summary(),
            "restage_per_batch": self.restage_stat.summary(),
        }


class ShardedDataLayer:
    """Data-diffusion layer sharded alongside the engines (DESIGN.md §8).

    One `DataLayer` per shard — each bound to that shard's Falkon service
    via ``FalkonService(data_layer=sdl.layer(i))`` — all sharing one
    `SharedStore` and `StagingCostModel`, plus one cross-shard
    `ShardDirectory`.  Per-dispatch holder lookups stay entirely
    shard-local (same O(inputs x probe_limit) contract as §7); the
    directory only answers the federation-level question "which shards
    hold X", used to price steal-induced restaging.
    """

    def __init__(self, n_shards: int, shared: SharedStore | None = None,
                 cost: StagingCostModel | None = None,
                 cache_capacity: float = 1e9, policy="lru", **layer_kw):
        self.shared = shared or SharedStore()
        self.cost = cost or StagingCostModel()
        self.directory = ShardDirectory()
        self.shards: list[DataLayer] = []
        for i in range(n_shards):
            dl = DataLayer(self.shared, self.cost,
                           cache_capacity=cache_capacity, policy=policy,
                           **layer_kw)
            dl.shard_id = i
            dl.directory = self.directory
            self.shards.append(dl)

    def layer(self, shard_id: int) -> DataLayer:
        return self.shards[shard_id]

    def restage_estimate(self, inputs, src: int, dst: int) -> float:
        """Bytes a task migrated src -> dst must re-stage: inputs held
        somewhere in the source shard but nowhere in the destination shard
        (O(inputs) cross-shard directory probes — this is the query the
        directory exists for; per-executor holder maps stay shard-local)."""
        if src == dst:
            return 0.0
        directory = self.directory
        bytes_ = 0.0
        for obj in inputs:
            if directory.holds(obj.name, src) and \
                    not directory.holds(obj.name, dst):
                bytes_ += obj.size
        return bytes_

    def metrics(self) -> dict:
        per_shard = [dl.metrics() for dl in self.shards]
        return {
            "directory_objects": len(self.directory),
            "hits": sum(m["hits"] for m in per_shard),
            "misses": sum(m["misses"] for m in per_shard),
            "bytes_staged": sum(m["bytes_staged"] for m in per_shard),
            "bytes_local": sum(m["bytes_local"] for m in per_shard),
            "shards": per_shard,
        }


class FederatedEngine:
    """Shard one dataflow graph across N `Engine`s sharing a clock.

    Duck-types the `Engine` surface the DSL uses (`submit`, `run`,
    `clock`, aggregate counters), so ``Workflow("w", FederatedEngine(4))``
    — `foreach`, `gather`, `when`, atomic procedures — works unchanged.

    * **Partitioning** — each submission is routed by
      ``partitioner(task_key, n_shards)`` (default: crc32 hash of the
      key).  Keys are federation-assigned (`name#counter`) unless the
      caller passes one, so partitioning is deterministic and pluggable
      (e.g. `skewed_partitioner` for imbalance experiments, or a
      domain partitioner that keeps a molecule's pipeline on one shard).
    * **Cross-shard futures** — an argument future produced by another
      shard is replaced by a shard-local proxy delivered through the
      consumer shard's `Mailbox`: the consumer blocks only on the
      producing shard's completion event (plus `delivery_latency`), and
      one proxy is shared by all consumers on the same shard.  Futures
      with no owning shard — workflow combinators (`gather` / `foreach` /
      `when`) resolve driver-side — cross the driver->shard transport
      the same way, so high-fan-in joins also pay delivery latency and
      count in `cross_shard_edges`.  Ownership bookkeeping is dropped as
      futures resolve, so the map is bounded by *in-flight* futures, not
      by workflow size.
    * **Work stealing** — shards hold excess ready work in their pending
      queue (`_hold_excess`); `notify_idle`/`notify_backlog` hooks poke
      the `WorkStealer`, which migrates steal-half batches to idle
      shards.  Pass ``steal=False`` (or ``stealer=None`` explicitly) for
      a partition-only federation.
    """

    def __init__(self, shards: int | list[Engine],
                 clock: Clock | None = None,
                 partitioner: Callable[[str, int], int] | None = None,
                 data_layer: ShardedDataLayer | None = None,
                 stealer: WorkStealer | None = None, steal: bool = True,
                 victim_policy: str = "load",
                 delivery_latency: float = 0.0,
                 transport: str | Callable[[], MailboxTransport]
                 | None = None,
                 engine_kwargs: dict | None = None,
                 tracer=None):
        # observability (DESIGN.md §12): one shared tracer across every
        # shard — spans carry their shard id, mailbox flushes and steals
        # land as component events, and the clock's deterministic event
        # order keeps the merged stream reproducible under SimClock
        self.tracer = tracer
        # online health (DESIGN.md §13): set by `HealthMonitor.watch(fed)`,
        # which also watches every shard engine; drained shards then stop
        # being steal thieves via the suspended-site seam in `idle_slots`
        self.health = None
        if isinstance(shards, int):
            if shards < 1:
                raise ValueError("need at least one shard")
            self.clock = clock or SimClock()
            kw = dict(engine_kwargs or {})
            if tracer is not None:
                kw.setdefault("tracer", tracer)
            shards = [Engine(self.clock, **kw) for _ in range(shards)]
        else:
            shards = list(shards)
            if not shards:
                raise ValueError("need at least one shard")
            self.clock = clock or shards[0].clock
            for eng in shards:
                if eng.clock is not self.clock:
                    raise ValueError("all shards must share one clock")
                if tracer is not None and eng.tracer is None:
                    eng.tracer = tracer
        self.shards = shards
        self.partitioner = partitioner or hash_partitioner
        self._partition_on_inputs = getattr(self.partitioner,
                                            "wants_inputs", False)
        self.data_layer = data_layer
        # transport=None: latency-simulated delivery (one coalesced flush
        # per window).  "queue" (or a factory returning MailboxTransport
        # instances): real queue-backed delivery per consumer shard —
        # delivery_latency is then ignored (DESIGN.md §10).
        if transport == "queue":
            transport = QueueTransport
        elif isinstance(transport, str):
            raise ValueError(f"unknown mailbox transport {transport!r}; "
                             f"expected 'queue', a factory, or None")
        self.mailboxes = [
            Mailbox(self.clock, i, delivery_latency,
                    transport=transport() if transport is not None else None,
                    tracer=tracer)
            for i in range(len(shards))]
        self.stealer = stealer if stealer is not None else (
            WorkStealer(self.clock, victim_policy=victim_policy)
            if steal else None)
        if self.stealer is not None:
            self.stealer.attach(self)
        for i, eng in enumerate(shards):
            eng.shard_id = i
            eng._federation = self
            eng._hold_excess = True
        self.tasks_submitted = 0
        self.cross_shard_edges = 0
        self._owner: dict[int, int] = {}          # future id -> shard
        self._proxies: dict[tuple, DataFuture] = {}
        # aggregate backpressure waiters (DESIGN.md §9): shard completions
        # delegate the wake check here so the streaming frontier keys on
        # federation-wide saturation, not one shard's
        self._bp_waiters: list = []

    # ------------------------------------------------------------------
    def submit(self, name: str, fn=None, args: list | None = None,
               duration: float | None = None, app: str | None = None,
               durable: bool = False, key: str | None = None,
               vmap_key=None, inputs=None) -> DataFuture:
        args = args or []
        if key is None:
            key = f"{name}#{self.tasks_submitted}"
        self.tasks_submitted += 1
        if self._partition_on_inputs:
            # normalize once here (the shard engine skips re-normalizing
            # tuples), so the affinity partitioner sees the DataObjects
            if type(inputs) is not tuple:
                inputs = inputs_of(inputs, *args) if inputs is not None \
                    else ()
            shard = self.partitioner(key, len(self.shards), inputs)
        else:
            shard = self.partitioner(key, len(self.shards))
        routed = args
        for idx, a in enumerate(args):
            if isinstance(a, DataFuture) and not a.done:
                # owner None = a workflow-combinator future (gather /
                # foreach / when run driver-side, not on a shard): those
                # joins cross the driver->shard transport too, so they
                # proxy through the consumer's mailbox exactly like a
                # future produced by another shard
                if self._owner.get(a.id) != shard:
                    if routed is args:
                        routed = list(args)
                    routed[idx] = self._proxy(a, shard)
        out = self.shards[shard].submit(
            name, fn, routed, duration=duration, app=app, durable=durable,
            key=key, vmap_key=vmap_key, inputs=inputs)
        if not out.done:                 # restart-log hits resolve eagerly
            self._owner[out.id] = shard
            out.on_done(self._forget)
        return out

    def _forget(self, f: DataFuture) -> None:
        self._owner.pop(f.id, None)

    def _proxy(self, fut: DataFuture, consumer: int) -> DataFuture:
        """Shard-local stand-in for a future owned by another shard; one
        proxy per (future, consumer shard), delivered via the mailbox."""
        pkey = (fut.id, consumer)
        p = self._proxies.get(pkey)
        if p is None:
            p = DataFuture(name=f"{fut.name}@shard{consumer}")
            self._proxies[pkey] = p
            self.cross_shard_edges += 1
            mbox = self.mailboxes[consumer]
            fut.on_done(lambda f, p=p, m=mbox: m.post(p, f))
            p.on_done(lambda _p, k=pkey: self._proxies.pop(k, None))
        return p

    # -- stealer hooks (called from Engine._dispatch/_done) -------------
    def notify_backlog(self, eng: Engine) -> None:
        """A shard just held another ready task.  Cheap-gated: only looks
        for an idle thief when the backlog first becomes stealable and
        every 256 tasks after, so per-task cost stays O(1)."""
        st = self.stealer
        if st is None or st._scheduled:
            return
        lp = len(eng._pending)
        if lp != st.min_batch and lp & 0xFF:
            return
        now = self.clock.now()
        for s in self.shards:
            if (s is not eng and not s._pending
                    and s.balancer.idle_slots(now) > 0):
                st.poke()
                return

    def notify_idle(self, eng: Engine) -> None:
        """A shard finished a task with no held backlog left — steal if any
        other shard has a stealable queue (O(shards) length checks)."""
        st = self.stealer
        if st is None or st._scheduled:
            return
        mb = st.min_batch
        for s in self.shards:
            if s is not eng and len(s._pending) >= mb:
                st.poke()
                return

    # -- submit-side backpressure (DESIGN.md §9) -----------------------
    def inflight(self) -> int:
        """Tasks submitted but not yet finished, aggregated over shards."""
        return sum(e.inflight() for e in self.shards)

    def ready_backlog(self) -> int:
        """Held ready tasks across all shards (the stealable backlog)."""
        return sum(len(e._pending) for e in self.shards)

    def pool_capacity(self) -> int:
        return sum(e.pool_capacity() for e in self.shards)

    def dispatchable(self) -> int:
        return sum(e.dispatchable() for e in self.shards)

    def saturated(self, slack: float | None = None) -> bool:
        """Aggregate submit-side backpressure: the federation as a whole
        already holds ≥ slack x aggregate pool capacity of dispatchable
        work.  Aggregate, not per-shard: a skewed partition leaves some
        shards starved while others hold backlog, and it is the stealer's
        job to rebalance that — the streaming frontier should keep feeding
        until the *federation* is full, or steals would have nothing to
        migrate."""
        cap = self.pool_capacity()
        if cap <= 0:
            return False
        if slack is None:
            slack = self.shards[0].site_slack
        return self.dispatchable() >= slack * cap

    def add_backpressure_waiter(self, cb) -> None:
        """Single-shot callback fired when a shard completion leaves the
        federation (in aggregate) unsaturated."""
        self._bp_waiters.append(cb)

    def _wake_backpressure(self) -> None:
        if self._bp_waiters and not self.saturated():
            waiters, self._bp_waiters = self._bp_waiters, []
            for cb in waiters:
                cb()

    # ------------------------------------------------------------------
    def run(self):
        if self.stealer is not None:
            self.stealer.poke()          # initial probe (skewed bootstraps)
        self.clock.run()

    @property
    def tasks_completed(self) -> int:
        return sum(e.tasks_completed for e in self.shards)

    @property
    def tasks_failed(self) -> int:
        return sum(e.tasks_failed for e in self.shards)

    def stats(self) -> dict:
        return {
            "submitted": self.tasks_submitted,
            "completed": self.tasks_completed,
            "failed": self.tasks_failed,
            "shards": len(self.shards),
            "per_shard_completed": [e.tasks_completed for e in self.shards],
            "cross_shard_edges": self.cross_shard_edges,
            "makespan": self.clock.now(),
        }

    def metrics(self) -> dict:
        """Bounded federation snapshot — safe at any task count."""
        m = {
            "shards": len(self.shards),
            "submitted": self.tasks_submitted,
            "completed": self.tasks_completed,
            "cross_shard_edges": self.cross_shard_edges,
            "mailboxes": [mb.metrics() for mb in self.mailboxes],
            "in_flight_owned": len(self._owner),
        }
        if self.stealer is not None:
            m["stealer"] = self.stealer.metrics()
        if self.data_layer is not None:
            m["data"] = self.data_layer.metrics()
        if self.health is not None:
            m["health"] = self.health.states()
        return m
