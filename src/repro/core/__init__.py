"""Core paper technique: Swift (workflow DSL + XDTM) / Karajan (futures
engine) / Falkon (multi-level scheduling) adapted to JAX/TPU.

Layered scheduler subsystem (see DESIGN.md): task records
(`repro.core.task`) -> providers (`repro.core.providers`) -> Falkon service
(`repro.core.falkon`) -> sites/load balancing (`repro.core.sites`) ->
engine dataflow + dispatch policy (`repro.core.engine`).

Public API:
    Engine, Workflow, Dataset, mappers, FalkonService, providers,
    RestartLog, FaultInjector, SimClock/RealClock.
"""
from repro.core.clustering import VmapClusteringProvider
from repro.core.datastore import (DataLayer, DataObject, EvictionPolicy,
                                  ExecutorCache, LFUPolicy, LRUPolicy,
                                  ShardDirectory, SharedStore,
                                  SizeAwarePolicy, StagingCostModel)
from repro.core.devicepool import DeviceExecutorPool
from repro.core.engine import Engine
from repro.core.falkon import DRPConfig, FalkonConfig, FalkonService
from repro.core.federation import (FederatedEngine, Mailbox,
                                   MailboxTransport, QueueTransport,
                                   ShardedDataLayer, WorkStealer,
                                   hash_partitioner, inputs_partitioner,
                                   skewed_partitioner)
from repro.core.faults import FaultInjector, RetryPolicy, TaskFailure
from repro.core.futures import (CompletionCounter, DataFuture, resolved,
                                when_all)
from repro.core.health import (METRICS_STREAM_SCHEMA, HealthConfig,
                               HealthMonitor, RollingStat)
from repro.core.jobstore import (IllegalTransition, JobStore, Journal,
                                 TaskStateMachine, WorkflowState)
from repro.core.metrics import StreamStat
from repro.core.observability import (BoundedLog, MetricsRegistry, RunReport,
                                      Span, Tracer, build_report)
from repro.core.procfed import (ProcessFederation, ProcessTransport, Ref,
                                ShardHost, ShardSpec, SocketTransport)
from repro.core.provenance import VDC, InvocationRecord
from repro.core.providers import (BatchSchedulerProvider, ClusteringProvider,
                                  FalkonProvider, LocalProvider, Provider,
                                  WorkerPoolProvider)
from repro.core.realpool import ProcessExecutorPool, ThreadExecutorPool
from repro.core.restart_log import RestartLog
from repro.core.service import (ResumeView, WorkflowHandle,
                                WorkflowService)
from repro.core.simclock import RealClock, SimClock
from repro.core.sites import LoadBalancer, Site
from repro.core.task import Task, task_key
from repro.core.workflow import Procedure, Workflow
from repro.core.xdtm import (ArrayOf, CSVMapper, Dataset, FILE,
                             FileSystemMapper, FLOAT, INT, ListMapper,
                             Mapper, PhysicalRef, Primitive, ShardMapper,
                             STRING, Struct)

__all__ = [
    "Engine", "Workflow", "Procedure", "Task", "task_key",
    "Provider", "WorkerPoolProvider",
    "LocalProvider", "BatchSchedulerProvider", "FalkonProvider",
    "ClusteringProvider", "VmapClusteringProvider",
    "FalkonService", "FalkonConfig", "DRPConfig",
    "ThreadExecutorPool", "ProcessExecutorPool", "DeviceExecutorPool",
    "DataFuture", "CompletionCounter", "resolved", "when_all",
    "SimClock", "RealClock",
    "RestartLog", "FaultInjector", "RetryPolicy", "TaskFailure",
    "JobStore", "Journal", "TaskStateMachine", "IllegalTransition",
    "WorkflowState", "WorkflowService", "WorkflowHandle", "ResumeView",
    "VDC", "InvocationRecord", "LoadBalancer", "Site", "StreamStat",
    "Tracer", "Span", "BoundedLog", "MetricsRegistry", "RunReport",
    "build_report",
    "HealthMonitor", "HealthConfig", "RollingStat",
    "DataLayer", "DataObject", "SharedStore", "ExecutorCache",
    "StagingCostModel", "EvictionPolicy", "LRUPolicy", "LFUPolicy",
    "SizeAwarePolicy", "ShardDirectory",
    "FederatedEngine", "Mailbox", "MailboxTransport", "QueueTransport",
    "WorkStealer", "ShardedDataLayer",
    "ProcessFederation", "ShardSpec", "ShardHost", "ProcessTransport",
    "SocketTransport", "Ref",
    "hash_partitioner", "skewed_partitioner", "inputs_partitioner",
    "Dataset", "Mapper", "ListMapper", "FileSystemMapper", "CSVMapper",
    "ShardMapper", "PhysicalRef", "Struct", "ArrayOf", "Primitive",
    "INT", "FLOAT", "STRING", "FILE",
]
