"""The Karajan-style execution engine (paper §3.8-3.13) — dataflow +
dispatch policy only.

Event-driven, future-based: every task is a lightweight record (no OS
threads); data dependencies are futures; a task becomes *ready* when its
argument futures resolve and is dispatched through a provider picked by the
score-based load balancer.  Pipelining across stages is inherent (§3.13 —
"comes for free with the future mechanism").

The engine is the top of the layered scheduler subsystem (DESIGN.md §1):
task records live in `repro.core.task`, providers in
`repro.core.providers`, the Falkon service in `repro.core.falkon`, and
sites/load-balancing in `repro.core.sites`.  Per-task work here is O(1) in
both task count and site count: site candidates come from the balancer's
per-app index, and the ready queue (`_pending`) is drained in coalesced
batches rather than one scheduled event per completion.

The pre-refactor names (`Task`, `Provider`, `LocalProvider`,
`BatchSchedulerProvider`, `FalkonProvider`, `ClusteringProvider`) are
re-exported so existing imports of `repro.core.engine` keep resolving.
"""
from __future__ import annotations

from collections import deque
from functools import partial

from repro.core.datastore import inputs_of
from repro.core.faults import FaultInjector, RetryPolicy, TaskFailure
from repro.core.futures import DataFuture, when_all
from repro.core.provenance import VDC, InvocationRecord
from repro.core.providers import (BatchSchedulerProvider, ClusteringProvider,
                                  FalkonProvider, LocalProvider, Provider,
                                  WorkerPoolProvider)
from repro.core.restart_log import RestartLog
from repro.core.simclock import Clock, SimClock
from repro.core.sites import LoadBalancer, Site
from repro.core.task import Task, sim_duration, task_key

__all__ = [
    "Engine", "ReadyQueue", "Task", "Provider", "WorkerPoolProvider",
    "LocalProvider", "BatchSchedulerProvider", "FalkonProvider",
    "ClusteringProvider",
]


class ReadyQueue:
    """Held ready tasks, bucketed per app.

    The drain pass visits each app bucket head-first and stops at the
    first unplaceable task, so a blocked app costs O(1) instead of
    shuffling its whole backlog through the deque — with a standing
    backlog of K tasks (a federation shard holding excess work for the
    stealer) the seed's flat deque made every completion O(K).  Buckets
    preserve per-app FIFO; iteration order is app first-arrival order
    (dict insertion), deterministic under `SimClock`.

    `steal(n)` is the work-migration interface (DESIGN.md §8): pops up to
    n entries from the *newest* end, largest bucket first, so the victim
    keeps its oldest work in order and the thief gets work least likely
    to be locality-bound.  O(apps + n) per call.
    """

    __slots__ = ("_buckets", "_len")

    def __init__(self):
        self._buckets: dict = {}
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def __bool__(self) -> bool:
        return self._len > 0

    def append(self, item) -> None:
        app = item[0].app
        bucket = self._buckets.get(app)
        if bucket is None:
            self._buckets[app] = bucket = deque()
        bucket.append(item)
        self._len += 1

    def buckets(self):
        """(app, deque) pairs; snapshot so callers may drop empty buckets."""
        return list(self._buckets.items())

    def pop_head(self, app) -> None:
        """Drop the head of `app`'s bucket (after a successful placement)."""
        bucket = self._buckets[app]
        bucket.popleft()
        self._len -= 1
        if not bucket:
            del self._buckets[app]

    def peek(self, n: int) -> list:
        """Up to n *tasks* from the newest end — what `steal(n)` would
        migrate — largest bucket first, without removing anything.
        O(apps + n); the directory-guided `WorkStealer` samples these to
        price a candidate victim's restage cost before committing to a
        steal (DESIGN.md §14)."""
        out: list = []
        if not self._len:
            return out
        # stable sort: ties keep first-arrival order, matching the
        # max-by-length bucket choice steal() makes
        for app in sorted(self._buckets,
                          key=lambda a: -len(self._buckets[a])):
            bucket = self._buckets[app]
            take = min(len(bucket), n - len(out))
            for i in range(1, take + 1):
                out.append(bucket[-i][0])
            if len(out) >= n:
                break
        return out

    def steal(self, n: int) -> list:
        """Pop up to n entries from the newest end, largest bucket first."""
        out = []
        while len(out) < n and self._len:
            # apps are few (workflow-level); max over the bucket dict is
            # O(apps), ties broken by first-arrival order (deterministic)
            app = max(self._buckets, key=lambda a: len(self._buckets[a]))
            bucket = self._buckets[app]
            take = min(len(bucket), n - len(out))
            for _ in range(take):
                out.append(bucket.pop())
            self._len -= take
            if not bucket:
                del self._buckets[app]
        out.reverse()                  # restore ready order for the thief
        return out


class Engine:
    """The Karajan-style dataflow engine: submit tasks, get futures, run.

    Tasks become *ready* when their argument futures resolve and are placed
    on a site by the score-based `LoadBalancer`; `run()` drives the clock
    until the graph drains.  Most programs use the `Workflow` DSL on top,
    but `submit` is the primitive everything lowers to.

    Example::

        clock = SimClock()                 # or RealClock() for wall time
        eng = Engine(clock)
        eng.local_site(concurrency=4)
        a = eng.submit("double", lambda x: 2 * x, args=[21])
        b = eng.submit("inc", lambda x: x + 1, args=[a])   # depends on a
        eng.run()
        assert b.get() == 43

    Constructor knobs: ``provenance="summary"`` keeps only aggregate VDC
    counters (required at 10^6 tasks), `restart_log`/`fault_injector`
    enable §3.12 behaviors, `retry_policy` bounds retries.
    """

    def __init__(self, clock: Clock | None = None,
                 retry_policy: RetryPolicy | None = None,
                 vdc: VDC | None = None,
                 restart_log: RestartLog | None = None,
                 fault_injector: FaultInjector | None = None,
                 provenance: str = "records",
                 duration_predictor=None,
                 tracer=None):
        self.clock = clock or SimClock()
        # observability (DESIGN.md §12): when a `Tracer` is attached every
        # task gets lifecycle accounting (exact counters + critical path)
        # and every k-th task a full span; None keeps each hook to a
        # single attribute test.
        self.tracer = tracer
        # online health (DESIGN.md §13): set by `HealthMonitor.watch` —
        # dispatch/completion hooks feed its rolling windows and its state
        # machine drives `Site.suspended_until`/`Site.derate`.  None keeps
        # each hook to a single attribute test.
        self.health = None
        self.retry_policy = retry_policy or RetryPolicy()
        self.vdc = vdc or VDC()
        self.restart_log = restart_log
        self.fault_injector = fault_injector
        # durability (DESIGN.md §15): set to a `jobstore.Journal` (usually
        # by `WorkflowService`) to record every task's status transitions
        # through the explicit state machine into the sqlite store.  None
        # keeps each hook to a single attribute test, like tracer/health.
        self.journal = None
        # multi-tenant fair share (DESIGN.md §15): when True, the pending
        # drain interleaves app buckets by stride scheduling (weights from
        # `app_shares`, default 1) instead of first-arrival bucket order,
        # so one app's standing backlog cannot starve later arrivals.
        self.fair_share = False
        self.app_shares: dict = {}
        self._fair_pass: dict = {}
        self._fair_vt = 0.0
        # duration prediction (DESIGN.md §11): when a predictor (e.g.
        # `repro.launch.hlo_cost.DurationPredictor`) is attached, tasks
        # with a callable and no explicit `duration=` are priced from
        # their HLO cost *before* dispatch — the predicted seconds then
        # steer the duration-aware balancer, the data layer's
        # wait-vs-stage test, and anything else reading `sim_duration`.
        # None keeps the submit hot path byte-for-byte.
        self.duration_predictor = duration_predictor
        self.balancer = LoadBalancer([])
        self.tasks_submitted = 0
        self.tasks_completed = 0
        self.tasks_failed = 0
        self.tasks_restored = 0
        self.tasks_revoked = 0   # drain revocations re-placed (§13)
        # per-site submission throttle (Swift holds excess ready tasks and
        # feeds sites as they turn jobs around, letting responsiveness
        # scores steer the split — paper §3.13)
        self.site_slack = 2.0
        self._pending = ReadyQueue()
        self._drain_scheduled = False
        # federation hooks (DESIGN.md §8): set by FederatedEngine.attach.
        # `_hold_excess` keeps ready tasks beyond the site throttle in
        # `_pending` even with a single site, so a WorkStealer has a
        # migratable backlog; the notify hooks are O(1)-guarded calls into
        # the federation on backlog growth / local starvation.  All three
        # are inert (one attribute test per event) outside a federation.
        self.shard_id: int | None = None
        self._federation = None
        self._hold_excess = False
        # submit-side backpressure waiters (DESIGN.md §9): single-shot
        # callbacks fired by `_done` when a completion leaves the engine
        # unsaturated — the streaming-expansion refill loop parks here so
        # the frontier resumes the moment the pool has room, not only when
        # a whole body pipeline completes.  Empty-list check per completion
        # when unused.
        self._bp_waiters: list = []
        # provenance="summary" keeps the VDC aggregate counters but skips
        # per-invocation records — required for bounded-memory 10^6-task runs
        if provenance not in ("records", "summary"):
            raise ValueError(f"provenance must be records|summary, "
                             f"got {provenance!r}")
        self._prov_records = provenance == "records"

    # ------------------------------------------------------------------
    def add_site(self, name: str, provider: Provider, capacity: int = 1,
                 apps: set[str] | None = None, data_layer=None) -> Site:
        site = Site(name, provider, capacity, apps)
        self.balancer.add_site(site)
        if data_layer is not None:
            # cache-aware balancing: pick() will boost this site for tasks
            # whose declared inputs its executors already hold
            self.balancer.set_affinity(name, data_layer)
        return site

    def local_site(self, concurrency: int = 1) -> Site:
        return self.add_site("localhost",
                             LocalProvider(self.clock, concurrency),
                             capacity=concurrency)

    # ------------------------------------------------------------------
    def submit(self, name: str, fn=None, args: list | None = None,
               duration: float | None = None, app: str | None = None,
               durable: bool = False, key: str | None = None,
               vmap_key=None, inputs=None) -> DataFuture:
        """Submit one task; returns its output `DataFuture` immediately.

        `fn` is the task body (None for pure-simulation tasks); `args` may
        mix literals and futures — the task dispatches when every argument
        future resolves.  `duration` is the simulated service time (ignored
        on the real execution path, where runtime is measured).  `app`
        routes via site app-validity; `durable` + a `RestartLog` persists
        the result; `inputs` declares the task's file inputs for the data
        layer — a `DataObject`, an iterable of them, or a callable mapping
        the call args to either (see `DataLayer`, DESIGN.md §7).
        """
        args = args or []
        out = DataFuture(name=name)
        if key is None:
            # dataflow-stable keys are only needed for restart-log lookups
            # and journaling; skip the fingerprint hash on the hot path
            # otherwise, and in summary-provenance mode (no stored records
            # reference the key) skip even the counter suffix
            if self.journal is not None:
                # the store's primary key is (wf, key): duplicate content
                # keys get a deterministic occurrence suffix
                key = self.journal.unique_key(task_key(name, args))
            elif self.restart_log is not None:
                key = task_key(name, args)
            elif self._prov_records:
                key = f"{name}#{self.tasks_submitted}"
            else:
                key = name
        out.name = key

        # restart log: datasets already produced are marked available and
        # their producing tasks are NOT re-run (paper §3.12)
        if self.restart_log is not None:
            hit, value = self.restart_log.lookup(key)
            if hit:
                self.tasks_restored += 1
                out.set(value)
                return out

        # Procedure.__call__ already normalizes to a tuple — trust it and
        # skip re-normalizing on the per-task hot path; a callable spec
        # receives the call args, as on the Procedure path
        if type(inputs) is not tuple:
            inputs = inputs_of(inputs, *args) if inputs is not None else ()
        task = Task(name, fn, args, out, duration, app,
                    self.retry_policy.max_retries, durable, key,
                    inputs=inputs)
        task.created_time = self.clock.now()
        j = self.journal
        if j is not None and j.full:
            # terminal durability records completions only — the
            # non-terminal transitions never leave the clock thread
            j.task_submitted(key)
        task.vmap_key = vmap_key
        tr = self.tracer
        if tr is not None:
            # the sampling decision (Tracer.task_created) is inlined — a
            # counter bump and one modulus for the overwhelming non-sampled
            # majority; ready_t/path0 are stamped in _ready (dependent
            # tasks) or just below (dependency-free tasks), never in
            # Task.__init__, so the tracing-off hot path skips the slots
            tr.tasks_seen = seen = tr.tasks_seen + 1
            task.span = (tr._new_span(task, task.created_time,
                                      self.shard_id)
                         if (seen - 1) % tr._k == 0 else None)
        if self.fault_injector is not None:
            inj = self.fault_injector

            def chk(t):
                s = t.site
                inj.check(t.name, t.host, t.attempt,
                          s.name if s is not None else "")

            if getattr(inj, "timed", False):
                # fail-slow rules: the Falkon sim path pre-evaluates the
                # check at dispatch so TaskFailure.latency can set the
                # failed attempt's service time
                chk.timed = True
            task.fault_check = chk
        self.tasks_submitted += 1
        # dependency scan without per-task garbage: at frontier scale
        # (10^6 in-flight tasks) the list + closure the seed allocated
        # here were ~40% of per-task graph memory; `partial` carries the
        # task reference in one small object instead
        first = None
        nfuts = 0
        for a in args:
            if isinstance(a, DataFuture):
                nfuts += 1
                if first is None:
                    first = a
        if nfuts == 0:
            if tr is not None:
                task.path0 = -task.created_time
            if (duration is None and fn is not None
                    and self.duration_predictor is not None):
                task.duration = self.duration_predictor.predict_duration(
                    fn, args)
            self._dispatch(task)
        elif nfuts == 1:
            # single dependency (serial chains): skip the when_all counter
            first.on_done(partial(self._ready, task))
        else:
            when_all((a for a in args if isinstance(a, DataFuture)),
                     partial(self._ready, task))
        return out

    # -- submit-side backpressure (DESIGN.md §9) -----------------------
    def inflight(self) -> int:
        """Tasks submitted but not yet finished (queued, held, or running)."""
        return self.tasks_submitted - self.tasks_completed - self.tasks_failed

    def ready_backlog(self) -> int:
        """Ready tasks held because every valid site is throttled."""
        return len(self._pending)

    def pool_capacity(self) -> int:
        """Total registered site capacity (executor slots)."""
        return sum(s.capacity for s in self.balancer.sites)

    def dispatchable(self) -> int:
        """Dependency-free work the pool can chew on right now: tasks
        handed to site providers (queued or running) plus the held ready
        backlog.  Dependency-*blocked* tasks are excluded on purpose —
        they occupy memory, not executors."""
        return (sum(s.outstanding for s in self.balancer.sites)
                + len(self._pending))

    def saturated(self, slack: float | None = None) -> bool:
        """Submit-side backpressure (DESIGN.md §9): True while the engine
        already holds at least ``slack x pool capacity`` of *dispatchable*
        work.  Streaming `foreach` expansion keys its refill loop on this,
        so the standing frontier tracks pool capacity rather than a fixed
        window constant — expanding further ahead than this grows the
        graph, never the achieved throughput.  Keyed on dispatchable work,
        not `inflight()`: a pipeline-shaped body contributes mostly
        dependency-blocked tasks, and throttling on those would starve
        the pool long before memory was a concern (the hard memory bound
        is the window itself)."""
        cap = self.pool_capacity()
        if cap <= 0:
            return False
        if slack is None:
            slack = self.site_slack
        return self.dispatchable() >= slack * cap

    def add_backpressure_waiter(self, cb) -> None:
        """Register a single-shot callback fired when a completion leaves
        the engine unsaturated (all waiters fire together)."""
        self._bp_waiters.append(cb)

    def _wake_backpressure(self) -> None:
        if self._bp_waiters and not self.saturated():
            waiters, self._bp_waiters = self._bp_waiters, []
            for cb in waiters:
                cb()

    # ------------------------------------------------------------------
    def _ready(self, task: Task, _f: DataFuture | None = None):
        tr = self.tracer
        if tr is None:
            for a in task.args:
                if isinstance(a, DataFuture) and a.failed:
                    task.output.set_error(
                        TaskFailure(f"upstream failure for {task.name}"))
                    self.tasks_failed += 1
                    if self.journal is not None:
                        self.journal.task_failed(task.key, "upstream failure")
                    task.args = ()
                    return
        else:
            # single pass over the args: the upstream-failure check merged
            # with the O(1)/task critical-path propagation — path up to
            # this task's start is the max over its parents' path values,
            # read here *before* the args are cleared below (DESIGN.md §12)
            p0 = 0.0
            for a in task.args:
                if type(a) is DataFuture:
                    if a.failed:
                        task.output.set_error(
                            TaskFailure(f"upstream failure for {task.name}"))
                        self.tasks_failed += 1
                        tr.task_done(task, self.clock.now(), "failed")
                        if self.journal is not None:
                            self.journal.task_failed(task.key,
                                                     "upstream failure")
                        task.args = ()
                        return
                    p = a.path
                    if p > p0:
                        p0 = p
            now = self.clock.now()
            # path0 encodes (parent path - ready time): completion adds
            # `now` back, so the done-path costs one addition per task
            task.path0 = p0 - now
            sp = task.span
            if sp is not None:
                sp.ready = now
        if task.fn is None and task.vmap_key is None:
            # pure-sim task: the argument values are never read again, so
            # drop them now — in a streaming (windowed) expansion this is
            # what lets a resolved upstream chain be freed while its
            # dependents are still queued (DESIGN.md §9 GC contract)
            task.args = ()
        elif (task.duration is None and task.fn is not None
                and self.duration_predictor is not None):
            # future-fed tasks are priced here, when the argument shapes
            # are known; the predictor's signature cache makes this a
            # dict probe for every task after the first per signature
            task.duration = self.duration_predictor.predict_duration(
                task.fn, [a.get() if isinstance(a, DataFuture) else a
                          for a in task.args])
        self._dispatch(task)

    def _dispatch(self, task: Task, exclude_site: str | None = None):
        j = self.journal
        if j is not None and j.full:
            j.task_ready(task.key)
        if not self._place(task, exclude_site):
            # every valid site is at its throttle: hold in the ready queue
            self._pending.append((task, exclude_site))
            if self._federation is not None:
                self._federation.notify_backlog(self)

    def _place(self, task: Task, exclude_site: str | None = None) -> bool:
        """Try to hand the task to a site; False means *hold* (valid sites
        exist but all are throttled or suspended)."""
        cands = self.balancer.sites_for(task.app)
        if not cands:
            task.output.set_error(TaskFailure(f"no site for {task.name}"))
            self.tasks_failed += 1
            if self.tracer is not None:
                self.tracer.task_done(task, self.clock.now(), "failed")
            if self.journal is not None:
                self.journal.task_failed(task.key, "no site")
            return True  # consumed (failed), not held
        now = self.clock.now()
        # throttle only matters when there is a choice to steer: with a
        # single site the provider's own queue is the right place to wait —
        # unless this engine is a federation shard (`_hold_excess`), where
        # excess ready work stays in `_pending` so it can be stolen, or
        # fair share is on (§15), where the stride drain must own the
        # ordering of everything not yet running
        site = self.balancer.pick(task.app, now,
                                  require_room=(len(cands) > 1
                                                or self._hold_excess
                                                or self.fair_share),
                                  slack=self.site_slack,
                                  inputs=task.inputs or None)
        if site is None:
            return False
        if site.name == exclude_site:
            for s in cands:
                if s.name != exclude_site and now >= s.suspended_until:
                    site = s
                    break
        task.site = site
        task.submit_time = now
        j = self.journal
        if j is not None and j.full:
            j.task_dispatched(task.key)
        site.outstanding += 1
        if self.balancer.duration_aware:
            site.outstanding_work += sim_duration(task)
        site.stats.submitted += 1
        h = self.health
        if h is not None:
            # before provider.submit: a provider may complete synchronously
            # and the monitor must see dispatch before finish (inlined
            # HealthMonitor.task_dispatched — §13 hot-path contract)
            if not h._armed:
                h.arm()
            r = h._running
            if len(r) < h._track_cap:
                r.append(task)
        site.provider.submit(
            task, lambda ok, v, e: self._done(task, ok, v, e))
        return True

    def _drain_pending(self):
        """Batched drain: after completions free capacity, dispatch *every*
        pending task that now has room, in one pass.  The seed engine popped
        a single task per completion, which both cost one clock event per
        task and head-of-line-blocked apps whose site had no room.  The
        per-app buckets make the pass O(apps + placed): an app whose sites
        are full is skipped at its bucket head, its backlog untouched."""
        self._drain_scheduled = False
        pending = self._pending
        if self.fair_share and len(pending._buckets) > 1:
            self._drain_fair(pending)
            return
        for app, bucket in pending.buckets():
            while bucket:
                task, excl = bucket[0]
                if not self._place(task, excl):
                    break              # app blocked; leave its backlog be
                pending.pop_head(app)

    def _drain_fair(self, pending: ReadyQueue):
        """Stride-scheduled drain (DESIGN.md §15): each placement goes to
        the app with the smallest virtual *pass*, which then advances by
        1/share.  Per-app pass values persist across drains, so even when
        completions free one slot at a time the long-run placement ratio
        between backlogged apps converges to their `app_shares` weights —
        the first-arrival bucket order of the default drain would hand
        every freed slot to the oldest app until its backlog drained
        (the starved-app case in `tests/test_service.py`)."""
        passes = self._fair_pass
        shares = self.app_shares
        vt = self._fair_vt
        blocked: set = set()
        buckets = pending._buckets
        while True:
            best = None
            best_pass = 0.0
            for app, bucket in buckets.items():
                if app in blocked or not bucket:
                    continue
                p = passes.get(app)
                if p is None or p < vt:
                    # joining (or rejoining after idle) apps start at the
                    # current virtual time: an idle period banks no credit
                    passes[app] = p = vt
                if best is None or p < best_pass:
                    best, best_pass = app, p
            if best is None:
                break
            task, excl = buckets[best][0]
            if not self._place(task, excl):
                blocked.add(best)
                continue
            pending.pop_head(best)
            vt = best_pass
            passes[best] = best_pass + 1.0 / shares.get(best, 1.0)
        self._fair_vt = vt

    def _done(self, task: Task, ok: bool, value, err):
        site = task.site
        now = self.clock.now()
        site.outstanding -= 1
        if self.balancer.duration_aware:
            # clamp: float drift must never leave a phantom backlog
            site.outstanding_work = max(
                0.0, site.outstanding_work - sim_duration(task))
        if self._pending:
            if not self._drain_scheduled:
                self._drain_scheduled = True
                self.clock.schedule(0.0, self._drain_pending)
        elif self._federation is not None:
            # shard starving: no held backlog left — let the federation's
            # stealer consider migrating work here (flag-guarded, O(1))
            self._federation.notify_idle(self)
        fed = self._federation
        if fed is not None and fed._bp_waiters:
            fed._wake_backpressure()
        if self._bp_waiters:
            # not elif: a workflow driven over one *shard* of a federation
            # registers its waiters here, and they must still fire
            self._wake_backpressure()
        if ok:
            site.on_success(now - task.submit_time)
            self.tasks_completed += 1
            h = self.health
            if h is not None:
                # inlined HealthMonitor.task_finished (§13 hot-path
                # contract): error windows come from Site.stats counter
                # deltas on the monitor's tick, and the straggler registry
                # self-prunes — a success pays only the sampling stride
                if h._dur_skip:
                    h._dur_skip -= 1
                else:
                    h.sample_turnaround(task, site, now)
            self._record(task, "ok")
            if self.restart_log is not None and task.durable:
                self.restart_log.append(task.key, value)
            if self.journal is not None:
                self.journal.task_done(task.key, value)
            tr = self.tracer
            if tr is not None:
                # inlined Tracer.task_done: stamp the output's critical-path
                # length before the set() fires downstream callbacks
                # (dependents read it in _ready)
                tr.tasks_done += 1
                path = task.path0 + now
                if path > tr.critical_path_s:
                    tr.critical_path_s = path
                task.output.path = path
                sp = task.span
                if sp is not None:
                    tr._close_span(sp, task, now, "ok")
            task.args = ()             # resolved chains must be GC-able: a
            task.fault_check = None    # retained record must not pin its
            task.output.set(value)     # upstream futures (DESIGN.md §9)
            return
        # failure path (§3.12)
        if getattr(err, "kind", None) == "revoked":
            # administrative drain revocation (DESIGN.md §13): a drained
            # service handed the still-queued task back — re-place it on
            # another site without charging a retry or denting the score
            self.tasks_revoked += 1
            if self.health is not None:
                self.health.task_revoked(task)
            if self.tracer is not None:
                self.tracer.event("revoked", now)
            if self.journal is not None and self.journal.full:
                self.journal.task_revoked(task.key)
            self._dispatch(task, exclude_site=site.name)
            return
        site.on_failure()
        # no monitor hook on failure: error windows come from Site.stats
        # counter deltas, and the straggler registry entry (if any) tracks
        # the live task across its retries (HealthMonitor.task_finished)
        failures = task.site_failures
        if failures is None:
            failures = task.site_failures = {}
        failures[site.name] = failures.get(site.name, 0) + 1
        self._record(task, "retried" if task.retries_left > 0 else "failed",
                     error=str(err))
        tr = self.tracer
        if tr is not None:
            status = "retried" if task.retries_left > 0 else "failed"
            path = tr.task_done(task, now, status)
            if status == "failed":
                task.output.path = path
        if task.retries_left <= 0:
            self.tasks_failed += 1
            if self.journal is not None:
                self.journal.task_failed(task.key, str(err))
            task.args = ()
            task.fault_check = None
            task.output.set_error(err or TaskFailure(f"{task.name} failed"))
            return
        task.retries_left -= 1
        task.attempt += 1
        exclude = None
        kind = getattr(err, "kind", "transient")
        if (kind == "site" or failures[site.name]
                >= self.retry_policy.site_fail_threshold):
            exclude = site.name  # reschedule at a different site
        self.clock.schedule(self.retry_policy.backoff,
                            lambda: self._dispatch(task, exclude_site=exclude))

    def _record(self, task: Task, status: str, error: str = ""):
        now = self.clock.now()
        if not self._prov_records:
            self.vdc.tally(status == "ok",
                           task.start_time - task.submit_time,
                           now - task.start_time)
            return
        sp = getattr(task, "span", None)
        self.vdc.record(InvocationRecord(
            task_id=str(task.id), name=task.name,
            site=task.site.name if task.site else "",
            host=task.host, submit_time=task.submit_time,
            start_time=task.start_time, end_time=now,
            exit_status=status, attempt=task.attempt,
            args_repr="", outputs=[task.output.name], error=error,
            span_id=sp.span_id if sp is not None else ""))

    def poke(self) -> None:
        """Schedule a pending-queue drain pass.  Completions trigger drains
        on their own; this exists for *external* capacity changes — the
        health monitor calls it when a site suspension lapses (the
        recovery probe), since with every site suspended no completion
        would ever arrive to unwedge the held backlog."""
        if self._pending and not self._drain_scheduled:
            self._drain_scheduled = True
            self.clock.schedule(0.0, self._drain_pending)

    # ------------------------------------------------------------------
    def run(self):
        self.clock.run()

    def stats(self) -> dict:
        return {
            "submitted": self.tasks_submitted,
            "completed": self.tasks_completed,
            "failed": self.tasks_failed,
            "restored_from_log": self.tasks_restored,
            "revoked": self.tasks_revoked,
            "makespan": self.clock.now(),
        }
