"""The Karajan-style execution engine (paper §3.8-3.13).

Event-driven, future-based: every task is a lightweight record (no OS
threads); data dependencies are futures; a task becomes *ready* when its
argument futures resolve and is dispatched through a provider picked by the
score-based load balancer.  Pipelining across stages is inherent (§3.13 —
"comes for free with the future mechanism").

Providers implement the paper's abstract provider interface (§3.11):

  * LocalProvider           — run on the submit host
  * BatchSchedulerProvider  — simulated PBS/Condor: serial submission rate +
                              scheduler latency + node pool (the GRAM+PBS
                              baseline of Figs 6/12/13/14)
  * FalkonProvider          — the Falkon service (multi-level scheduling)
  * ClusteringProvider      — wraps any provider, bundling small tasks within
                              a clustering window (§3.13)
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Callable, Optional

from repro.core import falkon as falkon_mod
from repro.core.faults import FaultInjector, RetryPolicy, TaskFailure
from repro.core.futures import DataFuture, when_all
from repro.core.provenance import VDC, InvocationRecord
from repro.core.restart_log import RestartLog
from repro.core.simclock import Clock, RealClock, SimClock
from repro.core.sites import LoadBalancer, Site

_task_ids = itertools.count()


class Task:
    __slots__ = ("id", "name", "key", "fn", "args", "output", "duration",
                 "sim_value", "app", "attempt", "retries_left", "site",
                 "host", "created_time", "submit_time", "start_time",
                 "durable", "fault_check", "_falkon_done", "vmap_key",
                 "site_failures")

    def __init__(self, name: str, fn, args, output: DataFuture,
                 duration: float | None, app: str | None,
                 retries: int, durable: bool, key: str):
        self.id = next(_task_ids)
        self.name = name
        self.key = key
        self.fn = fn
        self.args = args
        self.output = output
        self.duration = duration
        self.sim_value = None
        self.app = app
        self.attempt = 0
        self.retries_left = retries
        self.site: Optional[Site] = None
        self.host = ""
        self.created_time = 0.0
        self.submit_time = 0.0
        self.start_time = 0.0
        self.durable = durable
        self.fault_check = None
        self.vmap_key = None
        self.site_failures: dict = {}


# ---------------------------------------------------------------------------
# providers
# ---------------------------------------------------------------------------

class Provider:
    name = "provider"

    def submit(self, task: Task, when_done: Callable) -> None:
        raise NotImplementedError


class LocalProvider(Provider):
    """Immediate local execution (the paper's local-host provider)."""

    name = "local"

    def __init__(self, clock: Clock, concurrency: int = 1):
        self.clock = clock
        self.concurrency = concurrency
        self._running = 0
        self._queue: deque = deque()

    def submit(self, task: Task, when_done: Callable) -> None:
        self._queue.append((task, when_done))
        self._pump()

    def _pump(self):
        while self._queue and self._running < self.concurrency:
            task, when_done = self._queue.popleft()
            self._running += 1
            task.start_time = self.clock.now()

            def fin(task=task, when_done=when_done):
                ok, value, err = falkon_mod._execute(task)
                self._running -= 1
                when_done(ok, value, err)
                self._pump()

            self.clock.schedule(falkon_mod._sim_duration(task), fin)


class BatchSchedulerProvider(Provider):
    """Simulated conventional batch scheduler (PBS / Condor).

    Models the paper's measured behavior: a serial job-submission throttle
    (GRAM gateway: ~1/5 jobs/s in §5.4.3; PBS ~1-2 jobs/s in Fig 12) plus a
    per-job scheduler latency, over a fixed node pool.
    """

    name = "batch"

    def __init__(self, clock: Clock, nodes: int, submit_rate: float = 1.0,
                 sched_latency: float = 60.0):
        self.clock = clock
        self.nodes = nodes
        self.submit_interval = 1.0 / submit_rate
        self.sched_latency = sched_latency
        self._busy = 0
        self._queue: deque = deque()
        self._gateway_free_at = 0.0

    def submit(self, task: Task, when_done: Callable) -> None:
        now = self.clock.now()
        # serial submission gateway (throttled)
        gate = max(now, self._gateway_free_at)
        self._gateway_free_at = gate + self.submit_interval
        delay = (gate - now) + self.sched_latency

        def queued():
            self._queue.append((task, when_done))
            self._pump()

        self.clock.schedule(delay, queued)

    def _pump(self):
        while self._queue and self._busy < self.nodes:
            task, when_done = self._queue.popleft()
            self._busy += 1
            task.start_time = self.clock.now()

            def fin(task=task, when_done=when_done):
                ok, value, err = falkon_mod._execute(task)
                self._busy -= 1
                when_done(ok, value, err)
                self._pump()

            self.clock.schedule(falkon_mod._sim_duration(task), fin)


class FalkonProvider(Provider):
    name = "falkon"

    def __init__(self, service: falkon_mod.FalkonService):
        self.service = service

    def submit(self, task: Task, when_done: Callable) -> None:
        self.service.submit(task, when_done)


class ClusteringProvider(Provider):
    """Dynamic clustering (§3.13): accumulate ready tasks for a clustering
    window, then submit them as one bundle paying one per-job overhead.
    No prior knowledge of the workflow graph is needed."""

    name = "clustering"

    def __init__(self, clock: Clock, inner: Provider, window: float = 1.0,
                 bundle_size: int = 8):
        self.clock = clock
        self.inner = inner
        self.window = window
        self.bundle_size = bundle_size
        self._pending: list = []
        self._flush_scheduled = False

    def submit(self, task: Task, when_done: Callable) -> None:
        self._pending.append((task, when_done))
        if len(self._pending) >= self.bundle_size:
            self._flush()
        elif not self._flush_scheduled:
            self._flush_scheduled = True
            self.clock.schedule(self.window, self._window_flush)

    def _window_flush(self):
        self._flush_scheduled = False
        if self._pending:
            self._flush()

    def _flush(self):
        bundle, self._pending = self._pending[:self.bundle_size], \
            self._pending[self.bundle_size:]
        if not bundle:
            return
        tasks = [t for t, _ in bundle]
        total = sum(falkon_mod._sim_duration(t) for t in tasks)

        def run_bundle(*_):
            results = []
            for t, _cb in bundle:
                ok, value, err = falkon_mod._execute(t)
                results.append((ok, value, err))
            return results

        meta = Task(name=f"bundle[{len(bundle)}]", fn=run_bundle, args=[],
                    output=DataFuture(), duration=total, app=tasks[0].app,
                    retries=0, durable=False, key="")
        meta.fault_check = None

        def done(ok, results, err):
            if not ok or results is None:
                for _t, cb in bundle:
                    cb(False, None, err or TaskFailure("bundle failed"))
                return
            for (t, cb), (ok_i, v_i, e_i) in zip(bundle, results):
                cb(ok_i, v_i, e_i)

        self.inner.submit(meta, done)
        if self._pending:
            self._flush()


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class Engine:
    def __init__(self, clock: Clock | None = None,
                 retry_policy: RetryPolicy | None = None,
                 vdc: VDC | None = None,
                 restart_log: RestartLog | None = None,
                 fault_injector: FaultInjector | None = None):
        self.clock = clock or SimClock()
        self.retry_policy = retry_policy or RetryPolicy()
        self.vdc = vdc or VDC()
        self.restart_log = restart_log
        self.fault_injector = fault_injector
        self.balancer = LoadBalancer([])
        self.tasks_submitted = 0
        self.tasks_completed = 0
        self.tasks_failed = 0
        self.tasks_restored = 0
        # per-site submission throttle (Swift holds excess ready tasks and
        # feeds sites as they turn jobs around, letting responsiveness
        # scores steer the split — paper §3.13)
        self.site_slack = 2.0
        self._pending: deque = deque()

    # ------------------------------------------------------------------
    def add_site(self, name: str, provider: Provider, capacity: int = 1,
                 apps: set[str] | None = None) -> Site:
        site = Site(name, provider, capacity, apps)
        self.balancer.add_site(site)
        return site

    def local_site(self, concurrency: int = 1) -> Site:
        return self.add_site("localhost",
                             LocalProvider(self.clock, concurrency),
                             capacity=concurrency)

    # ------------------------------------------------------------------
    def submit(self, name: str, fn=None, args: list | None = None,
               duration: float | None = None, app: str | None = None,
               durable: bool = False, key: str | None = None,
               vmap_key=None) -> DataFuture:
        args = args or []
        out = DataFuture(name=name)
        if key is None:
            # dataflow-stable keys are only needed for restart-log lookups;
            # skip the fingerprint hash on the hot path otherwise
            key = self._task_key(name, args) if self.restart_log is not None \
                else f"{name}#{self.tasks_submitted}"
        out.name = key

        # restart log: datasets already produced are marked available and
        # their producing tasks are NOT re-run (paper §3.12)
        if self.restart_log is not None:
            hit, value = self.restart_log.lookup(key)
            if hit:
                self.tasks_restored += 1
                out.set(value)
                return out

        task = Task(name, fn, args, out, duration, app,
                    self.retry_policy.max_retries, durable, key)
        task.created_time = self.clock.now()
        task.vmap_key = vmap_key
        if self.fault_injector is not None:
            inj = self.fault_injector

            def chk(t):
                inj.check(t.name, t.host, t.attempt)

            task.fault_check = chk
        self.tasks_submitted += 1
        futs = [a for a in args if isinstance(a, DataFuture)]
        when_all(futs, lambda: self._ready(task))
        return out

    def _task_key(self, name: str, args: list) -> str:
        parts = [name]
        for a in args:
            if isinstance(a, DataFuture):
                parts.append(f"f:{a.name or a.id}")
            elif hasattr(a, "shape") and hasattr(a, "dtype"):
                # arrays: cheap structural fingerprint (repr would format
                # the whole buffer)
                parts.append(f"arr:{a.shape}:{a.dtype}:{id(a)}")
            else:
                parts.append(repr(a))
        import hashlib
        return name + "#" + hashlib.sha1("|".join(parts).encode()).hexdigest()[:12]

    # ------------------------------------------------------------------
    def _ready(self, task: Task):
        failed = [a for a in task.args
                  if isinstance(a, DataFuture) and a.failed]
        if failed:
            task.output.set_error(
                TaskFailure(f"upstream failure for {task.name}"))
            self.tasks_failed += 1
            return
        self._dispatch(task)

    def _dispatch(self, task: Task, exclude_site: str | None = None):
        now = self.clock.now()
        # throttle only matters when there is a choice to steer: with a
        # single site the provider's own queue is the right place to wait
        multi = sum(1 for s in self.balancer.sites
                    if s.valid_for(task.app)) > 1
        site = self.balancer.pick(task.app, now, require_room=multi,
                                  slack=self.site_slack)
        if site is None and self.balancer.any_valid(task.app):
            # every valid site is at its throttle: hold in the ready queue
            self._pending.append((task, exclude_site))
            return
        if site is not None and site.name == exclude_site:
            for s in self.balancer.sites:
                if s.name != exclude_site and s.valid_for(task.app):
                    site = s
                    break
        if site is None:
            task.output.set_error(TaskFailure(f"no site for {task.name}"))
            self.tasks_failed += 1
            return
        task.site = site
        task.submit_time = self.clock.now()
        site.outstanding += 1
        site.stats.submitted += 1
        site.provider.submit(
            task, lambda ok, v, e: self._done(task, ok, v, e))

    def _done(self, task: Task, ok: bool, value, err):
        site = task.site
        now = self.clock.now()
        site.outstanding -= 1
        if self._pending:
            nxt, excl = self._pending.popleft()
            self.clock.schedule(0.0, lambda: self._dispatch(nxt, excl))
        if ok:
            site.on_success(now - task.submit_time)
            self.tasks_completed += 1
            self._record(task, "ok")
            if self.restart_log is not None and task.durable:
                self.restart_log.append(task.key, value)
            task.output.set(value)
            return
        # failure path (§3.12)
        site.on_failure()
        task.site_failures[site.name] = task.site_failures.get(site.name, 0) + 1
        self._record(task, "retried" if task.retries_left > 0 else "failed",
                     error=str(err))
        if task.retries_left <= 0:
            self.tasks_failed += 1
            task.output.set_error(err or TaskFailure(f"{task.name} failed"))
            return
        task.retries_left -= 1
        task.attempt += 1
        exclude = None
        kind = getattr(err, "kind", "transient")
        if (kind == "site" or task.site_failures[site.name]
                >= self.retry_policy.site_fail_threshold):
            exclude = site.name  # reschedule at a different site
        self.clock.schedule(self.retry_policy.backoff,
                            lambda: self._dispatch(task, exclude_site=exclude))

    def _record(self, task: Task, status: str, error: str = ""):
        self.vdc.record(InvocationRecord(
            task_id=str(task.id), name=task.name,
            site=task.site.name if task.site else "",
            host=task.host, submit_time=task.submit_time,
            start_time=task.start_time, end_time=self.clock.now(),
            exit_status=status, attempt=task.attempt,
            args_repr="", outputs=[task.output.name], error=error))

    # ------------------------------------------------------------------
    def run(self):
        self.clock.run()

    def stats(self) -> dict:
        return {
            "submitted": self.tasks_submitted,
            "completed": self.tasks_completed,
            "failed": self.tasks_failed,
            "restored_from_log": self.tasks_restored,
            "makespan": self.clock.now(),
        }
