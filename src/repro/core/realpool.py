"""Real concurrent executor pools behind the provider seam (DESIGN.md §10).

The simulated path prices a task's service time and schedules its completion
on the clock; this module supplies the *real* alternative: task bodies run on
actual OS workers, durations are measured, and completions re-enter the
single-threaded scheduler through `Clock.post_release`.  Both pools expose
one seam:

    submit(task, done, stage=None)   # done(ok, value, err, io_s, run_s)
    resize(n) / size() / shutdown()

and register with `FalkonService(pool=...)` exactly like the simulated
executor pool — DRP provisioning acquires real workers (an allocation
spawns threads, idle shrink retires them) — or back a `WorkerPoolProvider`
(``LocalProvider(clock, n, pool=...)``) directly.

  * `ThreadExecutorPool`  — N daemon worker threads over one shared work
    queue.  Right default: scientific task bodies that release the GIL
    (NumPy/JAX, I/O, subprocesses) and every dispatch-overhead benchmark.
  * `ProcessExecutorPool` — `concurrent.futures.ProcessPoolExecutor`
    behind the same seam, for GIL-bound pure-Python bodies.  Task callables
    and resolved argument values must be picklable; fault checks and
    staging copies run on the clock thread (the child sees only
    ``fn(*args)``).

Threading contract: `submit` is called on the clock thread only; workers
touch nothing but the work queue and `post_release`; `done` and all pool
counters run back on the clock thread.  See DESIGN.md §10.
"""
from __future__ import annotations

import queue
import threading
from functools import partial
from time import perf_counter
from typing import Callable, Optional

from repro.core.metrics import StreamStat
from repro.core.simclock import Clock
from repro.core.task import execute_task

_STOP = object()


def _require_threadsafe_clock(clock: Clock, name: str) -> None:
    """Pools complete through `post_release` from worker threads, and rely
    on `run()` blocking while hold tokens are out — a clock without the
    thread-safe post/hold protocol (e.g. `SimClock`) would race its event
    heap and exit with bodies still on workers, silently losing
    completions.  Fail at construction, not mid-run."""
    if not getattr(clock, "threadsafe_post", False):
        raise ValueError(
            f"pool {name!r} needs a clock with thread-safe post/hold "
            f"(RealClock), got {type(clock).__name__}; simulated runs "
            f"use no pool at all")


class ThreadExecutorPool:
    """Real worker threads behind the provider/Falkon seam.

    Example — the same engine program as the simulated path, on threads::

        clock = RealClock()
        pool = ThreadExecutorPool(clock)          # autoscales with DRP
        svc = FalkonService(clock, cfg, pool=pool)
        eng = Engine(clock)
        eng.add_site("pod0", FalkonProvider(svc), capacity=64)
        ... submit tasks with real callables ...
        eng.run()
        pool.shutdown()

    With ``workers=0`` (default) the pool *autoscales*: a `FalkonService`
    it is attached to resizes it to the executor count on every DRP
    allocation arrival and idle shrink, so provisioning acquires and
    releases actual threads.  Pass ``workers=n`` for a fixed-size pool
    (e.g. behind a `LocalProvider`).

    Measured, not priced: `done` receives the staging time and body runtime
    observed on the worker (`perf_counter` deltas); the pool aggregates
    them in bounded `StreamStat` summaries (`io_stat`, `run_stat`).
    """

    autoscale: bool

    def __init__(self, clock: Clock, workers: int = 0, name: str = "threads",
                 tracer=None):
        _require_threadsafe_clock(clock, name)
        self.clock = clock
        self.name = name
        # observability (DESIGN.md §12): completions emit `worker_task`
        # events (count + measured body seconds); mutated on the clock
        # thread only, like every other pool counter
        self.tracer = tracer
        self.autoscale = workers <= 0
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._threads: list[threading.Thread] = []
        self._alive = 0
        self._lock = threading.Lock()
        self._shutdown = False
        # counters/summaries — mutated on the clock thread only
        self.tasks_run = 0
        self.io_stat = StreamStat(cap=256)    # measured staging s per task
        self.run_stat = StreamStat(cap=256)   # measured body s per task
        if workers > 0:
            self.resize(workers)

    def size(self) -> int:
        with self._lock:
            return self._alive

    def resize(self, n: int) -> None:
        """Grow or shrink to `n` worker threads.  Shrinking is graceful:
        retiring workers finish their current task first."""
        if self._shutdown:
            raise RuntimeError(f"pool {self.name!r} is shut down")
        n = max(0, n)
        with self._lock:
            grow = n - self._alive
            self._alive = n
        # drop threads already retired by earlier shrinks, so the roster
        # stays bounded by the live count under autoscale churn
        self._threads = [t for t in self._threads if t.is_alive()]
        for _ in range(max(0, grow)):
            t = threading.Thread(target=self._loop,
                                 name=f"{self.name}-worker", daemon=True)
            self._threads.append(t)
            t.start()
        for _ in range(max(0, -grow)):
            self._q.put(_STOP)

    # -- the seam (clock thread) ----------------------------------------
    def submit(self, task, done: Callable,
               stage: Optional[Callable[[], None]] = None) -> None:
        """Hand one task to the workers.  `stage` (optional) performs the
        real input-staging copies; it runs on the worker, inside the task's
        service time, exactly where the simulated path adds priced staging
        I/O — the pool times it and reports the seconds as `io_s`.
        `done(ok, value, err, io_s, run_s)` is called back on the clock
        thread."""
        self.clock.hold()
        self._q.put((task, stage, done))

    def shutdown(self, wait: bool = True) -> None:
        """Stop all workers (after their queued work) and join them."""
        if self._shutdown:
            return
        self._shutdown = True
        with self._lock:
            alive, self._alive = self._alive, 0
        for _ in range(alive):
            self._q.put(_STOP)
        if wait:
            for t in self._threads:
                t.join()
        self._threads.clear()

    # -- worker side -----------------------------------------------------
    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is _STOP:
                return
            task, stage, done = item
            t0 = perf_counter()
            if stage is not None:
                try:
                    stage()
                except BaseException as err:  # noqa: BLE001 — staging
                    t1 = perf_counter()           # failure fails the task
                    self.clock.post_release(partial(
                        self._complete, done, False, None, err, t1 - t0, 0.0))
                    continue
            t1 = perf_counter()
            ok, value, err = execute_task(task)
            t2 = perf_counter()
            self.clock.post_release(partial(
                self._complete, done, ok, value, err, t1 - t0, t2 - t1))

    # -- back on the clock thread ----------------------------------------
    def _complete(self, done, ok, value, err, io_s, run_s) -> None:
        self.tasks_run += 1
        now = self.clock.now()
        self.io_stat.observe(now, io_s)
        self.run_stat.observe(now, run_s)
        if self.tracer is not None:
            self.tracer.event("worker_task", now, run_s)
            if not ok:
                # worker-level failure signal (DESIGN.md §13): the health
                # monitor's event subscription sees pool errors even before
                # the engine's completion path classifies them
                self.tracer.event("worker_error", now)
        done(ok, value, err, io_s, run_s)

    def metrics(self) -> dict:
        """Bounded snapshot — safe at any task count."""
        return {
            "workers": self.size(),
            "tasks_run": self.tasks_run,
            "io_s": self.io_stat.summary(),
            "run_s": self.run_stat.summary(),
        }

    def stats_snapshot(self) -> dict:
        """Picklable measured-stat state (DESIGN.md §14): a shard process
        ships this back at shutdown and the parent folds it into one
        federation-wide view via `StreamStat.merge`."""
        return {
            "tasks_run": self.tasks_run,
            "io_s": self.io_stat.snapshot(),
            "run_s": self.run_stat.snapshot(),
        }


def _run_remote(fn, args):
    """Child-process task body (module-level so it pickles)."""
    return fn(*args)


class ProcessExecutorPool:
    """`ProcessPoolExecutor` behind the same seam as `ThreadExecutorPool`,
    for GIL-bound pure-Python task bodies.

    Example::

        pool = ProcessExecutorPool(clock, workers=4)
        svc = FalkonService(clock, cfg, pool=pool)

    Differences from the thread pool (all documented in DESIGN.md §10):
    the task callable and its *resolved* argument values cross a pickle
    boundary; fault checks run on the clock thread before dispatch; the
    `stage` closure (real staging copies) also runs on the clock thread —
    shipping cache bytes to a child and back would measure pickling, not
    staging.  Pure-sim tasks (no callable) complete without touching the
    process pool at all.  The pool is fixed-size (`autoscale` is False):
    spawning workers per DRP allocation would dominate any measurement.

    Workers start via the ``"spawn"`` method by default: the parent is
    multi-threaded by construction (worker pools, JAX runtimes), and
    forking a multi-threaded process can deadlock the child.  Pass
    ``mp_context="fork"`` only when the parent is known thread-free.
    """

    autoscale = False

    def __init__(self, clock: Clock, workers: int, name: str = "processes",
                 mp_context: str = "spawn", tracer=None):
        if workers < 1:
            raise ValueError("ProcessExecutorPool needs >= 1 worker")
        _require_threadsafe_clock(clock, name)
        self.clock = clock
        self.name = name
        self.tracer = tracer
        self.workers = workers
        self.mp_context = mp_context
        self._exe = None
        self._shutdown = False
        self.tasks_run = 0
        self.io_stat = StreamStat(cap=256)
        self.run_stat = StreamStat(cap=256)

    def size(self) -> int:
        return self.workers

    def resize(self, n: int) -> None:
        """Fixed-size by design; resize requests are ignored (the service
        calls this only for `autoscale` pools)."""

    def _executor(self):
        if self._exe is None:
            import multiprocessing
            from concurrent.futures import ProcessPoolExecutor
            self._exe = ProcessPoolExecutor(
                max_workers=self.workers,
                mp_context=multiprocessing.get_context(self.mp_context))
        return self._exe

    # -- the seam (clock thread) ----------------------------------------
    def submit(self, task, done: Callable,
               stage: Optional[Callable[[], None]] = None) -> None:
        if self._shutdown:
            raise RuntimeError(f"pool {self.name!r} is shut down")
        t0 = perf_counter()
        if stage is not None:
            try:
                stage()
            except BaseException as err:  # noqa: BLE001
                io_s = perf_counter() - t0
                self.clock.schedule(0.0, partial(
                    self._complete, done, False, None, err, io_s, 0.0))
                return
        io_s = perf_counter() - t0
        chk = getattr(task, "fault_check", None)
        if chk is not None:
            try:
                chk(task)
            except BaseException as err:  # noqa: BLE001
                self.clock.schedule(0.0, partial(
                    self._complete, done, False, None, err, io_s, 0.0))
                return
        fn = getattr(task, "fn", None)
        if fn is None:
            # pure-sim task: nothing to run remotely
            self.clock.schedule(0.0, partial(
                self._complete, done, True,
                getattr(task, "sim_value", None), None, io_s, 0.0))
            return
        try:
            args = [a.get() if hasattr(a, "get") and hasattr(a, "on_done")
                    else a for a in task.args]
            fut = self._executor().submit(_run_remote, fn, args)
        except BaseException as err:  # noqa: BLE001 — unpicklable body etc.
            self.clock.schedule(0.0, partial(
                self._complete, done, False, None, err, io_s, 0.0))
            return
        self.clock.hold()
        t1 = perf_counter()

        def on_future_done(f):              # executor waiter thread
            run_s = perf_counter() - t1
            err = f.exception()
            if err is not None:
                res = (False, None, err)
            else:
                res = (True, f.result(), None)
            self.clock.post_release(partial(
                self._complete, done, *res, io_s, run_s))

        fut.add_done_callback(on_future_done)

    def shutdown(self, wait: bool = True) -> None:
        if self._shutdown:
            return
        self._shutdown = True
        if self._exe is not None:
            self._exe.shutdown(wait=wait)
            self._exe = None

    # -- back on the clock thread ----------------------------------------
    def _complete(self, done, ok, value, err, io_s, run_s) -> None:
        self.tasks_run += 1
        now = self.clock.now()
        self.io_stat.observe(now, io_s)
        self.run_stat.observe(now, run_s)
        if self.tracer is not None:
            self.tracer.event("worker_task", now, run_s)
            if not ok:
                # worker-level failure signal (DESIGN.md §13): the health
                # monitor's event subscription sees pool errors even before
                # the engine's completion path classifies them
                self.tracer.event("worker_error", now)
        done(ok, value, err, io_s, run_s)

    def metrics(self) -> dict:
        """Bounded snapshot — safe at any task count."""
        return {
            "workers": self.workers,
            "tasks_run": self.tasks_run,
            "io_s": self.io_stat.summary(),
            "run_s": self.run_stat.summary(),
        }

    def stats_snapshot(self) -> dict:
        """Picklable measured-stat state (see `ThreadExecutorPool`)."""
        return {
            "tasks_run": self.tasks_run,
            "io_s": self.io_stat.snapshot(),
            "run_s": self.run_stat.snapshot(),
        }
