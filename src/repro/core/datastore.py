"""Data diffusion: cache-aware data layer for the Falkon service (paper §6).

The paper names data management as the remaining bottleneck once dispatch is
fast, and proposes *data diffusion* as the Falkon follow-on: repeated input
files are served from executor-local caches instead of the shared filesystem,
and the dispatcher steers tasks toward executors that already hold their
inputs.  This module is that data layer:

  * `DataObject`      — descriptor of one input file (name, size, home store)
  * `SharedStore`     — the GPFS-like home filesystem; tracks concurrent
                        readers so staging cost degrades under contention
                        (the Fig-8 aggregate-bandwidth ceiling)
  * `ExecutorCache`   — per-executor local cache with pluggable eviction
                        (LRU / LFU / size-aware) and pin counts: objects in
                        use by a running task are never evicted (deferred)
  * `StagingCostModel`— shared-filesystem vs local-read bandwidth/latency,
                        calibrated like DESIGN.md §6's provider parameters
  * `DataLayer`       — binds the above; owns the per-object *holder index*
                        (object name -> executors caching it) so the
                        cache-aware dispatch lookup is O(task inputs), not
                        O(executors), and bounded `StreamStat`
                        hit/miss/staged-bytes metrics

Scale contract (DESIGN.md §7): per-task cost of the data layer is
O(inputs x probe_limit); all metrics are bounded; the locality-blind path
(`data_layer=None` on the service) is untouched.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import TYPE_CHECKING, Iterable, Optional

from repro.core.metrics import StreamStat

if TYPE_CHECKING:
    from repro.core.falkon import Executor
    from repro.core.simclock import Clock


class DataObject:
    """One logical input file: a name, a size, and a home store.

    `store` is provenance only (which store holds the authoritative copy);
    a `DataLayer` prices all staging against the single `SharedStore` it
    was constructed with.
    """

    __slots__ = ("name", "size", "store")

    def __init__(self, name: str, size: float, store: str = "gpfs"):
        if size < 0:
            raise ValueError("DataObject size must be >= 0")
        self.name = name
        self.size = float(size)
        self.store = store

    def __repr__(self):
        return f"DataObject({self.name!r}, {self.size:.3g}B)"

    def __hash__(self):
        return hash(self.name)

    def __eq__(self, other):
        return isinstance(other, DataObject) and other.name == self.name


@dataclasses.dataclass
class StagingCostModel:
    """Staging / read cost, calibrated against Fig 8 (see DESIGN.md §7).

    The shared filesystem has an *aggregate* bandwidth ceiling (Fig 8:
    4 GB/s over 8 I/O servers); a single reader cannot exceed
    `shared_reader_bw`, and concurrent readers split the aggregate.  Local
    cache reads avoid both the network and the contention.
    """

    shared_aggregate_bw: float = 4e9     # Fig 8: GPFS, 8 I/O servers
    shared_reader_bw: float = 500e6      # one reader ~ one I/O server
    shared_latency: float = 0.010        # per-read shared-fs round trip
    local_bw: float = 2e9                # executor-local read
    local_latency: float = 0.001

    def shared_read_time(self, size: float, readers: int = 1) -> float:
        bw = min(self.shared_reader_bw,
                 self.shared_aggregate_bw / max(1, readers))
        return self.shared_latency + size / bw

    def local_read_time(self, size: float) -> float:
        return self.local_latency + size / self.local_bw


class SharedStore:
    """The home filesystem (GPFS in the paper's runs).

    Holds the authoritative copy of every `DataObject` and a live
    concurrent-reader count that `DataLayer` uses to price staging under
    contention.  Bookkeeping is O(1) per read.
    """

    def __init__(self, name: str = "gpfs"):
        self.name = name
        self.objects: dict[str, DataObject] = {}
        # real payload bytes (DESIGN.md §10): populated by `put`, read by
        # measured staging; objects declared by size only synthesize a
        # zero-filled payload at read time
        self.payloads: dict[str, bytes] = {}
        self.readers = 0
        self.reads = 0
        self.bytes_read = 0.0

    def add(self, obj: DataObject) -> DataObject:
        self.objects[obj.name] = obj
        return obj

    def put(self, name: str, data: bytes) -> DataObject:
        """Store a real payload (DESIGN.md §10): declares `name` with the
        payload's size and keeps the bytes, so measured staging copies the
        actual content into executor caches instead of synthesizing
        zeros.  Example::

            store = SharedStore()
            archive = store.put("params.tar", b"x" * 4096)
        """
        obj = self.file(name, len(data))
        self.payloads[name] = bytes(data)
        return obj

    def payload(self, obj: DataObject) -> bytes | None:
        """The stored payload for `obj`, or None when it was declared by
        size only (measured staging then synthesizes zeros of that size)."""
        return self.payloads.get(obj.name)

    def file(self, name: str, size: float) -> DataObject:
        """Declare (or look up) a file in this store.  Re-declaring a name
        with a different size is almost certainly a typo and would silently
        skew every byte metric, so it raises."""
        obj = self.objects.get(name)
        if obj is None:
            obj = self.add(DataObject(name, size, self.name))
        elif obj.size != float(size):
            raise ValueError(f"{name!r} already declared with size "
                             f"{obj.size:g}, not {float(size):g}")
        return obj

    def _begin_read(self, size: float) -> None:
        self.readers += 1
        self.reads += 1
        self.bytes_read += size

    def _end_read(self) -> None:
        self.readers -= 1


# ---------------------------------------------------------------------------
# eviction policies
# ---------------------------------------------------------------------------

class EvictionPolicy:
    """Bookkeeping interface for `ExecutorCache` victim selection.

    Implementations must be deterministic (no RNG, no wall clock) so
    cache-aware dispatch replays identically under `SimClock`.
    """

    name = "policy"

    def on_admit(self, obj: DataObject) -> None:
        raise NotImplementedError

    def on_access(self, obj: DataObject) -> None:
        raise NotImplementedError

    def on_evict(self, obj: DataObject) -> None:
        raise NotImplementedError

    def victim(self, cache: "ExecutorCache") -> Optional[str]:
        """Name of the next evictable (present, unpinned) object, else None."""
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    """Least-recently-used: dict insertion order is recency order."""

    name = "lru"

    def __init__(self):
        self._order: dict[str, None] = {}

    def on_admit(self, obj: DataObject) -> None:
        self._order[obj.name] = None

    def on_access(self, obj: DataObject) -> None:
        # move to most-recent end
        del self._order[obj.name]
        self._order[obj.name] = None

    def on_evict(self, obj: DataObject) -> None:
        self._order.pop(obj.name, None)

    def victim(self, cache: "ExecutorCache") -> Optional[str]:
        for name in self._order:        # oldest first
            if not cache.pinned(name):
                return name
        return None


class LFUPolicy(EvictionPolicy):
    """Least-frequently-used with frequency buckets; ties broken by
    admission order within a bucket (oldest evicted first).  `on_admit` /
    `on_access` are O(1); `victim` scans the *occupied* buckets (at most
    one per cached object, so bounded by cache occupancy, never by how hot
    an object got)."""

    name = "lfu"

    def __init__(self):
        self._freq: dict[str, int] = {}
        self._buckets: dict[int, dict[str, None]] = {}

    def _bump(self, name: str, to: int) -> None:
        self._freq[name] = to
        self._buckets.setdefault(to, {})[name] = None

    def on_admit(self, obj: DataObject) -> None:
        self._bump(obj.name, 1)

    def on_access(self, obj: DataObject) -> None:
        f = self._freq[obj.name]
        bucket = self._buckets[f]
        del bucket[obj.name]
        if not bucket:
            del self._buckets[f]
        self._bump(obj.name, f + 1)

    def on_evict(self, obj: DataObject) -> None:
        f = self._freq.pop(obj.name, None)
        if f is None:
            return
        bucket = self._buckets.get(f)
        if bucket is not None:
            bucket.pop(obj.name, None)
            if not bucket:
                del self._buckets[f]

    def victim(self, cache: "ExecutorCache") -> Optional[str]:
        for f in sorted(self._buckets):
            for name in self._buckets[f]:
                if not cache.pinned(name):
                    return name
        return None


class SizeAwarePolicy(EvictionPolicy):
    """Evict the largest object first (frees the most room per eviction;
    favors keeping many small hot files over one cold archive).  Implemented
    as a max-heap with lazy invalidation — stale entries (already-evicted
    names) are dropped when popped."""

    name = "size"

    def __init__(self):
        import heapq
        self._heapq = heapq
        self._heap: list[tuple[float, int, str]] = []
        self._seq = itertools.count()
        self._live: set[str] = set()

    def on_admit(self, obj: DataObject) -> None:
        self._live.add(obj.name)
        self._heapq.heappush(self._heap, (-obj.size, next(self._seq),
                                          obj.name))

    def on_access(self, obj: DataObject) -> None:
        pass                            # size order is access-independent

    def on_evict(self, obj: DataObject) -> None:
        self._live.discard(obj.name)    # heap entry dropped lazily

    def victim(self, cache: "ExecutorCache") -> Optional[str]:
        heap = self._heap
        skipped = []
        found = None
        while heap:
            entry = self._heapq.heappop(heap)
            name = entry[2]
            if name not in self._live:
                continue                # stale: evicted or superseded
            if cache.pinned(name):
                skipped.append(entry)   # deferred: in use by a running task
                continue
            found = name
            skipped.append(entry)       # re-push; ExecutorCache will call
            break                       # on_evict to invalidate it
        for entry in skipped:
            self._heapq.heappush(heap, entry)
        return found


POLICIES = {"lru": LRUPolicy, "lfu": LFUPolicy, "size": SizeAwarePolicy}


def make_policy(policy) -> EvictionPolicy:
    if isinstance(policy, EvictionPolicy):
        return policy
    if callable(policy):
        return policy()
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown eviction policy {policy!r}; "
                         f"expected one of {sorted(POLICIES)}") from None


# ---------------------------------------------------------------------------
# executor-local cache
# ---------------------------------------------------------------------------

class ExecutorCache:
    """Fixed-capacity (bytes) cache of `DataObject`s on one executor.

    Invariants (tested in tests/test_data_diffusion.py):
      * used bytes never exceed `capacity`;
      * pinned (in-use) objects are never evicted — eviction is deferred to
        the next admission after they are unpinned;
      * an object larger than the whole cache is never admitted (the read
        still happens, the bytes just are not retained).
    """

    def __init__(self, capacity: float, policy="lru"):
        self.capacity = float(capacity)
        self.policy = make_policy(policy)
        self.objects: dict[str, DataObject] = {}
        # real cached bytes (DESIGN.md §10): filled by measured staging on
        # the real execution path, keyed like `objects`; empty on the
        # simulated path.  Bounded by `capacity` because admission is.
        self.data: dict[str, bytes] = {}
        self.used = 0.0
        self.evictions = 0
        self._pins: dict[str, int] = {}
        self._pinned_bytes = 0.0

    def __contains__(self, name: str) -> bool:
        return name in self.objects

    def contains(self, name: str) -> bool:
        return name in self.objects

    def touch(self, name: str) -> None:
        self.policy.on_access(self.objects[name])

    def pinned(self, name: str) -> bool:
        return name in self._pins

    def pin(self, name: str) -> None:
        obj = self.objects.get(name)
        if obj is None:
            return
        n = self._pins.get(name, 0)
        if n == 0:
            self._pinned_bytes += obj.size
        self._pins[name] = n + 1

    def unpin(self, name: str) -> None:
        n = self._pins.get(name)
        if n is None:
            return
        if n <= 1:
            del self._pins[name]
            obj = self.objects.get(name)
            if obj is not None:
                self._pinned_bytes -= obj.size
        else:
            self._pins[name] = n - 1

    def admit(self, obj: DataObject) -> tuple[bool, list[DataObject]]:
        """Try to cache `obj`; returns (admitted, evicted objects).

        Evicts per policy until there is room; if pinned objects leave too
        little evictable space, the object is simply not retained (cache
        bypass) — capacity is never exceeded.
        """
        if obj.name in self.objects:
            self.touch(obj.name)
            return True, []
        # feasibility first: pinned bytes are not evictable, so an object
        # that cannot fit beside them is bypassed *without* gutting the
        # cache of evictable-but-useful replicas.  A zero-capacity cache
        # retains nothing — including zero-size objects — so the GPFS-only
        # baseline stays exactly locality-blind.
        if self.capacity <= 0 or obj.size > self.capacity - self._pinned_bytes:
            return False, []
        evicted: list[DataObject] = []
        while self.used + obj.size > self.capacity:
            name = self.policy.victim(self)
            if name is None:            # defensive; feasibility checked above
                return False, evicted
            evicted.append(self._evict(name))
        self.objects[obj.name] = obj
        self.used += obj.size
        self.policy.on_admit(obj)
        return True, evicted

    def _evict(self, name: str) -> DataObject:
        obj = self.objects.pop(name)
        self.data.pop(name, None)
        self.used -= obj.size
        self.evictions += 1
        self.policy.on_evict(obj)
        return obj


# ---------------------------------------------------------------------------
# the data layer
# ---------------------------------------------------------------------------

class ShardDirectory:
    """Cross-shard holder directory (DESIGN.md §8): object name -> set of
    shard ids with at least one executor caching it.

    This is the *small* half of the sharded holder index: per-executor
    holder maps stay inside each shard's `DataLayer` (they are the hot,
    per-dispatch structure), while the directory answers the federation's
    coarse question — "does shard S hold object X at all?" — in O(1) for
    steal-time restage pricing.  Entries are maintained at shard
    granularity (first holder in a shard adds it, last holder drops it),
    so the directory is bounded by live objects x shards, independent of
    executor count and task count.
    """

    def __init__(self, on_change=None):
        self._map: dict[str, set[int]] = {}
        # cross-process propagation hook (DESIGN.md §14): in a
        # process-per-shard federation each shard's directory is local, so
        # membership changes must travel as messages instead of
        # shared-memory mutation — `on_change("add"|"drop", name, shard)`
        # fires on every first-holder add / last-holder drop and the shard
        # host batches the deltas to the parent's replica.  None (the
        # in-process default) keeps add/drop allocation-free.
        self.on_change = on_change

    def add(self, name: str, shard: int | None) -> None:
        shards = self._map.get(name)
        if shards is None:
            self._map[name] = shards = set()
        if shard not in shards:
            shards.add(shard)
            if self.on_change is not None:
                self.on_change("add", name, shard)

    def drop(self, name: str, shard: int | None) -> None:
        shards = self._map.get(name)
        if shards is not None and shard in shards:
            shards.discard(shard)
            if not shards:
                del self._map[name]
            if self.on_change is not None:
                self.on_change("drop", name, shard)

    def shards_holding(self, name: str) -> frozenset:
        return frozenset(self._map.get(name, ()))

    def holds(self, name: str, shard: int | None) -> bool:
        shards = self._map.get(name)
        return shards is not None and shard in shards

    def __len__(self):
        return len(self._map)


class DataLayer:
    """Cache-aware data management bound to one Falkon service.

    Owns the shared store, the staging cost model, one `ExecutorCache` per
    registered executor, and the *holder index* `object name -> {executor id
    -> executor}` used by cache-aware dispatch.  The index lets the service
    answer "is any idle executor already holding this task's inputs?" in
    O(inputs x probe_limit): for each input it probes at most `probe_limit`
    holders instead of intersecting with the full idle pool.
    """

    def __init__(self, shared: SharedStore | None = None,
                 cost: StagingCostModel | None = None,
                 cache_capacity: float = 1e9, policy="lru",
                 probe_limit: int = 8, affinity_frac: float = 0.5,
                 max_local_queue: int = 128, park_patience: float = 96.0):
        self.shared = shared or SharedStore()
        # holder-index sharding (DESIGN.md §8): when this layer is one
        # shard of a `ShardedDataLayer`, `directory` is the federation's
        # cross-shard directory and `shard_id` this layer's shard; the
        # directory tracks only *which shards* hold an object (first
        # holder appears / last holder drops), so it stays small while the
        # per-executor holder maps stay shard-local
        self.shard_id: int | None = None
        self.directory = None
        self.cost = cost or StagingCostModel()
        self.cache_capacity = float(cache_capacity)
        self.policy = policy
        self.probe_limit = probe_limit
        # affinity routing (DESIGN.md §7): a task waits behind a *busy*
        # holder only when that holder covers at least `affinity_frac` of
        # its input bytes, the holder's local queue is shorter than
        # `max_local_queue`, and the work already parked there is within
        # `park_patience x` the estimated staging cost of going cold.
        # Otherwise it spills to an idle holder/executor (staging a
        # replica) or waits in the global queue for capacity.  The
        # patience term keeps compute-heavy tasks (staging cheap relative
        # to their runtime) from serializing a wide fan-out behind one
        # holder while the rest of the pool idles; data-heavy tasks still
        # queue deep and keep their bytes local.
        self.affinity_frac = affinity_frac
        self.max_local_queue = max_local_queue
        self.park_patience = park_patience
        # observability (DESIGN.md §12): set to a `Tracer` to emit one
        # `stage_bytes` event per dispatch that staged cold bytes
        self.tracer = None
        self._holders: dict[str, dict[int, "Executor"]] = {}
        # bounded metrics (DESIGN.md §4): counters + StreamStat reservoirs
        self.hits = 0
        self.misses = 0
        self.bytes_local = 0.0
        self.bytes_staged = 0.0
        self.staged_stat = StreamStat(cap=512)   # staged bytes per dispatch
        self.hit_stat = StreamStat(cap=512)      # hit fraction per dispatch
        # real path only (DESIGN.md §10): measured staging seconds per task
        self.measured_io_stat = StreamStat(cap=512)

    # -- executor lifecycle --------------------------------------------------
    def register_executor(self, e: "Executor") -> None:
        e.cache = ExecutorCache(self.cache_capacity, self.policy)

    def deregister_executor(self, e: "Executor") -> None:
        cache = e.cache
        if cache is None:
            return
        for name in cache.objects:
            self._drop_holder(name, e)
        e.cache = None

    # -- holder-index queries -------------------------------------------------
    def holds(self, name: str) -> bool:
        """True when at least one registered executor caches `name` —
        O(1); used by the balancer's affinity term and by cross-shard
        restage accounting."""
        return name in self._holders

    # -- cache-aware placement ----------------------------------------------
    def pick_home(self, task, now: float):
        """Routing decision for one task, via the holder index.

        Returns ``(executor, run_now)``: with ``run_now`` True the executor
        is idle and should run the task immediately; with False it is a busy
        holder worth waiting behind (append to its local queue).  Returns
        ``(None, False)`` when no holder is attractive — the caller falls
        back to locality-blind first-idle dispatch, or leaves the task at
        the head of the global queue where any executor that frees (or
        arrives via DRP growth) can take it.

        Parking is bounded by the wait-vs-stage test unconditionally — not
        just when an idle executor is visible right now — because refusing
        commits nothing: the task simply stays in the global queue while
        capacity frees or grows.

        Cost is O(inputs x probe_limit): for each input at most
        `probe_limit` holders are probed, and each probe's byte-coverage
        scan is O(inputs) (input tuples are small).
        """
        inputs = task.inputs
        total = 0.0
        for o in inputs:
            total += o.size
        best_idle = best_busy = None
        idle_bytes = busy_bytes = 0.0
        busy_qlen = 0
        seen: set = set()
        for obj in inputs:
            holders = self._holders.get(obj.name)
            if not holders:
                continue
            # probe order is holder-registration order and bounded by
            # probe_limit per input — holders past the bound are invisible
            # to this decision by design (the bound is what keeps routing
            # O(inputs)); `seen` skips re-scoring an executor that holds
            # several of the task's inputs
            probes = 0
            for e in holders.values():
                if probes >= self.probe_limit:
                    break
                probes += 1
                if e.id in seen:
                    continue
                seen.add(e.id)
                if now < e.suspended_until or e.cache is None:
                    continue
                covered = sum(o.size for o in inputs
                              if o.name in e.cache.objects)
                if e.busy:
                    qlen = len(e.local_q)
                    if qlen < self.max_local_queue and (
                            covered > busy_bytes or
                            (covered == busy_bytes and best_busy is not None
                             and qlen < busy_qlen)):
                        best_busy, busy_bytes, busy_qlen = e, covered, qlen
                elif covered > idle_bytes:
                    best_idle, idle_bytes = e, covered
        if best_idle is not None and idle_bytes >= busy_bytes:
            return best_idle, True
        if best_busy is not None and busy_bytes >= self.affinity_frac * total:
            # wait-vs-stage: parking serializes behind the holder, so it is
            # only worth it while the wait stays comparable to re-staging
            # the inputs cold elsewhere
            stage_est = self.cost.shared_read_time(total,
                                                   self.shared.readers + 1)
            if best_busy.local_work <= self.park_patience * stage_est:
                return best_busy, False
        if best_idle is not None:
            return best_idle, True
        return None, False

    # -- staging -------------------------------------------------------------
    def stage_inputs(self, e: "Executor", task, clock: "Clock") -> float:
        """Price the task's input reads on executor `e`, update its cache and
        the holder index, and pin inputs for the run; returns the total I/O
        time to add to the task's service time.

        Contention approximation: a task's own reads are serial, so read k
        is priced against *external* readers only (its own earlier reads
        have finished by the time it starts) and each read's release event
        fires at its serialized end, not at the dispatch instant.  External
        windows still all open at dispatch time — exact interleaving would
        need one extra event per read start, which the miss path does not
        pay.
        """
        cache = e.cache
        io = 0.0
        hits = misses = 0
        staged = 0.0
        own_open = 0
        stage_end = 0.0                 # cumulative serialized staging time
        for obj in task.inputs:
            if cache is not None and obj.name in cache.objects:
                cache.touch(obj.name)
                hits += 1
                self.bytes_local += obj.size
                io += self.cost.local_read_time(obj.size)
            else:
                misses += 1
                staged += obj.size
                shared = self.shared
                shared._begin_read(obj.size)
                own_open += 1
                t = self.cost.shared_read_time(
                    obj.size, shared.readers - own_open + 1)
                stage_end += t
                clock.schedule(stage_end, shared._end_read)
                io += t
                if cache is not None:
                    admitted, evicted = cache.admit(obj)
                    if admitted:
                        holders = self._holders.get(obj.name)
                        if holders is None:
                            self._holders[obj.name] = holders = {}
                            if self.directory is not None:
                                self.directory.add(obj.name, self.shard_id)
                        holders[e.id] = e
                    for ev in evicted:
                        self._drop_holder(ev.name, e)
            if cache is not None:
                cache.pin(obj.name)
        self.hits += hits
        self.misses += misses
        self.bytes_staged += staged
        now = clock.now()
        self.staged_stat.observe(now, staged)
        n = hits + misses
        if n:
            self.hit_stat.observe(now, hits / n)
        if staged and self.tracer is not None:
            self.tracer.event("stage_bytes", now, staged)
        return io

    # -- measured staging (real execution path, DESIGN.md §10) ---------------
    def plan_staging(self, e: "Executor", task) -> "_StagePlan":
        """Clock-thread half of *measured* staging: identical cache, holder
        index, pin, and byte accounting to `stage_inputs`, but instead of
        pricing the reads it returns a `_StagePlan` — a callable the worker
        pool runs inside the task's service time to perform the real byte
        copies (shared-store payload -> executor cache for misses, cache ->
        local read for hits).  `end_staging` closes the books when the
        measured completion comes back.

        The worker touches only the plan's copy list and `cache.data` (its
        own pinned keys — never evicted mid-run, so no clock-thread
        conflict); all index/metric state stays on the clock thread.
        """
        cache = e.cache
        copies: list = []
        hits = misses = 0
        staged = 0.0
        open_reads = 0
        for obj in task.inputs:
            if cache is not None and obj.name in cache.objects:
                cache.touch(obj.name)
                hits += 1
                self.bytes_local += obj.size
                copies.append((obj, cache, False))
            else:
                misses += 1
                staged += obj.size
                self.shared._begin_read(obj.size)
                open_reads += 1
                admitted = False
                if cache is not None:
                    admitted, evicted = cache.admit(obj)
                    if admitted:
                        holders = self._holders.get(obj.name)
                        if holders is None:
                            self._holders[obj.name] = holders = {}
                            if self.directory is not None:
                                self.directory.add(obj.name, self.shard_id)
                        holders[e.id] = e
                    for ev in evicted:
                        self._drop_holder(ev.name, e)
                copies.append((obj, cache if admitted else None, True))
            if cache is not None:
                cache.pin(obj.name)
        self.hits += hits
        self.misses += misses
        self.bytes_staged += staged
        return _StagePlan(self.shared, copies, open_reads, hits, misses,
                          staged)

    def end_staging(self, plan: "_StagePlan", io_s: float,
                    now: float) -> None:
        """Close a `plan_staging` plan on the clock thread: release the
        shared-store reader slots the plan's misses held for the duration
        of the real copies, and record the plan's byte/hit stats plus the
        *measured* staging seconds."""
        for _ in range(plan.open_reads):
            self.shared._end_read()
        self.staged_stat.observe(now, plan.staged)
        n = plan.hits + plan.misses
        if n:
            self.hit_stat.observe(now, plan.hits / n)
        self.measured_io_stat.observe(now, io_s)
        if plan.staged and self.tracer is not None:
            self.tracer.event("stage_bytes", now, plan.staged)

    def release_inputs(self, e: "Executor", task) -> None:
        cache = e.cache
        if cache is None:
            return
        for obj in task.inputs:
            cache.unpin(obj.name)

    def _drop_holder(self, name: str, e: "Executor") -> None:
        holders = self._holders.get(name)
        if holders is not None:
            holders.pop(e.id, None)
            if not holders:
                del self._holders[name]
                if self.directory is not None:
                    self.directory.drop(name, self.shard_id)

    # -- metrics -------------------------------------------------------------
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def metrics(self) -> dict:
        """Bounded snapshot — safe at any task count."""
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate(),
            "bytes_local": self.bytes_local,
            "bytes_staged": self.bytes_staged,
            "staged_per_task": self.staged_stat.summary(),
            "hit_fraction": self.hit_stat.summary(),
            "shared_reads": self.shared.reads,
            "shared_bytes": self.shared.bytes_read,
            "indexed_objects": len(self._holders),
            "measured_io_s": self.measured_io_stat.summary(),
        }


class _StagePlan:
    """One task's worth of real staging copies (DESIGN.md §10).

    Built by `DataLayer.plan_staging` on the clock thread; called by a
    worker inside the task's measured service time.  A miss materializes
    the shared store's payload (stored bytes, or synthesized zeros for
    size-only objects) and retains it in `cache.data` when the object was
    admitted; a hit copies out of the executor's cache (the local read).
    """

    __slots__ = ("shared", "copies", "open_reads", "hits", "misses",
                 "staged")

    def __init__(self, shared: SharedStore, copies: list, open_reads: int,
                 hits: int, misses: int, staged: float):
        self.shared = shared
        self.copies = copies
        self.open_reads = open_reads
        self.hits = hits
        self.misses = misses
        self.staged = staged

    def __call__(self) -> None:
        for obj, cache, is_miss in self.copies:
            if is_miss:
                src = self.shared.payload(obj)
                # the shared-store read: copy the payload (or synthesize a
                # zero-filled buffer of the declared size — an equivalent
                # allocation+fill)
                data = bytes(bytearray(src)) if src is not None \
                    else bytes(int(obj.size))
                if cache is not None:       # admitted on the clock thread
                    cache.data[obj.name] = data
            else:
                src = cache.data.get(obj.name)
                if src is None:
                    # cache-resident from a sim run or seeded by size only:
                    # materialize once so later local reads copy real bytes
                    src = cache.data[obj.name] = bytes(int(obj.size))
                bytearray(src)              # the local read: one real copy


def inputs_of(spec, *args) -> tuple:
    """Normalize an input declaration: a `DataObject`, an iterable of them,
    or a callable mapping call args -> either."""
    if spec is None:
        return ()
    if callable(spec) and not isinstance(spec, DataObject):
        spec = spec(*args)
        if spec is None:
            return ()
    if isinstance(spec, DataObject):
        return (spec,)
    return tuple(spec)
