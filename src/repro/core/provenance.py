"""Kickstart-style provenance records (paper §3.14).

Every task invocation produces an *invocation document* capturing arguments,
host, timings, exit status and retry lineage; records are stored in a
queryable in-memory VDC (virtual data catalog) with optional JSONL
persistence.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
from collections import deque
from typing import Any


@dataclasses.dataclass
class InvocationRecord:
    task_id: str
    name: str
    site: str
    host: str
    submit_time: float
    start_time: float
    end_time: float
    exit_status: str            # ok | failed | retried
    attempt: int
    args_repr: str
    outputs: list[str]
    error: str = ""
    # tracer span id (DESIGN.md §12): links this invocation document to its
    # lifecycle span in the run's trace; "" when tracing is off or the task
    # fell outside the sampling stride
    span_id: str = ""

    @property
    def queue_time(self) -> float:
        return self.start_time - self.submit_time

    @property
    def run_time(self) -> float:
        return self.end_time - self.start_time


class VDC:
    """Virtual data catalog: invocation records + produced-dataset registry.

    Aggregate counters (invocations / ok / queue and run time) are always
    maintained, so `summary()` stays exact even when per-invocation records
    are bounded (``max_records=N`` keeps only the N most recent) or skipped
    entirely (engine ``provenance="summary"`` calls `tally` instead of
    `record`) — the memory-bounded configuration for 10^6-task runs.
    """

    def __init__(self, path: str | None = None,
                 max_records: int | None = None):
        self.records = [] if max_records is None \
            else deque(maxlen=max_records)
        self.datasets: dict[str, dict] = {}
        self.path = path
        self.host = socket.gethostname()
        self._invocations = 0
        self._ok = 0
        self._queue_time = 0.0
        self._run_time = 0.0

    def tally(self, ok: bool, queue_time: float = 0.0,
              run_time: float = 0.0) -> None:
        """Count an invocation without materializing a record."""
        self._invocations += 1
        if ok:
            self._ok += 1
        self._queue_time += queue_time
        self._run_time += run_time

    def record(self, rec: InvocationRecord) -> None:
        self.tally(rec.exit_status == "ok", rec.queue_time, rec.run_time)
        self.records.append(rec)
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(dataclasses.asdict(rec)) + "\n")

    def register_dataset(self, name: str, producer: str, meta: dict) -> None:
        self.datasets[name] = {"producer": producer, **meta}

    # -- persistence ---------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        """Write the retained records (one JSON object per line, the same
        shape the ``path=`` append stream produces) plus a trailing
        ``{"_datasets": ...}`` line carrying the dataset registry.
        Returns the number of invocation records written."""
        n = 0
        with open(path, "w", encoding="utf-8") as f:
            for rec in self.records:
                f.write(json.dumps(dataclasses.asdict(rec)) + "\n")
                n += 1
            if self.datasets:
                f.write(json.dumps({"_datasets": self.datasets}) + "\n")
        return n

    @classmethod
    def load_jsonl(cls, path: str,
                   max_records: int | None = None) -> "VDC":
        """Rebuild a VDC from an `export_jsonl` file (or a ``path=`` append
        stream): records are replayed through `record`, so the aggregate
        counters and `summary()` come back exact."""
        vdc = cls(max_records=max_records)
        fields = {f.name for f in dataclasses.fields(InvocationRecord)}
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                if "_datasets" in obj:
                    vdc.datasets.update(obj["_datasets"])
                    continue
                # tolerate records written by older schemas
                vdc.record(InvocationRecord(
                    **{k: v for k, v in obj.items() if k in fields}))
        return vdc

    # -- queries (paper: "powerful exploration and expressive query") -------
    def by_task(self, name: str) -> list[InvocationRecord]:
        return [r for r in self.records if r.name == name]

    def failures(self) -> list[InvocationRecord]:
        return [r for r in self.records if r.exit_status != "ok"]

    def derivation(self, dataset: str) -> dict:
        """Trace how a dataset was derived (producer chain)."""
        chain = []
        cur = dataset
        seen = set()
        while cur in self.datasets and cur not in seen:
            seen.add(cur)
            info = self.datasets[cur]
            chain.append({"dataset": cur, **info})
            cur = info.get("derived_from", "")
        return {"dataset": dataset, "chain": chain}

    def summary(self) -> dict:
        return {
            "invocations": self._invocations,
            "ok": self._ok,
            "failed": self._invocations - self._ok,
            "total_queue_time": self._queue_time,
            "total_run_time": self._run_time,
        }
