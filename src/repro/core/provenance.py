"""Kickstart-style provenance records (paper §3.14).

Every task invocation produces an *invocation document* capturing arguments,
host, timings, exit status and retry lineage; records are stored in a
queryable in-memory VDC (virtual data catalog) with optional JSONL
persistence.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
from typing import Any


@dataclasses.dataclass
class InvocationRecord:
    task_id: str
    name: str
    site: str
    host: str
    submit_time: float
    start_time: float
    end_time: float
    exit_status: str            # ok | failed | retried
    attempt: int
    args_repr: str
    outputs: list[str]
    error: str = ""

    @property
    def queue_time(self) -> float:
        return self.start_time - self.submit_time

    @property
    def run_time(self) -> float:
        return self.end_time - self.start_time


class VDC:
    """Virtual data catalog: invocation records + produced-dataset registry."""

    def __init__(self, path: str | None = None):
        self.records: list[InvocationRecord] = []
        self.datasets: dict[str, dict] = {}
        self.path = path
        self.host = socket.gethostname()

    def record(self, rec: InvocationRecord) -> None:
        self.records.append(rec)
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(dataclasses.asdict(rec)) + "\n")

    def register_dataset(self, name: str, producer: str, meta: dict) -> None:
        self.datasets[name] = {"producer": producer, **meta}

    # -- queries (paper: "powerful exploration and expressive query") -------
    def by_task(self, name: str) -> list[InvocationRecord]:
        return [r for r in self.records if r.name == name]

    def failures(self) -> list[InvocationRecord]:
        return [r for r in self.records if r.exit_status != "ok"]

    def derivation(self, dataset: str) -> dict:
        """Trace how a dataset was derived (producer chain)."""
        chain = []
        cur = dataset
        seen = set()
        while cur in self.datasets and cur not in seen:
            seen.add(cur)
            info = self.datasets[cur]
            chain.append({"dataset": cur, **info})
            cur = info.get("derived_from", "")
        return {"dataset": dataset, "chain": chain}

    def summary(self) -> dict:
        ok = [r for r in self.records if r.exit_status == "ok"]
        return {
            "invocations": len(self.records),
            "ok": len(ok),
            "failed": len(self.records) - len(ok),
            "total_queue_time": sum(r.queue_time for r in self.records),
            "total_run_time": sum(r.run_time for r in self.records),
        }
