"""Unified task-lifecycle tracing, metrics registry, and run reports
(DESIGN.md §12).

Every figure in the paper — dispatch throughput (Fig 6), pipelining
(Fig 10), executor timelines (Fig 18) — is a view over per-task lifecycle
events, and the Kickstart/VDC provenance layer (§3.14) exists because
"reliable at scale" means knowing where each of a million tasks spent its
time.  This module is the one place that question is answered from:

  * `Tracer`          — bounded, deterministic per-task lifecycle spans
                        (submit -> ready -> queued -> staged -> running ->
                        done/failed/retried) plus component events (DRP
                        allocations, affinity redirects, mailbox flushes,
                        steals, bundle fusions).  Sampling keeps every k-th
                        task; the span store and every event log decimate
                        deterministically (drop every other entry, double
                        the stride — the `StreamStat` scheme, no RNG), so a
                        10^6-task run stays memory-bounded and two
                        `SimClock` runs of the same workflow produce
                        byte-identical span streams.
  * `MetricsRegistry` — aggregates every component's named metrics
                        (`FalkonService.metrics`, `DataLayer.metrics`,
                        pool/federation snapshots, bare `StreamStat`s)
                        into one JSON-able `snapshot()`.
  * `Tracer.export_chrome_trace` — Chrome trace-event / Perfetto JSON:
                        one process per site/shard, one thread track per
                        worker host, counter tracks for named logs
                        (queue length), instant events for component
                        events.
  * `RunReport`       — post-run analysis: critical-path length,
                        per-stage time breakdown, queue-wait / stage-wait
                        / run-time percentiles, per-site utilization
                        timeline.  `benchmarks/common.py` emits it as the
                        standard report schema; `tools/trace_view.py`
                        renders it (and validates chrome traces) from the
                        command line.

Hot-path contract: with no tracer attached every hook is a single
`is not None` test.  With a tracer attached, a *non-sampled* task costs
one counter increment plus the O(1) critical-path update at completion;
only every k-th task materializes a `Span` and touches the reservoirs.
All timestamps are passed in from the caller's clock — the tracer never
reads the wall clock and uses no RNG, so traces replay exactly under
`SimClock`.
"""
from __future__ import annotations

import json
from typing import Any, Callable, Optional

from repro.core.health import RollingStat
from repro.core.metrics import StreamStat, percentile_of

__all__ = [
    "BoundedLog", "Span", "Tracer", "MetricsRegistry", "RunReport",
    "build_report",
]


class BoundedLog:
    """Append-only event log with bounded, deterministic decimation.

    The raw-trace analog of `StreamStat`'s reservoir: entries are kept
    every `stride`-th append, and when the kept list reaches `cap` every
    other entry is dropped (the first stays anchored) and the stride
    doubles — memory is bounded by `cap` for any run length, decimation is
    reproducible (no RNG), and `count` stays exact.  Used for the Falkon
    trace logs (`queue_len_log`, `alloc_log`, per-executor `task_log`),
    component event streams, and executor span tracks.
    """

    __slots__ = ("cap", "count", "entries", "_stride", "_skip")

    def __init__(self, cap: int = 1024):
        if cap < 2:
            raise ValueError("cap must be >= 2")
        self.cap = cap
        self.count = 0              # total appended (exact)
        self.entries: list = []     # kept subset, append order
        self._stride = 1
        self._skip = 0

    def append(self, entry) -> None:
        self.count += 1
        if self._skip:
            self._skip -= 1
            return
        self.entries.append(entry)
        if len(self.entries) >= self.cap:
            del self.entries[1::2]
            self._stride *= 2
        self._skip = self._stride - 1

    @property
    def stride(self) -> int:
        return self._stride

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self):
        return iter(self.entries)

    def __getitem__(self, i):
        return self.entries[i]

    def __eq__(self, other):
        if isinstance(other, BoundedLog):
            return self.entries == other.entries
        return self.entries == other

    def __repr__(self):
        return (f"<BoundedLog n={self.count} kept={len(self.entries)} "
                f"stride={self._stride}>")


class Span:
    """One sampled task's lifecycle record.

    Timestamps are clock seconds (virtual under `SimClock`, wall under
    `RealClock`): `created` (submitted to the engine), `ready` (argument
    futures resolved; equals `created` for dependency-free tasks),
    `submitted` (handed to a site provider), `started` (body begins,
    after dispatch overhead + staging), `ended` (completion observed).
    `io_s` is the staging (stage-wait) time, `weight` the number of tasks
    this sampled span statistically represents (the sampling stride at
    creation), `shard` the federation shard (None outside a federation).
    """

    __slots__ = ("span_id", "name", "app", "shard", "site", "host",
                 "status", "attempt", "weight", "created", "ready",
                 "submitted", "started", "ended", "io_s")

    def __init__(self, span_id: str, name: str, app: str | None,
                 shard: int | None, created: float, weight: int):
        self.span_id = span_id
        self.name = name
        self.app = app
        self.shard = shard
        self.site = ""
        self.host = ""
        self.status = ""
        self.attempt = 0
        self.weight = weight
        self.created = created
        self.ready = created
        self.submitted = 0.0
        self.started = 0.0
        self.ended = 0.0
        self.io_s = 0.0

    def queue_wait(self) -> float:
        """Seconds between provider hand-off and body start (dispatch
        overhead + executor queueing + staging)."""
        return max(0.0, self.started - self.submitted)

    def to_dict(self) -> dict:
        return {
            "span_id": self.span_id, "name": self.name, "app": self.app,
            "shard": self.shard, "site": self.site, "host": self.host,
            "status": self.status, "attempt": self.attempt,
            "weight": self.weight, "created": self.created,
            "ready": self.ready, "submitted": self.submitted,
            "started": self.started, "ended": self.ended,
            "io_s": self.io_s,
        }

    def __repr__(self):
        return (f"<Span {self.span_id} {self.name} {self.status} "
                f"[{self.started:.3f},{self.ended:.3f}]>")


class Tracer:
    """Bounded, deterministic recorder of task spans and component events.

    Construct once per run and hand the same instance to every component
    (`Engine(tracer=...)`, `FalkonService(tracer=...)`,
    `FederatedEngine(tracer=...)`, pools, data layers) — all components
    share one clock thread, so no locking is needed and event order is the
    clock's deterministic event order.

    Sampling: every `sample_every`-th submitted task gets a `Span`
    (`sample_every=1` records all).  When the closed-span store reaches
    `max_spans` it decimates — drop every other span, double the effective
    stride — so memory is bounded for any task count while early and late
    tasks both stay represented.  Exact (never sampled): task outcome
    counters, the critical-path length, and each component's own
    `StreamStat` aggregates (read via `MetricsRegistry`).

    Example::

        tracer = Tracer(sample_every=16)
        eng = Engine(clock, tracer=tracer)
        svc = FalkonService(clock, cfg, tracer=tracer)
        ... run ...
        tracer.export_chrome_trace("trace.json")     # chrome://tracing
        report = build_report(tracer, makespan=eng.clock.now())
    """

    def __init__(self, sample_every: int = 1, max_spans: int = 4096,
                 event_cap: int = 1024, log_cap: int = 2048,
                 rate_window: float = 60.0, rate_buckets: int = 12):
        if sample_every < 1:
            raise ValueError("sample_every must be >= 1")
        if max_spans < 2:
            raise ValueError("max_spans must be >= 2")
        self.sample_every = sample_every
        self.max_spans = max_spans
        self.event_cap = event_cap
        self.log_cap = log_cap
        # windowed per-kind event rates (DESIGN.md §13): `event_counts`
        # only gives cumulative totals, so two snapshots had to be diffed
        # by hand to see a rate; each kind also feeds a `RollingStat`
        self.rate_window = rate_window
        self.rate_buckets = rate_buckets
        self._event_rates: dict[str, RollingStat] = {}
        self._last_event_t = 0.0
        # event-stream subscribers (`subscribe`): called per event as
        # fn(kind, t, value).  Tuple, not list — the hot path iterates it
        # and the empty-tuple check is one truthiness test.
        self._subs: tuple = ()
        # exact counters (every task, sampled or not)
        self.tasks_seen = 0
        self.tasks_done = 0
        self.tasks_failed = 0
        self.tasks_retried = 0
        self.critical_path_s = 0.0
        # sampled state
        self._stride = 1             # doubles when the span store decimates
        self._k = sample_every       # sample_every * _stride, kept in sync
        self.spans: list[Span] = []  # closed sampled spans
        self._open_spans = 0
        # exact sum of closed-span weights (~= tasks the sampled spans
        # stand for).  Store decimation drops spans but not this counter,
        # so readers rescale kept-set estimates by
        # span_weight_total / sum(kept weights) — survivor weights are NOT
        # doubled on decimation (under mixed strides that compounds on the
        # always-kept head and explodes the estimate)
        self.span_weight_total = 0.0
        # per-stage aggregates, accumulated from sampled spans with their
        # weights: name -> [weighted count, run_s, queue_s, io_s]
        self.stage_cap = 512
        self._stages: dict[str, list] = {}
        # component events: kind -> exact [count, value_total] + bounded log
        self._event_agg: dict[str, list] = {}
        self.events: dict[str, BoundedLog] = {}
        # named gauges: last-value-wins scalars for state that is a level,
        # not a stream — recovery counters (``tasks_restored``,
        # ``recovery_wall_s``), journal depth, etc. (DESIGN.md §15)
        self.gauges: dict[str, float] = {}
        # executor occupancy track: (site, host, start, end, name)
        self.exec_spans = BoundedLog(cap=max(log_cap, 2))
        # named raw-series logs (Falkon queue length / allocations live
        # here when the service runs with trace=True)
        self.logs: dict[str, BoundedLog] = {}

    # -- named logs -----------------------------------------------------
    def log(self, name: str, cap: int | None = None) -> BoundedLog:
        """Get-or-create the named bounded log (e.g. ``falkon.queue_len``)."""
        lg = self.logs.get(name)
        if lg is None:
            self.logs[name] = lg = BoundedLog(cap or self.log_cap)
        return lg

    # -- task lifecycle (hot path) --------------------------------------
    def task_created(self, task, now: float,
                     shard: int | None = None) -> Optional[Span]:
        """Admit one submitted task; returns its `Span` if sampled (the
        caller stores it on ``task.span``), else None.  Deterministic:
        the decision is a counter modulus, never a coin flip."""
        self.tasks_seen += 1
        if (self.tasks_seen - 1) % self._k:
            return None
        return self._new_span(task, now, shard)

    def _new_span(self, task, now: float, shard: int | None) -> "Span":
        """Materialize the sampled-task span (the engine inlines the
        counter/modulus fast path and calls this only on a hit)."""
        span = Span(f"s{self.tasks_seen}", task.name, task.app, shard,
                    now, self._k)
        self._open_spans += 1
        return span

    def task_done(self, task, now: float, status: str = "ok") -> float:
        """Record a task outcome (engine completion path).  Updates exact
        counters and the critical path for *every* task; closes the span
        for sampled ones.  Returns the task's critical-path value (its
        dependency-chain latency), which the engine propagates onto the
        output future."""
        if status == "retried":
            self.tasks_retried += 1
            sp = getattr(task, "span", None)
            if sp is not None:
                sp.attempt = task.attempt + 1
            return 0.0
        if status == "ok":
            self.tasks_done += 1
        else:
            self.tasks_failed += 1
        # critical path: longest dependency chain of per-task latencies
        # (ready -> done); exact, O(1) per task (engine maintains
        # task.path0 = max over parent futures' path values)
        # the engine encodes (parent path - ready time) in path0; adding
        # `now` back yields the task's dependency-chain latency
        base = getattr(task, "path0", None)
        path = 0.0 if base is None else base + now
        if path > self.critical_path_s:
            self.critical_path_s = path
        sp = getattr(task, "span", None)
        if sp is not None:
            self._close_span(sp, task, now, status)
        return path

    def _close_span(self, sp: Span, task, now: float, status: str) -> None:
        sp.submitted = task.submit_time
        sp.started = task.start_time
        sp.ended = now
        sp.status = status
        sp.attempt = task.attempt
        site = task.site
        if site is not None:
            sp.site = site.name
        sp.host = task.host
        self._open_spans -= 1
        # weighted per-stage aggregate (estimates scale by span weight, so
        # they stay consistent across store decimations)
        st = self._stages.get(sp.name)
        if st is None:
            if len(self._stages) >= self.stage_cap:
                name = "<other>"
                st = self._stages.get(name)
                if st is None:
                    self._stages[name] = st = [0, 0.0, 0.0, 0.0]
            else:
                self._stages[sp.name] = st = [0, 0.0, 0.0, 0.0]
        w = sp.weight
        st[0] += w
        st[1] += w * (now - sp.started)
        st[2] += w * sp.queue_wait()
        st[3] += w * sp.io_s
        self.span_weight_total += w
        spans = self.spans
        spans.append(sp)
        if len(spans) >= self.max_spans:
            del spans[1::2]
            self._stride *= 2
            self._k = self.sample_every * self._stride

    # -- component events -----------------------------------------------
    def subscribe(self, fn: Callable[[str, float, float], None]) -> None:
        """Register an event-stream listener, called synchronously as
        ``fn(kind, t, value)`` for every `event` — the `HealthMonitor`
        subscribes here to fold component events into its windowed
        alerts.  Listeners must not block (they run on the clock thread)."""
        self._subs = self._subs + (fn,)

    def event(self, kind: str, t: float, value: float = 1.0) -> None:
        """Record one component event (``drp_alloc``, ``affinity_park``,
        ``mailbox_flush``, ``steal``, ``bundle_fused``, ``stage_bytes``,
        ...): exact count/total per kind, a bounded (t, value) log, and a
        rolling windowed rate (`event_rates`).  Subscribers see every
        event."""
        agg = self._event_agg.get(kind)
        if agg is None:
            self._event_agg[kind] = agg = [0, 0.0]
            self.events[kind] = BoundedLog(self.event_cap)
            self._event_rates[kind] = RollingStat(self.rate_window,
                                                  self.rate_buckets)
        agg[0] += 1
        agg[1] += value
        self.events[kind].append((t, value))
        self._event_rates[kind].observe(t, value)
        if t > self._last_event_t:
            self._last_event_t = t
        if self._subs:
            for fn in self._subs:
                fn(kind, t, value)

    def gauge(self, name: str, value: float) -> None:
        """Set a named gauge (last value wins).  Gauges appear in
        `snapshot()` and in the `RunReport` payload; on `merge_snapshot`
        they *sum* across processes (every current user is an additive
        count — restored tasks, journal rows)."""
        self.gauges[name] = float(value)

    def exec_span(self, site: str, host: str, start: float, end: float,
                  name: str = "") -> None:
        """Record one executor-occupancy interval (the Fig-18 / worker
        timeline data): bounded, one shared log across sites."""
        self.exec_spans.append((site, host, start, end, name))

    def merge_snapshot(self, snap: dict) -> None:
        """Fold another process's `snapshot()` into this tracer
        (DESIGN.md §14): exact task counters add, the critical path takes
        the max, and per-kind event aggregates accumulate, so a
        `RunReport` built from the merged tracer covers the whole
        process-per-shard federation.  Sampled spans and bounded event
        logs are process-local and are *not* reconstructed — percentile
        and timeline views stay per-process (each shard can export its
        own trace); the merged report's counters and event totals are
        exact."""
        self.tasks_seen += snap["tasks_seen"]
        self.tasks_done += snap["tasks_done"]
        self.tasks_failed += snap["tasks_failed"]
        self.tasks_retried += snap["tasks_retried"]
        if snap["critical_path_s"] > self.critical_path_s:
            self.critical_path_s = snap["critical_path_s"]
        for kind, d in snap.get("events", {}).items():
            agg = self._event_agg.get(kind)
            if agg is None:
                self._event_agg[kind] = agg = [0, 0.0]
                self.events[kind] = BoundedLog(self.event_cap)
                self._event_rates[kind] = RollingStat(self.rate_window,
                                                      self.rate_buckets)
            agg[0] += d["count"]
            agg[1] += d["total"]
        for name, v in snap.get("gauges", {}).items():
            self.gauges[name] = self.gauges.get(name, 0.0) + v

    # -- snapshots ------------------------------------------------------
    def event_counts(self) -> dict:
        return {k: {"count": a[0], "total": a[1]}
                for k, a in sorted(self._event_agg.items())}

    def event_rates(self, now: float | None = None) -> dict:
        """Windowed per-kind event rates over the trailing `rate_window`
        seconds (the satellite to `event_counts`' cumulative totals).
        `now` defaults to the newest event timestamp seen — callers with a
        clock should pass its now() so stale kinds decay to zero."""
        if now is None:
            now = self._last_event_t
        w = self.rate_window
        out = {}
        for kind in sorted(self._event_rates):
            rs = self._event_rates[kind]
            c = rs.count(now)
            out[kind] = {"window_s": w, "count": c,
                         "rate_per_s": c / w,
                         "value_per_s": rs.total(now) / w}
        return out

    def stage_breakdown(self) -> dict:
        """Per-stage estimated totals: task count, run seconds, queue-wait
        seconds, stage-wait (staging I/O) seconds.  Estimates are
        weighted sampled sums — exact when ``sample_every == 1`` and the
        span store never decimated."""
        return {
            name: {
                "count_est": st[0],
                "run_s_est": st[1],
                "run_s_mean": st[1] / st[0] if st[0] else 0.0,
                "queue_s_est": st[2],
                "queue_s_mean": st[2] / st[0] if st[0] else 0.0,
                "io_s_est": st[3],
            }
            for name, st in sorted(self._stages.items())
        }

    def snapshot(self) -> dict:
        """Bounded self-description — safe at any task count."""
        return {
            "tasks_seen": self.tasks_seen,
            "tasks_done": self.tasks_done,
            "tasks_failed": self.tasks_failed,
            "tasks_retried": self.tasks_retried,
            "critical_path_s": self.critical_path_s,
            "sampled_spans": len(self.spans),
            "open_spans": self._open_spans,
            "sample_stride": self.sample_every * self._stride,
            "events": self.event_counts(),
            "event_rates": self.event_rates(),
            "gauges": dict(self.gauges),
        }

    # -- chrome trace export --------------------------------------------
    def export_chrome_trace(self, path: str | None = None) -> dict:
        """Serialize to Chrome trace-event JSON (the format
        chrome://tracing and Perfetto load): one *process* per site (or
        federation shard), one *thread* track per worker host, complete
        ("X") events for task spans and executor occupancy, counter ("C")
        tracks for named logs, instant ("i") events for component events.
        Returns the trace dict; writes it to `path` when given."""
        events: list[dict] = []
        pids: dict[str, int] = {}
        tids: dict[tuple, int] = {}

        def pid_of(proc: str) -> int:
            p = pids.get(proc)
            if p is None:
                pids[proc] = p = len(pids) + 1
                events.append({"ph": "M", "name": "process_name",
                               "pid": p, "tid": 0,
                               "args": {"name": proc}})
            return p

        def tid_of(p: int, thread: str) -> int:
            key = (p, thread)
            t = tids.get(key)
            if t is None:
                # per-process thread numbering, 1-based; 0 is the
                # process-level track for span/counter events with no host
                t = sum(1 for (pp, _) in tids if pp == p) + 1
                tids[key] = t
                events.append({"ph": "M", "name": "thread_name",
                               "pid": p, "tid": t,
                               "args": {"name": thread}})
            return t

        for sp in self.spans:
            proc = (f"shard{sp.shard}" if sp.shard is not None
                    else (sp.site or "engine"))
            p = pid_of(proc)
            t = tid_of(p, sp.host) if sp.host else 0
            events.append({
                "ph": "X", "cat": "task", "name": sp.name,
                "pid": p, "tid": t,
                "ts": sp.started * 1e6,
                "dur": max(0.0, sp.ended - sp.started) * 1e6,
                "args": {"span_id": sp.span_id, "status": sp.status,
                         "attempt": sp.attempt, "weight": sp.weight,
                         "queue_wait_s": sp.queue_wait(),
                         "io_s": sp.io_s, "site": sp.site},
            })
        for site, host, start, end, name in self.exec_spans:
            p = pid_of(site or "pool")
            t = tid_of(p, host) if host else 0
            events.append({
                "ph": "X", "cat": "executor", "name": name or "task",
                "pid": p, "tid": t,
                "ts": start * 1e6, "dur": max(0.0, end - start) * 1e6,
                "args": {},
            })
        for log_name, lg in sorted(self.logs.items()):
            p = pid_of("counters")
            for t_s, v in lg:
                events.append({
                    "ph": "C", "cat": "counter", "name": log_name,
                    "pid": p, "tid": 0, "ts": t_s * 1e6,
                    "args": {"value": v},
                })
        for kind in sorted(self.events):
            p = pid_of("events")
            t = tid_of(p, kind)
            for t_s, v in self.events[kind]:
                events.append({
                    "ph": "i", "cat": "component", "name": kind,
                    "pid": p, "tid": t, "ts": t_s * 1e6, "s": "t",
                    "args": {"value": v},
                })
        events.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0.0)))
        trace = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"schema": "repro.chrome_trace/v1",
                          **{k: v for k, v in self.snapshot().items()
                             if k not in ("events", "event_rates")}},
        }
        if path is not None:
            with open(path, "w", encoding="utf-8") as f:
                json.dump(trace, f)
        return trace


class MetricsRegistry:
    """One snapshot over every component's named metrics.

    Components register under a name; `snapshot()` normalizes each source
    — an object exposing ``metrics()`` (Falkon service, data layer, pools,
    federation), ``summary()`` (a bare `StreamStat`), ``stats()`` (an
    engine), a zero-arg callable, or a plain dict — into one JSON-able
    mapping.  Registration is O(1); nothing is polled until `snapshot()`.

    Example::

        reg = MetricsRegistry()
        reg.register("falkon", svc)
        reg.register("queue_wait", some_streamstat)
        json.dumps(reg.snapshot())
    """

    def __init__(self):
        self._sources: dict[str, Any] = {}

    def register(self, name: str, source: Any) -> Any:
        if name in self._sources:
            raise ValueError(f"metrics source {name!r} already registered")
        self._sources[name] = source
        return source

    def names(self) -> list[str]:
        return list(self._sources)

    @staticmethod
    def _snap(source: Any) -> Any:
        for attr in ("metrics", "summary", "snapshot", "stats"):
            fn = getattr(source, attr, None)
            if callable(fn):
                return fn()
        if callable(source):
            return source()
        return source

    def snapshot(self) -> dict:
        """Collect every registered source into one JSON-able dict."""
        return {name: self._snap(src)
                for name, src in self._sources.items()}

    def to_json(self, path: str) -> dict:
        snap = self.snapshot()
        with open(path, "w", encoding="utf-8") as f:
            json.dump(snap, f, indent=2, default=str)
        return snap


REPORT_SCHEMA = "repro.run_report/v1"


class RunReport:
    """Post-run analysis over a `Tracer` (and optionally a
    `MetricsRegistry`): the standard report every benchmark emits.

    Fields: exact task counters; critical-path length and its ratio to the
    makespan (1.0 = the run was dependency-bound, ≪1 = resource-bound);
    per-stage time breakdown (the Fig-10 view); queue-wait / stage-wait /
    run-time percentiles from the sampled spans; a per-site utilization
    timeline (estimated busy executors per time bin, scaled by span
    weights); and the registry's component snapshot.  Build with
    `build_report`; render with `format()` or `tools/trace_view.py`.
    """

    def __init__(self, payload: dict):
        self.payload = payload

    def __getitem__(self, key):
        return self.payload[key]

    def get(self, key, default=None):
        return self.payload.get(key, default)

    def to_dict(self) -> dict:
        return self.payload

    def to_json(self, path: str) -> dict:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.payload, f, indent=2, default=str)
        return self.payload

    def format(self) -> str:
        """Human-readable multi-line rendering of the report."""
        p = self.payload
        lines = [
            f"run report (schema {p['schema']})",
            f"  makespan           {p['makespan_s']:.3f} s",
            f"  tasks              done={p['tasks']['done']} "
            f"failed={p['tasks']['failed']} "
            f"retried={p['tasks']['retried']} "
            f"(sampled {p['tasks']['sampled_spans']}, "
            f"stride {p['tasks']['sample_stride']})",
            f"  critical path      {p['critical_path_s']:.3f} s "
            f"(ratio {p['critical_path_ratio']:.2f})",
        ]
        pct = p["percentiles"]
        for key in ("queue_wait_s", "stage_wait_s", "run_s"):
            d = pct[key]
            lines.append(
                f"  {key:<18} p50={d['p50']:.4f} p95={d['p95']:.4f} "
                f"p99={d['p99']:.4f} max={d['max']:.4f}")
        lines.append("  stages:")
        for name, st in p["stages"].items():
            lines.append(
                f"    {name:<24} n~{st['count_est']:<8} "
                f"run={st['run_s_est']:.1f}s "
                f"queue={st['queue_s_est']:.1f}s "
                f"io={st['io_s_est']:.1f}s")
        util = p["utilization"]
        for site, series in util["sites"].items():
            peak = max(series) if series else 0.0
            lines.append(f"  site {site}: peak ~{peak:.1f} busy "
                         f"({util['bins']} bins of {util['bin_s']:.3f}s)")
        if p.get("events"):
            lines.append("  events: " + ", ".join(
                f"{k}={v['count']}" for k, v in p["events"].items()))
        return "\n".join(lines)


def _pct_block(values: list) -> dict:
    vals = sorted(values)
    n = len(vals)
    return {
        "count": n,
        "mean": sum(vals) / n if n else 0.0,
        "p50": percentile_of(vals, 0.50),
        "p95": percentile_of(vals, 0.95),
        "p99": percentile_of(vals, 0.99),
        "max": vals[-1] if n else 0.0,
        "min": vals[0] if n else 0.0,
    }


def build_report(tracer: Tracer, registry: MetricsRegistry | None = None,
                 makespan: float | None = None,
                 utilization_bins: int = 32) -> RunReport:
    """Assemble the standard `RunReport` from a tracer (and optionally a
    registry) after the run drains.  `makespan` defaults to the latest
    span end observed — pass the workload's real completion time when the
    run had trailing events (samplers, shrink sweeps)."""
    spans = tracer.spans
    if makespan is None:
        makespan = max((sp.ended for sp in spans), default=0.0)
    queue_waits = [sp.queue_wait() for sp in spans]
    stage_waits = [sp.io_s for sp in spans]
    run_times = [max(0.0, sp.ended - sp.started) for sp in spans]
    # per-site utilization timeline: each sampled span contributes its
    # overlap with every bin, scaled by its weight -> estimated busy
    # executors per bin per site
    bins = max(1, utilization_bins)
    width = makespan / bins if makespan > 0 else 1.0
    # decimation keeps a uniform-in-time 1-in-2^d subsample of the closed
    # spans without touching their weights; one global factor rescales the
    # kept set back to the full closed population
    kept_w = sum(sp.weight for sp in spans)
    scale = tracer.span_weight_total / kept_w if kept_w else 1.0
    sites: dict[str, list] = {}
    for sp in spans:
        site = sp.site or "engine"
        series = sites.get(site)
        if series is None:
            sites[site] = series = [0.0] * bins
        lo, hi = sp.started, min(sp.ended, makespan)
        if hi <= lo:
            continue
        b0 = min(bins - 1, int(lo / width))
        b1 = min(bins - 1, int(hi / width))
        for b in range(b0, b1 + 1):
            bin_lo, bin_hi = b * width, (b + 1) * width
            overlap = min(hi, bin_hi) - max(lo, bin_lo)
            if overlap > 0:
                series[b] += scale * sp.weight * overlap / width
    payload = {
        "schema": REPORT_SCHEMA,
        "makespan_s": makespan,
        "tasks": {
            "seen": tracer.tasks_seen,
            "done": tracer.tasks_done,
            "failed": tracer.tasks_failed,
            "retried": tracer.tasks_retried,
            "sampled_spans": len(spans),
            "sample_stride": tracer.sample_every * tracer._stride,
        },
        "critical_path_s": tracer.critical_path_s,
        "critical_path_ratio": (tracer.critical_path_s / makespan
                                if makespan > 0 else 0.0),
        "stages": tracer.stage_breakdown(),
        "percentiles": {
            "queue_wait_s": _pct_block(queue_waits),
            "stage_wait_s": _pct_block(stage_waits),
            "run_s": _pct_block(run_times),
        },
        "utilization": {"bins": bins, "bin_s": width,
                        "sites": {k: sites[k] for k in sorted(sites)}},
        "events": tracer.event_counts(),
        "gauges": dict(tracer.gauges),
        "components": registry.snapshot() if registry is not None else {},
    }
    return RunReport(payload)
