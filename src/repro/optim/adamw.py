"""AdamW with global-norm clipping and warmup-cosine schedule (from scratch)."""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Hyper:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: float = 1.0
    warmup: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    microbatches: int = 1


def schedule(hp: Hyper, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, hp.warmup))
    prog = jnp.clip((step - hp.warmup) / max(1, hp.total_steps - hp.warmup), 0, 1)
    cos = hp.min_lr_frac + (1 - hp.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return hp.lr * warm * cos


def init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def update(grads, state, params, step, hp: Hyper):
    lr = schedule(hp, step)
    b1, b2 = hp.b1, hp.b2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_p = mh / (jnp.sqrt(vh) + hp.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_p + hp.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}
