"""Gradient compression for cross-pod sync (distributed-optimization trick).

Two standard schemes with error feedback (residual carry), usable when the
trainer runs in explicit-sync mode (cross-pod gradient exchange over DCN is
the bandwidth-constrained link at 1000+ node scale):

  * int8 quantization: per-tensor scale, symmetric
  * top-k sparsification: keep the k largest-|g| entries

Both are pure-JAX and tested for the error-feedback contraction property.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def topk_sparsify(g, frac: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return vals, idx, flat.size


def topk_densify(vals, idx, size, shape):
    return jnp.zeros((size,), vals.dtype).at[idx].set(vals).reshape(shape)


def compress_with_feedback(grads, residual, scheme: str = "int8",
                           topk_frac: float = 0.01):
    """Returns (compressed_repr, new_residual, decompressed).

    decompressed is what the receiver reconstructs; residual carries the
    compression error into the next step (error feedback).
    """

    def one(g, r):
        x = g + r
        if scheme == "int8":
            q, scale = quantize_int8(x)
            deq = dequantize_int8(q, scale)
            return (q, scale), x - deq, deq
        vals, idx, size = topk_sparsify(x, topk_frac)
        deq = topk_densify(vals, idx, size, x.shape)
        return (vals, idx), x - deq, deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = [o[0] for o in outs]
    new_r = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    deq = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])
    return comp, new_r, deq


def init_residual(grads):
    return jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32),
                                  grads)


def compressed_bytes(comp) -> int:
    total = 0
    for item in jax.tree_util.tree_leaves(comp):
        total += item.size * item.dtype.itemsize
    return total
