"""Engine-driven trainer: training as a Swift workflow (DESIGN.md §3).

Every unit of work — host data staging, the train step itself, periodic
evals, checkpoint writes — is a task in the Karajan engine, linked by data
futures:

    data(i)  ──┐
               ├─> step(i) ──> params(i+1) ──> step(i+1) ...
    params(i) ─┘         └──> eval(i)   (pipelined, off critical path)
                         └──> ckpt(i)   (durable artifact -> manifest)

Fault tolerance comes from the engine (retries on injected/transient step
failures) plus the checkpoint manifest (data-availability restart, §3.12):
`fit()` resumes from the latest durable step after a crash.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.core import Engine, RealClock, Workflow
from repro.core.faults import FaultInjector, RetryPolicy
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.params import init_tree
from repro.optim import adamw
from repro.train.steps import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 20
    ckpt_every: int = 10
    eval_every: int = 5
    log_every: int = 1
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, hp: adamw.Hyper, dcfg: DataConfig,
                 workdir: str, tcfg: TrainerConfig | None = None,
                 fault_injector: FaultInjector | None = None):
        self.cfg = cfg
        self.hp = hp
        self.dcfg = dcfg
        self.workdir = workdir
        self.tcfg = tcfg or TrainerConfig()
        self.data = SyntheticLM(cfg, dcfg)
        self.ckpt = Checkpointer(os.path.join(workdir, "ckpt"))
        self.fault_injector = fault_injector
        self._train_step = jax.jit(make_train_step(cfg, hp), donate_argnums=(0, 1))
        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def init_state(self):
        descs = T.build_descriptors(self.cfg)
        params = init_tree(descs, jax.random.PRNGKey(self.tcfg.seed))
        opt = adamw.init(params)
        return params, opt

    def restore_or_init(self):
        params, opt = self.init_state()
        latest = self.ckpt.latest_step()
        if latest is None:
            return params, opt, 0
        state, step = self.ckpt.restore({"params": params, "opt": opt})
        return state["params"], state["opt"], step

    # ------------------------------------------------------------------
    def fit(self, steps: int | None = None) -> list[dict]:
        total = steps or self.tcfg.total_steps
        params, opt, start = self.restore_or_init()

        engine = Engine(RealClock(),
                        retry_policy=RetryPolicy(max_retries=3),
                        fault_injector=self.fault_injector)
        engine.local_site(concurrency=1)
        wf = Workflow("train", engine)

        def stage_data(step):
            return self.data.global_batch(step)

        def do_step(state, batch, step):
            params, opt = state
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.monotonic()
            params, opt, metrics = self._train_step(
                params, opt, batch, jnp.asarray(step, jnp.int32))
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step"] = step
            metrics["step_time"] = time.monotonic() - t0
            self.history.append(metrics)
            return params, opt

        def do_eval(state, step):
            params, _ = state
            batch = self.data.batch(10_000_000 + step)  # held-out stream
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            loss, _ = T.forward_train(self.cfg, params, batch)
            rec = {"step": step, "eval_loss": float(loss)}
            self.history.append(rec)
            return rec

        def do_ckpt(state, step):
            params, opt = state
            self.ckpt.save(step, {"params": params, "opt": opt})
            return step

        step_proc = wf.atomic(do_step, name="train_step")
        data_proc = wf.atomic(stage_data, name="stage_data")
        eval_proc = wf.atomic(do_eval, name="eval")
        ckpt_proc = wf.atomic(do_ckpt, name="checkpoint")

        from repro.core.futures import resolved
        state_f = resolved((params, opt), name="state0")
        side = []
        for s in range(start, total):
            batch_f = data_proc(s)               # stages while prev step runs
            state_f = step_proc(state_f, batch_f, s)
            if self.tcfg.eval_every and (s + 1) % self.tcfg.eval_every == 0:
                side.append(eval_proc(state_f, s + 1))
            if self.tcfg.ckpt_every and (s + 1) % self.tcfg.ckpt_every == 0:
                side.append(ckpt_proc(state_f, s + 1))
        final = wf.gather([state_f] + side, name="train_done")
        wf.run()
        if final.failed:
            raise final._error
        self.vdc = engine.vdc
        self.engine_stats = engine.stats()
        return self.history
