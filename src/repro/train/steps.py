"""jit-able train / prefill / decode step functions (built per-config)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, hp: adamw.Hyper, grad_shardings=None):
    """Returns train_step(params, opt_state, batch, step) -> (params, opt, metrics).

    Supports gradient accumulation: with hp.microbatches > 1 the global batch
    is split along dim 0 and scanned, accumulating fp32 gradients.

    grad_shardings: optional NamedSharding tree matching params — pins the
    gradient layout so GSPMD emits sharded (reduce-scatter-shaped) weight-
    gradient reductions instead of replicated full-tensor all-reduces
    (§Perf lever G3).
    """

    def loss_fn(params, batch):
        return T.forward_train(cfg, params, batch)

    _grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def grad_fn(params, batch):
        out, grads = _grad_fn(params, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        return out, grads

    def train_step(params, opt_state, batch, step):
        if hp.microbatches > 1:
            mb = hp.microbatches

            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            batches = jax.tree_util.tree_map(split, batch)

            def body(acc, b):
                (loss, metrics), grads = grad_fn(params, b)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
                return (acc_g, acc_l + loss), metrics

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), batches)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss_sum / mb
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads, gnorm = adamw.clip_by_global_norm(grads, hp.clip)
        params, opt_state = adamw.update(grads, opt_state, params, step, hp)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm,
                       lr=adamw.schedule(hp, step))
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        enc = batch.get("enc_feats") if isinstance(batch, dict) else None
        return T.prefill(cfg, params, batch["tokens"], enc_feats=enc)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One greedy decode step: (params, caches, tokens, pos_t) ->
    (next_tokens, new_caches)."""

    def serve_step(params, caches, tokens, pos_t):
        logits, new_caches = T.decode_step(cfg, params, caches, tokens, pos_t)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_caches

    return serve_step
