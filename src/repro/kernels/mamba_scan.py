"""Mamba-1 selective-scan Pallas kernel (TPU target).

h_t = exp(dt_t A) h_{t-1} + (dt_t u_t) B_t ;  y_t = h_t . C_t

grid = (batch, d_inner_blocks, seq_chunks), LAST dim sequential; the
(block_d, N) state is VMEM scratch carried across chunks.  dA / dBu are
computed on the fly inside the kernel — the (S, D, N) expansion never
touches HBM, which is the entire point of the kernel (the pure-XLA chunked
reference materializes chunk-local (chunk, D, N) intermediates to HBM; see
the falcon-mamba roofline discussion in EXPERIMENTS.md).

d_inner blocks are lane-aligned; VMEM working set = chunk x block_d x N x 4B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _combine(p, q):
    a1, b1 = p
    a2, b2 = q
    return a1 * a2, a2 * b1 + b2


def _kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref, y_ref, hfin_ref,
            h_scr):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)        # (chunk, block_d)
    dt = dt_ref[0].astype(jnp.float32)
    A = A_ref[...].astype(jnp.float32)      # (block_d, N)
    Bm = B_ref[0].astype(jnp.float32)       # (chunk, N)
    Cm = C_ref[0].astype(jnp.float32)

    dA = jnp.exp(dt[:, :, None] * A[None])              # (chunk, bd, N)
    dBu = (dt * u)[:, :, None] * Bm[:, None, :]         # (chunk, bd, N)
    accA, accB = jax.lax.associative_scan(_combine, (dA, dBu), axis=0)
    hs = accA * h_scr[...][None] + accB                 # (chunk, bd, N)
    y = jnp.sum(hs * Cm[:, None, :], axis=-1)           # (chunk, bd)
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = hs[-1]

    @pl.when(it == nt - 1)
    def _finish():
        hfin_ref[0] = h_scr[...].astype(hfin_ref.dtype)


def mamba_scan(u, dt, A, Bm, Cm, h0=None, *, chunk: int = 64,
               block_d: int = 256, interpret: bool | None = None):
    """u, dt: (B, S, D); A: (D, N); Bm, Cm: (B, S, N); h0: (B, D, N).

    Returns (y: (B, S, D), h_final: (B, D, N))."""
    B, S, D = u.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    block_d = min(block_d, D)
    while D % block_d:
        block_d //= 2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (B, D // block_d, S // chunk)

    y, h_fin = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bb, jd, it: (bb, it, jd)),
            pl.BlockSpec((1, chunk, block_d), lambda bb, jd, it: (bb, it, jd)),
            pl.BlockSpec((block_d, N), lambda bb, jd, it: (jd, 0)),
            pl.BlockSpec((1, chunk, N), lambda bb, jd, it: (bb, it, 0)),
            pl.BlockSpec((1, chunk, N), lambda bb, jd, it: (bb, it, 0)),
            pl.BlockSpec((1, block_d, N), lambda bb, jd, it: (bb, jd, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bb, jd, it: (bb, it, jd)),
            pl.BlockSpec((1, block_d, N), lambda bb, jd, it: (bb, jd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), u.dtype),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, Bm, Cm, h0)
    return y, h_fin
