"""RG-LRU linear-recurrence Pallas kernel (TPU target).

h_t = a_t * h_{t-1} + b_t over (batch, seq, width), evaluated in time chunks:
grid = (batch, width_blocks, seq_chunks) with the LAST dim sequential; the
carried state h lives in VMEM scratch across chunk steps.  Inside a chunk the
recurrence is a log-depth associative scan over VPU-width lanes — the TPU
mapping of the chunked evaluation used by `repro.models.rglru`.

Width blocks are lane-aligned (multiples of 128); the time chunk bounds the
VMEM working set (chunk x block_w x 4 B per operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _combine(p, q):
    a1, b1 = p
    a2, b2 = q
    return a1 * a2, a2 * b1 + b2


def _kernel(a_ref, b_ref, h0_ref, y_ref, hfin_ref, h_scr):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)     # (chunk, block_w)
    b = b_ref[0].astype(jnp.float32)
    accA, accB = jax.lax.associative_scan(_combine, (a, b), axis=0)
    hs = accA * h_scr[...] + accB        # h carried in: (1, block_w) bcast
    y_ref[0] = hs.astype(y_ref.dtype)
    h_scr[...] = hs[-1:][...]

    @pl.when(it == nt - 1)
    def _finish():
        hfin_ref[...] = h_scr[...].astype(hfin_ref.dtype)


def rglru_scan(a, b, h0, *, chunk: int = 256, block_w: int = 512,
               interpret: bool | None = None):
    """a, b: (B, S, W); h0: (B, W) -> (hs: (B, S, W), h_final: (B, W))."""
    B, S, W = a.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    block_w = min(block_w, W)
    while W % block_w:
        block_w //= 2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (B, W // block_w, S // chunk)

    y, h_fin = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda bb, jw, it: (bb, it, jw)),
            pl.BlockSpec((1, chunk, block_w), lambda bb, jw, it: (bb, it, jw)),
            pl.BlockSpec((1, block_w), lambda bb, jw, it: (bb, jw)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda bb, jw, it: (bb, it, jw)),
            pl.BlockSpec((1, block_w), lambda bb, jw, it: (bb, jw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, h_fin
