"""Flash attention Pallas kernel (TPU target).

Canonical TPU structure: grid = (batch*q_heads, q_blocks, kv_blocks) with the
LAST grid dim sequential, so the online-softmax accumulators (m / l / acc)
live in VMEM scratch and persist across kv-block steps.  Causal and
sliding-window masks skip fully-masked kv blocks with `pl.when` — on TPU the
skipped grid step costs only the (empty) control flow, which is how the
kernel achieves O(S·W) work for local attention.

GQA is handled in the k/v BlockSpec index maps (q head h reads kv head
h // group), so repeated kv heads are never materialized.

Block shapes are MXU/VPU-aligned: block_q x head_dim and block_k x head_dim
tiles (multiples of 128 in the lane dim for f32/bf16); m/l scratch is
(block_q, 128) to match the sublane x lane layout.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # block-level skip: fully above the diagonal / outside the window
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window > 0:
        live = jnp.logical_and(live,
                               k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _step():
        q = q_ref[0]                       # (block_q, D)
        k = k_ref[0]                       # (block_k, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window > 0:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]              # (block_q, 1)
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[:, :1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D) -> (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    while S % block_q:
        block_q //= 2
    while T % block_k:
        block_k //= 2
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (B * Hq, S // block_q, T // block_k)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D),
                         lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, iq, ik, g=group, hq=Hq:
                         ((bh // hq) * (hq // g) + (bh % hq) // g, ik, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, iq, ik, g=group, hq=Hq:
                         ((bh // hq) * (hq // g) + (bh % hq) // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B * Hq, S, D),
      k.reshape(B * Hkv, T, D),
      v.reshape(B * Hkv, T, D)).reshape(B, Hq, S, D)
