"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D); GQA by head repetition."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    if Hq != Hkv:
        k = jnp.repeat(k, Hq // Hkv, axis=1)
        v = jnp.repeat(v, Hq // Hkv, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def ref_linear_scan(a, b, h0):
    """RG-LRU-style recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: (B, S, W) fp32; h0: (B, W).  Returns (hs: (B, S, W), h_final)."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_fin, hs = jax.lax.scan(step, h0,
                             (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), h_fin


def ref_selective_scan(u, dt, A, Bm, Cm, h0=None):
    """Mamba-1 selective scan.

    u, dt: (B, S, D); A: (D, N); Bm, Cm: (B, S, N); h0: (B, D, N).
    Returns (y: (B, S, D), h_final)."""
    B, S, D = u.shape
    N = A.shape[1]
    h0 = jnp.zeros((B, D, N), jnp.float32) if h0 is None else h0

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs
        dA = jnp.exp(dt_t[..., None] * A[None])           # (B, D, N)
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]   # (B, D, N)
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(u, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_fin
