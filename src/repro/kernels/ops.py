"""jit'd wrappers over the Pallas kernels (the public kernel API).

Each wrapper auto-selects interpret mode off-TPU and is shape/dtype swept
against the `ref.py` oracles in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.rglru_scan import rglru_scan as _rglru


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128, interpret=None):
    return _flash(q, k, v, causal=causal, window=window, scale=scale,
                  block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan(a, b, h0, *, chunk=256, block_w=512, interpret=None):
    return _rglru(a, b, h0, chunk=chunk, block_w=block_w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(u, dt, A, Bm, Cm, h0=None, *, chunk=64, block_d=256,
               interpret=None):
    return _mamba(u, dt, A, Bm, Cm, h0, chunk=chunk, block_d=block_d,
                  interpret=interpret)
