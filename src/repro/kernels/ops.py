"""jit'd wrappers over the Pallas kernels (the public kernel API).

Each wrapper auto-selects interpret mode off-TPU and is shape/dtype swept
against the `ref.py` oracles in tests/test_kernels.py.

Two tiers of entry point live here:

  * the jitted kernel wrappers (`flash_attention`, `rglru_scan`,
    `mamba_scan`) — one call == one fused device computation;
  * per-example *task bodies* (`matmul_task`, `attention_task`) — plain,
    unjitted functions over a single example, the granularity the
    device-batched executor fuses (`repro.core.devicepool`, DESIGN.md
    §11).  They are deliberately NOT jitted: submitted alone they pay
    op-by-op dispatch (the overhead the paper's clustering amortizes,
    §3.13); submitted with a ``vmap_key`` the pool stacks K of them into
    one ``jit(vmap(...))`` launch.  Their HLO cost is what
    `repro.launch.hlo_cost.DurationPredictor` prices scheduling with.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.ref import ref_attention
from repro.kernels.rglru_scan import rglru_scan as _rglru


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128, interpret=None):
    return _flash(q, k, v, causal=causal, window=window, scale=scale,
                  block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan(a, b, h0, *, chunk=256, block_w=512, interpret=None):
    return _rglru(a, b, h0, chunk=chunk, block_w=block_w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(u, dt, A, Bm, Cm, h0=None, *, chunk=64, block_d=256,
               interpret=None):
    return _mamba(u, dt, A, Bm, Cm, h0, chunk=chunk, block_d=block_d,
                  interpret=interpret)


# -- per-example task bodies (device-batched executor granularity) ----------

def matmul_task(x, w):
    """One example's projection + nonlinearity: ``tanh(x @ w)`` row-summed.

    Shapes: ``x (d,)``, ``w (d, d)`` -> ``(d,)``.  Pure and vmappable; the
    weight is typically identical across a bundle, so the pool broadcasts
    it (``in_axes=None``) instead of stacking K copies.
    """
    return jnp.sum(jnp.tanh(x @ w), axis=-1) + x


def attention_task(q, k, v):
    """One example's attention, via the reference oracle math.

    Shapes: ``q/k/v (heads, seq, dim)`` for a single example; the pool
    stacks bundles into the batched ``(K, heads, seq, dim)`` layout
    `ref_attention` already handles.
    """
    return ref_attention(q[None], k[None], v[None])[0]
