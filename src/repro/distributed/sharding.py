"""Logical-axis sharding constraints for activations.

Model code annotates intermediates with *logical* axes
(``constrain(x, ("batch", None, "heads", None))``).  When an `AxisRules`
context is active (set up by the launcher), these resolve to
``jax.lax.with_sharding_constraint`` with divisibility fallback; otherwise
they are no-ops (smoke tests run on 1 device without a mesh).
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


class AxisRules:
    def __init__(self, rules: dict[str, Any], mesh: Mesh):
        self.rules = rules
        self.mesh = mesh
        self.mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _axis_size(self, mapped) -> int:
        if mapped is None:
            return 1
        if isinstance(mapped, str):
            return self.mesh_shape.get(mapped, 1)
        return math.prod(self.mesh_shape.get(a, 1) for a in mapped)

    def spec(self, axes, shape) -> P:
        parts = []
        used: set = set()
        for dim, ax in zip(shape, axes):
            mapped = self.rules.get(ax) if ax is not None else None
            if mapped is None:
                parts.append(None)
                continue
            names = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            size = self._axis_size(mapped)
            if size <= 1 or dim % size != 0 or any(n in used for n in names):
                parts.append(None)
                continue
            used.update(names)
            parts.append(mapped)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)


@contextlib.contextmanager
def use_axis_rules(rules: AxisRules | None):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def current_rules() -> AxisRules | None:
    return getattr(_STATE, "rules", None)


def constrain(x, axes):
    """Annotate activation x with logical axes; no-op without an active mesh."""
    r = current_rules()
    if r is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    spec = r.spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))
