"""Elastic data-parallel scaling (Falkon DRP applied to training).

The paper's DRP grows/shrinks the executor pool on queue pressure; here the
"pool" is the data-parallel width.  Because the data pipeline is
stateless-addressable and optimizer state is sharded by logical rules,
rescaling between steps is: build the new mesh -> re-resolve shardings ->
`jax.device_put` the state.  The policy object mirrors DRPConfig.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding

from repro.launch.mesh import compat_make_mesh
from repro.models.params import default_rules, resolve_spec


@dataclasses.dataclass
class ElasticPolicy:
    min_dp: int = 1
    max_dp: int = 64
    grow_threshold: float = 2.0    # backlog/step-time ratio to grow
    shrink_threshold: float = 0.25

    def decide(self, current_dp: int, backlog: float, step_time: float) -> int:
        ratio = backlog / max(step_time, 1e-9)
        if ratio > self.grow_threshold and current_dp < self.max_dp:
            return min(self.max_dp, current_dp * 2)
        if ratio < self.shrink_threshold and current_dp > self.min_dp:
            return max(self.min_dp, current_dp // 2)
        return current_dp


def make_mesh_for_dp(dp: int, model: int = 1):
    devs = jax.devices()
    need = dp * model
    if len(devs) < need:
        raise ValueError(f"need {need} devices, have {len(devs)}")
    return compat_make_mesh((dp, model), ("data", "model"),
                            devices=devs[:need])


def reshard_tree(tree, descs, mesh: Mesh, rules=None):
    """Re-place a (possibly differently-sharded) state tree onto `mesh`."""
    rules = rules or default_rules()
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    from jax.sharding import PartitionSpec
    from repro.models.params import tree_map_desc
    spec_tree = tree_map_desc(lambda d: resolve_spec(d, rules, mesh_shape),
                              descs)
    import jax.tree_util as jtu
    specs = jtu.tree_leaves(spec_tree,
                            is_leaf=lambda x: isinstance(x, PartitionSpec))
    leaves, tdef = jtu.tree_flatten(tree)
    out = [jax.device_put(l, NamedSharding(mesh, s))
           for l, s in zip(leaves, specs)]
    return jtu.tree_unflatten(tdef, out)
