"""Deterministic synthetic LM data pipeline.

Stateless-addressable: batch contents are a pure function of
(seed, step, shard), so the checkpoint "cursor" is just the step index and
any shard can regenerate any batch — which is what makes the restart-log /
elastic-rescale semantics exact (a resumed or re-sharded run sees the same
token stream).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    global_batch: int = 8
    seq_len: int = 128
    # markov-ish structure so losses are learnable, not pure noise
    structure: float = 0.7


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.dcfg.seed, step, shard]))

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> dict:
        """Batch for one data shard; shards partition the global batch."""
        d = self.dcfg
        assert d.global_batch % num_shards == 0
        b = d.global_batch // num_shards
        rng = self._rng(step, shard)
        V = self.cfg.vocab
        # structured stream: blocks of repeated n-grams + noise
        base = rng.integers(0, V, size=(b, d.seq_len + 1), dtype=np.int32)
        if d.structure > 0:
            period = 8
            pattern = rng.integers(0, V, size=(b, period), dtype=np.int32)
            reps = -(-(d.seq_len + 1) // period)
            tiled = np.tile(pattern, (1, reps))[:, :d.seq_len + 1]
            mask = rng.random((b, d.seq_len + 1)) < d.structure
            base = np.where(mask, tiled, base)
        out = {"tokens": base[:, :-1], "labels": base[:, 1:]}
        if self.cfg.enc_dec:
            out["enc_feats"] = rng.standard_normal(
                (b, self.cfg.enc_frames, self.cfg.d_model)).astype(np.float32)
        return out

    def global_batch(self, step: int) -> dict:
        return self.batch(step, 0, 1)
