"""Unit tests for the Karajan engine, Falkon service, sites, and faults."""
import pytest

from repro.core import (BatchSchedulerProvider, ClusteringProvider, DRPConfig,
                        Engine, FalkonConfig, FalkonProvider, FalkonService,
                        LocalProvider, SimClock, Workflow)
from repro.core.faults import FaultInjector, RetryPolicy, TaskFailure
from repro.core.futures import DataFuture, resolved, when_all


# ---------------------------------------------------------------------------
# futures
# ---------------------------------------------------------------------------

def test_future_single_assignment():
    f = DataFuture("x")
    f.set(1)
    assert f.get() == 1
    with pytest.raises(Exception):
        f.set(2)


def test_when_all_fires_once():
    fs = [DataFuture() for _ in range(3)]
    hits = []
    when_all(fs, lambda: hits.append(1))
    for f in fs:
        f.set(0)
    assert hits == [1]


def test_future_callbacks_after_resolution():
    f = resolved(42)
    got = []
    f.on_done(lambda ff: got.append(ff.get()))
    assert got == [42]


# ---------------------------------------------------------------------------
# dispatch / dependencies
# ---------------------------------------------------------------------------

def test_dataflow_ordering():
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=2)
    order = []
    a = eng.submit("a", lambda: order.append("a") or 1)
    b = eng.submit("b", lambda x: order.append("b") or x + 1, [a])
    c = eng.submit("c", lambda x: order.append("c") or x + 1, [b])
    eng.run()
    assert order == ["a", "b", "c"]
    assert c.get() == 3


def test_implicit_parallelism():
    """Independent tasks overlap in (virtual) time."""
    clock = SimClock()
    eng = Engine(clock)
    eng.add_site("s", LocalProvider(clock, concurrency=8), capacity=8)
    outs = [eng.submit(f"t{i}", None, duration=10.0) for i in range(8)]
    eng.run()
    assert clock.now() == pytest.approx(10.0)
    assert all(o.resolved for o in outs)


# ---------------------------------------------------------------------------
# retries / fault handling
# ---------------------------------------------------------------------------

def test_transient_retry_succeeds():
    clock = SimClock()
    inj = FaultInjector().fail_first_n("flaky", 2)
    eng = Engine(clock, retry_policy=RetryPolicy(max_retries=3),
                 fault_injector=inj)
    eng.local_site(concurrency=1)
    out = eng.submit("flaky", lambda: "ok")
    eng.run()
    assert out.get() == "ok"
    assert eng.vdc.summary()["failed"] == 2  # two retried attempts recorded


def test_retry_exhaustion_fails_future():
    clock = SimClock()
    inj = FaultInjector().fail_first_n("doomed", 10)
    eng = Engine(clock, retry_policy=RetryPolicy(max_retries=2),
                 fault_injector=inj)
    eng.local_site(concurrency=1)
    out = eng.submit("doomed", lambda: "ok")
    eng.run()
    assert out.failed
    assert eng.tasks_failed == 1


def test_upstream_failure_propagates():
    clock = SimClock()
    eng = Engine(clock, retry_policy=RetryPolicy(max_retries=0))
    eng.local_site()

    def boom():
        raise TaskFailure("boom")

    a = eng.submit("a", boom)
    b = eng.submit("b", lambda x: x, [a])
    eng.run()
    assert a.failed and b.failed


def test_site_rescheduling_on_site_fault():
    """Site-kind failures move the task to a different site (§3.12)."""
    clock = SimClock()
    eng = Engine(clock, retry_policy=RetryPolicy(max_retries=3))
    ran_on = []

    class RecordingProvider(LocalProvider):
        def __init__(self, clock, name):
            super().__init__(clock, concurrency=4)
            self.site_name = name

        def submit(self, task, when_done):
            ran_on.append(self.site_name)
            if self.site_name == "bad":
                when_done(False, None, TaskFailure("stale NFS", kind="site"))
                return
            super().submit(task, when_done)

    bad = eng.add_site("bad", RecordingProvider(clock, "bad"), capacity=4)
    bad.score = 10.0  # make it the first choice
    eng.add_site("good", RecordingProvider(clock, "good"), capacity=4)
    out = eng.submit("t", lambda: "done")
    eng.run()
    assert out.get() == "done"
    assert "bad" in ran_on and "good" in ran_on


def test_falkon_host_suspension():
    """Repeated failures on one executor suspend that host."""
    clock = SimClock()
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=2, alloc_latency=0.0),
        host_fail_threshold=2, host_suspend_time=1000.0))
    svc.provision(2)
    inj = FaultInjector().fail_host("falkon-host0", 2)
    eng = Engine(clock, retry_policy=RetryPolicy(max_retries=4),
                 fault_injector=inj)
    eng.add_site("f", FalkonProvider(svc), capacity=2)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(6)]
    eng.run()
    assert all(o.resolved for o in outs)
    host0 = svc.executors[0]
    assert host0.suspended_until > 0  # was suspended at some point


# ---------------------------------------------------------------------------
# falkon DRP + metrics
# ---------------------------------------------------------------------------

def test_drp_grows_pool_on_queue_pressure():
    clock = SimClock()
    svc = FalkonService(clock, FalkonConfig(
        drp=DRPConfig(max_executors=16, alloc_latency=10.0, alloc_chunk=4)),
        trace=True)
    eng = Engine(clock)
    eng.add_site("f", FalkonProvider(svc), capacity=16)
    outs = [eng.submit(f"t{i}", None, duration=5.0) for i in range(32)]
    eng.run()
    assert all(o.resolved for o in outs)
    assert len(svc.alloc_log) >= 2  # grew incrementally
    # bounded allocation summary matches the full trace
    assert svc.alloc_stat.count == len(svc.alloc_log)
    assert svc.alloc_stat.total == sum(n for _, n in svc.alloc_log)
    assert svc.utilization()["dispatched"] == 32


def test_clustering_amortizes_overhead():
    """Bundled submission beats per-task submission on a slow scheduler."""

    def run(cluster):
        clock = SimClock()
        eng = Engine(clock)
        # admit_window=0: exact per-job admission — the 2.0x ratio below is
        # calibrated to the exact model with zero slack, so wave-quantized
        # admission lateness (default sched_latency/8) would skew it
        inner = BatchSchedulerProvider(clock, nodes=4, submit_rate=1.0,
                                       sched_latency=10.0, admit_window=0.0)
        prov = ClusteringProvider(clock, inner, window=0.5, bundle_size=8) \
            if cluster else inner
        eng.add_site("s", prov, capacity=4)
        outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(32)]
        eng.run()
        assert all(o.resolved for o in outs)
        return clock.now()

    t_clustered = run(True)
    t_plain = run(False)
    assert t_plain / t_clustered >= 2.0  # paper: 2-4x improvement


def test_load_balancing_proportional_to_speed():
    """Fig 11: the faster site completes more jobs."""
    clock = SimClock()
    eng = Engine(clock)

    class TimedProvider(LocalProvider):
        def __init__(self, clock, factor):
            super().__init__(clock, concurrency=8)
            self.factor = factor

        def submit(self, task, when_done):
            task.duration = task.duration * self.factor
            super().submit(task, when_done)

    fast = eng.add_site("fast", TimedProvider(clock, 0.5), capacity=8)
    slow = eng.add_site("slow", TimedProvider(clock, 1.0), capacity=8)
    wf = Workflow("lb", eng)
    p = wf.sim_proc("job", duration=4.0)
    out = wf.foreach(list(range(480)), p)
    wf.run()
    assert out.resolved
    assert fast.stats.completed + slow.stats.completed == 480
    assert fast.stats.completed > slow.stats.completed


# ---------------------------------------------------------------------------
# provenance
# ---------------------------------------------------------------------------

def test_provenance_records_invocations():
    clock = SimClock()
    eng = Engine(clock)
    eng.local_site(concurrency=2)
    a = eng.submit("stage_a", lambda: 1)
    b = eng.submit("stage_b", lambda x: x + 1, [a])
    eng.run()
    s = eng.vdc.summary()
    assert s["invocations"] == 2 and s["ok"] == 2
    recs = eng.vdc.by_task("stage_b")
    assert len(recs) == 1
    assert recs[0].end_time >= recs[0].start_time >= 0
