"""Real concurrent execution behind the provider interface (DESIGN.md §10).

Covers the acceptance surface of the real path:
  * the same workflow program runs unchanged under SimClock (simulated) and
    RealClock + ThreadExecutorPool (true concurrency) with equivalent results;
  * real staging performs measured byte copies with exact byte accounting;
  * the queue-backed Mailbox transport delivers (and propagates failures)
    across shards;
  * a bounded-time real-thread smoke suitable for CI;
  * the ProcessExecutorPool variant and the failure/retry path on workers.
"""
import threading
import time

import pytest

from repro.core import (DRPConfig, DataLayer, Engine, FalkonConfig,
                        FalkonProvider, FalkonService, FederatedEngine,
                        LocalProvider, ProcessExecutorPool, RealClock,
                        RetryPolicy, SharedStore, SimClock, TaskFailure,
                        ThreadExecutorPool, Workflow)

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def falkon_stack(clock, executors=4, pool=None, data_layer=None,
                 config=None):
    """One engine + one Falkon service, sim or real depending on `pool`."""
    cfg = config or FalkonConfig(
        drp=DRPConfig(max_executors=executors, alloc_latency=0.0,
                      alloc_chunk=executors))
    svc = FalkonService(clock, cfg, data_layer=data_layer, pool=pool)
    eng = Engine(clock)
    eng.add_site("pod0", FalkonProvider(svc), capacity=executors)
    return eng, svc


def moldyn_program(wf):
    """A small MolDyn-shaped pipeline with *real* task bodies: per-molecule
    prepare -> simulate -> score chains, folded into one energy total."""
    prepare = wf.atomic(lambda m: m * 10, name="prepare")
    simulate = wf.atomic(lambda p: p + 7, name="simulate")
    score = wf.atomic(lambda s: s * s, name="score")

    def chain(mol):
        return score(simulate(prepare(mol)))

    return wf.foreach(list(range(12)), chain, name="moldyn")


# ---------------------------------------------------------------------------
# sim vs real equivalence
# ---------------------------------------------------------------------------


def test_sim_real_equivalence_moldyn():
    # simulated: single-threaded discrete-event run
    eng_s, _ = falkon_stack(SimClock())
    out_s = moldyn_program(Workflow("m", eng_s))
    eng_s.run()

    # real: identical program text, thread pool behind the same service
    clock = RealClock()
    pool = ThreadExecutorPool(clock)
    eng_r, svc = falkon_stack(clock, pool=pool)
    out_r = moldyn_program(Workflow("m", eng_r))
    eng_r.run()
    svc.shutdown()

    assert out_s.get() == out_r.get()
    assert eng_r.tasks_completed == eng_s.tasks_completed == 36
    assert pool.tasks_run == 36


def test_real_results_arrive_from_worker_threads():
    clock = RealClock()
    pool = ThreadExecutorPool(clock)
    eng, svc = falkon_stack(clock, pool=pool)
    wf = Workflow("w", eng)
    main = threading.get_ident()
    seen = set()

    def body():
        seen.add(threading.get_ident())
        return 1

    task = wf.atomic(body, name="probe")
    outs = [task() for _ in range(8)]
    eng.run()
    svc.shutdown()
    assert all(o.get() == 1 for o in outs)
    assert main not in seen          # bodies ran off the clock thread
    assert len(seen) >= 1


def test_drp_provisioning_acquires_real_workers():
    clock = RealClock()
    pool = ThreadExecutorPool(clock)          # autoscaling
    eng, svc = falkon_stack(clock, executors=3, pool=pool)
    wf = Workflow("w", eng)
    t = wf.atomic(lambda: 0, name="noop")
    outs = [t() for _ in range(6)]
    eng.run()
    assert all(o.resolved for o in outs)
    # allocation arrival resized the pool to the executor count
    assert len(svc.executors) == 3
    assert pool.size() == 3
    svc.shutdown()
    assert pool.size() == 0


def test_real_thread_smoke_bounded_time():
    """CI smoke: 200 real sleep tasks across 8 real threads finish fast."""
    clock = RealClock()
    pool = ThreadExecutorPool(clock)
    eng, svc = falkon_stack(clock, executors=8, pool=pool)
    wf = Workflow("smoke", eng)
    nap = wf.atomic(lambda: time.sleep(0.001), name="nap")
    outs = [nap() for _ in range(200)]
    t0 = time.monotonic()
    eng.run()
    wall = time.monotonic() - t0
    svc.shutdown()
    assert all(o.resolved for o in outs)
    assert eng.tasks_completed == 200
    assert wall < 5.0                      # 200 x 1 ms over 8 workers
    # true concurrency: the serial floor is 200 ms of sleeping alone
    assert pool.run_stat.total > wall


def test_real_failure_retries_on_workers():
    clock = RealClock()
    pool = ThreadExecutorPool(clock)
    eng, svc = falkon_stack(clock, pool=pool)
    wf = Workflow("w", eng)
    lock = threading.Lock()
    attempts = {"n": 0}

    def flaky():
        with lock:
            attempts["n"] += 1
            if attempts["n"] == 1:
                raise TaskFailure("first attempt fails")
        return "ok"

    out = wf.atomic(flaky, name="flaky")()
    eng.run()
    svc.shutdown()
    assert out.get() == "ok"
    assert attempts["n"] == 2


def test_pool_rejects_non_threadsafe_clock():
    """A SimClock cannot host real workers: its event heap is not
    thread-safe and run() would exit with bodies still out — the pools
    refuse at construction instead of losing completions at runtime."""
    with pytest.raises(ValueError):
        ThreadExecutorPool(SimClock())
    with pytest.raises(ValueError):
        ProcessExecutorPool(SimClock(), workers=1)


def test_thread_roster_bounded_under_autoscale_churn():
    clock = RealClock()
    pool = ThreadExecutorPool(clock)
    for _ in range(5):
        pool.resize(4)
        pool.resize(1)
        time.sleep(0.01)           # let retiring workers exit
    pool.resize(4)
    assert pool.size() == 4
    assert len(pool._threads) <= 5  # live threads + at most one lagging exit
    pool.shutdown()


def test_batch_provider_on_thread_pool():
    clock = RealClock()
    from repro.core import BatchSchedulerProvider
    pool = ThreadExecutorPool(clock, workers=2)
    prov = BatchSchedulerProvider(clock, nodes=2, submit_rate=1000.0,
                                  sched_latency=0.001, pool=pool)
    eng = Engine(clock)
    eng.add_site("batch", prov, capacity=2)
    wf = Workflow("w", eng)
    inc = wf.atomic(lambda x: x + 1, name="inc")
    outs = [inc(i) for i in range(6)]
    eng.run()
    pool.shutdown()
    assert [o.get() for o in outs] == [i + 1 for i in range(6)]


def test_local_provider_on_fixed_thread_pool():
    clock = RealClock()
    pool = ThreadExecutorPool(clock, workers=4)
    eng = Engine(clock)
    eng.add_site("localhost", LocalProvider(clock, 4, pool=pool), capacity=4)
    wf = Workflow("w", eng)
    double = wf.atomic(lambda x: 2 * x, name="double")
    outs = [double(i) for i in range(10)]
    eng.run()
    pool.shutdown()
    assert [o.get() for o in outs] == [2 * i for i in range(10)]


# ---------------------------------------------------------------------------
# measured staging
# ---------------------------------------------------------------------------


def test_real_staging_byte_accounting():
    clock = RealClock()
    store = SharedStore()
    payload = b"x1y2z3" * 128
    obj = store.put("input.dat", payload)
    dl = DataLayer(store, cache_capacity=1e6)
    pool = ThreadExecutorPool(clock)
    eng, svc = falkon_stack(clock, executors=1, pool=pool, data_layer=dl)
    wf = Workflow("stage", eng)
    reader = wf.atomic(lambda: 1, name="read", inputs=(obj,))
    outs = [reader() for _ in range(4)]
    eng.run()
    svc.shutdown()
    assert all(o.resolved for o in outs)
    # first read staged the object; the rest hit the single executor's cache
    assert dl.misses == 1 and dl.hits == 3
    assert dl.bytes_staged == len(payload)
    assert dl.bytes_local == 3 * len(payload)
    assert store.reads == 1 and store.bytes_read == len(payload)
    assert store.readers == 0                  # every read slot released
    # the cache holds the *real* bytes, copied through the shared store
    cache = svc.executors[0].cache
    assert cache.data["input.dat"] == payload
    # staging time was measured (one observation per dispatched task)
    assert dl.measured_io_stat.count == 4
    assert dl.measured_io_stat.total > 0.0


def test_real_staging_eviction_drops_bytes():
    clock = RealClock()
    store = SharedStore()
    a = store.put("a.dat", b"a" * 600)
    b = store.put("b.dat", b"b" * 600)
    dl = DataLayer(store, cache_capacity=1000.0)   # holds only one of them
    pool = ThreadExecutorPool(clock)
    eng, svc = falkon_stack(clock, executors=1, pool=pool, data_layer=dl)
    wf = Workflow("evict", eng)
    ra = wf.atomic(lambda: "a", name="ra", inputs=(a,))
    rb = wf.atomic(lambda: "b", name="rb", inputs=(b,))
    fa = ra()
    fb = wf.then(fa, lambda _: rb())           # serialize: a then b
    eng.run()
    svc.shutdown()
    assert fb.get() == "b"
    cache = svc.executors[0].cache
    assert "b.dat" in cache.data and "a.dat" not in cache.data
    assert cache.used <= cache.capacity
    assert cache.evictions == 1


def test_sim_path_stays_byte_free():
    """The simulated path must not materialize payload bytes in caches."""
    clock = SimClock()
    store = SharedStore()
    obj = store.file("sim.dat", 1e6)
    dl = DataLayer(store, cache_capacity=1e9)
    eng, svc = falkon_stack(clock, executors=2, data_layer=dl)
    wf = Workflow("sim", eng)
    reader = wf.sim_proc("read", duration=1.0, inputs=(obj,))
    outs = [reader() for _ in range(6)]
    eng.run()
    assert all(o.resolved for o in outs)
    assert dl.hits + dl.misses == 6
    for e in svc.executors:
        assert e.cache.data == {}


# ---------------------------------------------------------------------------
# mailbox queue transport
# ---------------------------------------------------------------------------


def round_robin(key: str, n: int) -> int:
    """Force cross-shard chains regardless of key hashing."""
    round_robin.i += 1
    return round_robin.i % n


def test_queue_transport_delivery_sim():
    round_robin.i = -1
    fed = FederatedEngine(2, clock=SimClock(), partitioner=round_robin,
                          transport="queue", steal=False)
    for s in fed.shards:
        s.local_site(concurrency=2)
    wf = Workflow("fed", fed)
    inc = wf.atomic(lambda x: x + 1, name="inc")
    v = inc(0)
    for _ in range(7):
        v = inc(v)                 # alternating shards: every edge crosses
    wf.run()
    assert v.get() == 8
    assert fed.cross_shard_edges >= 7
    delivered = sum(m.messages for m in fed.mailboxes)
    flushed = sum(m.flushes for m in fed.mailboxes)
    assert delivered >= 7 and flushed >= 1
    sends = sum(m.transport.sends for m in fed.mailboxes)
    assert sends == delivered      # every message crossed the real queue


def test_queue_transport_failure_propagation():
    round_robin.i = -1
    fed = FederatedEngine(2, clock=SimClock(), partitioner=round_robin,
                          transport="queue", steal=False,
                          engine_kwargs={
                              "retry_policy": RetryPolicy(max_retries=0)})
    for s in fed.shards:
        s.local_site(concurrency=2)
    wf = Workflow("fed", fed)

    def boom(_x):
        raise TaskFailure("producer died")

    bad = wf.atomic(boom, name="boom")
    consume = wf.atomic(lambda x: x, name="consume")
    out = consume(bad(1))          # failure crosses the shard boundary
    wf.run()
    assert out.failed
    with pytest.raises(TaskFailure):
        out.get()


def test_queue_transport_federated_real_run():
    clock = RealClock()
    engines, pools = [], []
    for i in range(2):
        pool = ThreadExecutorPool(clock)
        eng, _svc = falkon_stack(clock, executors=2, pool=pool)
        engines.append(eng)
        pools.append(pool)
    round_robin.i = -1
    fed = FederatedEngine(engines, clock=clock, partitioner=round_robin,
                          transport="queue")
    wf = Workflow("fedreal", fed)
    inc = wf.atomic(lambda x: x + 1, name="inc")
    v = inc(0)
    for _ in range(9):
        v = inc(v)
    wf.run()
    for p in pools:
        p.shutdown()
    assert v.get() == 10
    assert fed.cross_shard_edges >= 9
    assert fed.tasks_completed == 10


def test_unknown_transport_rejected():
    with pytest.raises(ValueError):
        FederatedEngine(2, transport="carrier-pigeon")


# ---------------------------------------------------------------------------
# serialized dispatch ceiling (real time)
# ---------------------------------------------------------------------------


def test_serialize_dispatch_gates_real_starts():
    clock = RealClock()
    pool = ThreadExecutorPool(clock)
    cfg = FalkonConfig(
        dispatch_overhead=0.005, serialize_dispatch=True,
        drp=DRPConfig(max_executors=8, alloc_latency=0.0, alloc_chunk=8))
    eng, svc = falkon_stack(clock, executors=8, pool=pool, config=cfg)
    wf = Workflow("gate", eng)
    noop = wf.atomic(lambda: 0, name="noop")
    outs = [noop() for _ in range(20)]
    t0 = time.monotonic()
    eng.run()
    wall = time.monotonic() - t0
    svc.shutdown()
    assert all(o.resolved for o in outs)
    # the dispatcher is a serial resource: 20 starts x 5 ms >= 100 ms,
    # however many executors are idle
    assert wall >= 0.095


# ---------------------------------------------------------------------------
# process pool
# ---------------------------------------------------------------------------


def _cube(x):
    return x ** 3


def _raise_value_error(x):
    raise ValueError(f"bad {x}")


def test_process_pool_runs_bodies_in_children():
    clock = RealClock()
    pool = ProcessExecutorPool(clock, workers=2)
    eng, svc = falkon_stack(clock, executors=2, pool=pool)
    wf = Workflow("proc", eng)
    cube = wf.atomic(_cube, name="cube")
    outs = [cube(i) for i in range(5)]
    eng.run()
    svc.shutdown()
    assert [o.get() for o in outs] == [i ** 3 for i in range(5)]
    assert pool.tasks_run == 5


def test_process_pool_propagates_child_exceptions():
    clock = RealClock()
    pool = ProcessExecutorPool(clock, workers=1)
    eng, svc = falkon_stack(clock, executors=1, pool=pool)
    eng.retry_policy = RetryPolicy(max_retries=0)
    wf = Workflow("proc", eng)
    bad = wf.atomic(_raise_value_error, name="bad")
    out = bad(7)
    eng.run()
    svc.shutdown()
    assert out.failed
    with pytest.raises(ValueError):
        out.get()


# ---------------------------------------------------------------------------
# clock primitives
# ---------------------------------------------------------------------------


def test_realclock_waits_for_held_work():
    """run() must not exit while a task is out on a worker (hold token)."""
    clock = RealClock()
    clock.hold()
    delivered = []

    def worker():
        time.sleep(0.02)
        clock.post_release(lambda: delivered.append(True))

    threading.Thread(target=worker, daemon=True).start()
    clock.run()                    # no events queued — blocks on the token
    assert delivered == [True]


def test_realclock_post_wakes_timer_wait():
    clock = RealClock()
    order = []
    t0 = time.monotonic()
    clock.schedule(0.5, lambda: order.append("timer"))
    clock.hold()

    def worker():
        time.sleep(0.01)
        clock.post_release(lambda: order.append("posted"))

    threading.Thread(target=worker, daemon=True).start()
    # the post is processed long before the timer, which still fires at
    # its own 0.5 s deadline
    clock.run()
    assert order == ["posted", "timer"]
    assert time.monotonic() - t0 >= 0.5
