"""Device-batched execution (DESIGN.md §11): the DeviceExecutorPool seam,
vmap-signature bundling, HLO-priced scheduling, and their composition with
staging, streaming, DRP, and the duration-aware balancer.

Covers the acceptance surface of the device-batching PR:
  * bundles fuse into one vmapped call with per-task results identical to
    per-task execution (and measured stats attributed per task);
  * signature keying is structural (shapes/dtypes) and GC-safe (stable
    callable keys, not raw ids);
  * non-batchable tasks, fault-check failures, real staging, streaming
    `foreach(window=)`, and DRP autoscaling all compose unchanged;
  * `DurationPredictor` prices tasks without device work, caches by
    signature, and drives identical scheduling decisions in simulated and
    real runs of the same program.
"""
import gc

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DRPConfig, DataLayer, DeviceExecutorPool,
                        Engine, FalkonConfig, FalkonProvider, FalkonService,
                        RealClock, SharedStore, SimClock, Workflow)
from repro.core.clustering import VmapClusteringProvider, vmap_signature
from repro.core.sites import LoadBalancer, Site
from repro.core.task import FnKeyRegistry, stable_fn_key
from repro.launch.hlo_cost import DeviceModel, DurationPredictor

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def device_stack(clock, executors=16, max_bundle=16, data_layer=None,
                 alloc_latency=0.0, predictor=None):
    """Engine + Falkon service backed by a DeviceExecutorPool, with DRP
    pre-sized so one scheduler pump dispatches the whole ready set (the
    bundling-friendly configuration the benchmark uses)."""
    pool = DeviceExecutorPool(clock, max_bundle=max_bundle)
    cfg = FalkonConfig(drp=DRPConfig(
        min_executors=executors, max_executors=executors,
        alloc_latency=alloc_latency, alloc_chunk=executors))
    svc = FalkonService(clock, cfg, data_layer=data_layer, pool=pool)
    svc.provision(executors)
    eng = Engine(clock, duration_predictor=predictor)
    eng.add_site("dev", FalkonProvider(svc), capacity=executors)
    return eng, svc, pool


def body(x, w):
    return jnp.sum(jnp.tanh(x @ w), axis=-1) + x


# ---------------------------------------------------------------------------
# fusion correctness
# ---------------------------------------------------------------------------


def test_bundle_results_match_per_task_reference():
    clock = RealClock()
    eng, svc, pool = device_stack(clock, executors=32, max_bundle=32)
    w = np.asarray(np.random.default_rng(0).normal(size=(8, 8)), np.float32)
    xs = np.asarray(np.random.default_rng(1).normal(size=(24, 8)), np.float32)
    futs = [eng.submit(f"t{i}", body, [xs[i], w], vmap_key="b")
            for i in range(24)]
    eng.run()
    svc.shutdown()
    for i, f in enumerate(futs):
        np.testing.assert_allclose(np.asarray(f.get()),
                                   np.asarray(body(jnp.asarray(xs[i]),
                                                   jnp.asarray(w))),
                                   rtol=1e-5)
    # actually fused: far fewer device calls than tasks, and every task
    # went through the vmapped path
    assert pool.tasks_run == 24
    assert pool.fused_tasks == 24
    assert pool.bundles_run < 24
    assert pool.bundle_stat.peak == 24
    # measured bundle time attributed per task into the bounded stats
    assert pool.run_stat.count == 24
    assert pool.run_stat.total == pytest.approx(pool.device_s)
    # the service surfaces the pool's metrics on the real path
    assert svc.metrics()["pool"]["fused_tasks"] == 24


def test_mixed_signatures_form_separate_bundles():
    clock = RealClock()
    eng, svc, pool = device_stack(clock, executors=16, max_bundle=16)

    def f(x):
        return x * 2.0

    # same vmap_key, different shapes: the structural signature must split
    # them into two bundles instead of failing the stack at execution time
    a = [eng.submit(f"a{i}", f, [np.ones((4,), np.float32)], vmap_key="k")
         for i in range(4)]
    b = [eng.submit(f"b{i}", f, [np.ones((8,), np.float32)], vmap_key="k")
         for i in range(4)]
    eng.run()
    svc.shutdown()
    assert all(np.asarray(x.get()).shape == (4,) for x in a)
    assert all(np.asarray(x.get()).shape == (8,) for x in b)
    assert pool.bundles_run == 2
    assert pool.fused_tasks == 8


def test_non_batchable_tasks_run_as_measured_singletons():
    clock = RealClock()
    eng, svc, pool = device_stack(clock, executors=4)
    # no vmap_key: ordinary python body through the same pool
    futs = [eng.submit(f"p{i}", lambda i=i: i * 10) for i in range(3)]
    eng.run()
    svc.shutdown()
    assert [f.get() for f in futs] == [0, 10, 20]
    assert pool.tasks_run == 3
    assert pool.fused_tasks == 0
    assert pool.run_stat.count == 3
    assert pool.bundle_stat.peak == 1


def test_fault_check_fails_one_task_not_the_bundle():
    from repro.core import FaultInjector, RetryPolicy, TaskFailure
    clock = RealClock()
    pool = DeviceExecutorPool(clock, max_bundle=16)
    cfg = FalkonConfig(drp=DRPConfig(min_executors=8, max_executors=8,
                                     alloc_latency=0.0, alloc_chunk=8))
    svc = FalkonService(clock, cfg, pool=pool)
    svc.provision(8)
    inj = FaultInjector().fail_first_n("t2", 1)
    eng = Engine(clock, fault_injector=inj,
                 retry_policy=RetryPolicy(max_retries=0))
    eng.add_site("dev", FalkonProvider(svc), capacity=8)
    futs = [eng.submit(f"t{i}", body,
                       [np.ones((4,), np.float32) * i,
                        np.ones((4, 4), np.float32)], vmap_key="b")
            for i in range(6)]
    eng.run()
    svc.shutdown()
    for i, f in enumerate(futs):
        if i == 2:
            with pytest.raises(TaskFailure):
                f.get()
        else:
            assert f.resolved
    # the failing task was excluded from the batch, the rest still fused
    assert pool.fused_tasks == 5


def test_max_bundle_caps_fuse_width():
    clock = RealClock()
    eng, svc, pool = device_stack(clock, executors=32, max_bundle=4)
    futs = [eng.submit(f"t{i}", body,
                       [np.ones((4,), np.float32),
                        np.ones((4, 4), np.float32)], vmap_key="b")
            for i in range(12)]
    eng.run()
    svc.shutdown()
    assert all(f.resolved for f in futs)
    assert pool.bundle_stat.peak <= 4
    assert pool.bundles_run >= 3


# ---------------------------------------------------------------------------
# composition: staging, streaming, DRP
# ---------------------------------------------------------------------------


def test_real_staging_composes_with_bundling():
    clock = RealClock()
    store = SharedStore()
    payloads = {f"in{i}": np.full((16,), float(i), np.float32)
                for i in range(8)}
    objs = {name: store.put(name, arr.tobytes())
            for name, arr in payloads.items()}
    dl = DataLayer(store, cache_capacity=1e6)
    eng, svc, pool = device_stack(clock, executors=8, max_bundle=8,
                                  data_layer=dl)
    futs = [eng.submit(f"t{i}", body,
                       [payloads[f"in{i}"], np.eye(16, dtype=np.float32)],
                       vmap_key="b", inputs=(objs[f"in{i}"],))
            for i in range(8)]
    eng.run()
    svc.shutdown()
    assert all(f.resolved for f in futs)
    # staging ran through the pool's measured io path
    assert pool.io_stat.count == 8
    assert pool.io_stat.total > 0.0
    assert pool.fused_tasks > 0


def test_foreach_window_streams_through_device_pool():
    clock = RealClock()
    eng, svc, pool = device_stack(clock, executors=8, max_bundle=8)
    wf = Workflow("stream", eng)
    step = wf.atomic(lambda x: jnp.sum(x * 2.0), name="step", vmap_key="s")
    total = wf.foreach((np.full((4,), i, np.float32) for i in range(40)),
                       step, window=16,
                       reduce=lambda acc, v: acc + float(v), init=0.0)
    eng.run()
    svc.shutdown()
    assert total.get() == pytest.approx(sum(8.0 * i for i in range(40)))
    assert pool.tasks_run == 40
    assert pool.fused_tasks > 0


def test_drp_autoscaling_composes_with_device_pool():
    clock = RealClock()
    pool = DeviceExecutorPool(clock, max_bundle=8)
    # start from zero executors with a real (small) allocation latency:
    # the pool is fixed-size (autoscale False), so DRP only grows the
    # logical executor set and never resizes the pool
    cfg = FalkonConfig(drp=DRPConfig(max_executors=8, alloc_latency=0.01,
                                     alloc_chunk=4))
    svc = FalkonService(clock, cfg, pool=pool)
    eng = Engine(clock)
    eng.add_site("dev", FalkonProvider(svc), capacity=8)
    futs = [eng.submit(f"t{i}", body,
                       [np.ones((4,), np.float32),
                        np.ones((4, 4), np.float32)], vmap_key="b")
            for i in range(16)]
    eng.run()
    assert pool.size() == 1          # dispatcher count untouched by DRP
    svc.shutdown()
    assert all(f.resolved for f in futs)
    assert len(svc.executors) > 0
    assert pool.tasks_run == 16


# ---------------------------------------------------------------------------
# prediction: pricing compute before running it
# ---------------------------------------------------------------------------


def test_predictor_fills_task_duration_via_engine():
    pred = DurationPredictor(device=DeviceModel(launch_overhead=0.25))
    eng = Engine(SimClock(), duration_predictor=pred)
    eng.local_site(concurrency=1)
    futs = [eng.submit(f"t{i}", body,
                       [np.ones((8,), np.float32),
                        np.ones((8, 8), np.float32)])
            for i in range(4)]
    eng.run()
    assert all(f.resolved for f in futs)
    # one host compile for the shared signature, then cache hits; the
    # predicted duration is the simulated service time, so four serial
    # tasks advance the sim clock by at least 4x the launch floor
    assert pred.compiles == 1
    assert pred.hits == 3
    assert eng.clock.now() >= 4 * 0.25


def test_predictor_caches_unpredictable_bodies_as_none():
    pred = DurationPredictor()

    def untraceable(xs):
        return sorted(xs)

    assert pred.predict_duration(untraceable, [[3, 1, 2]]) is None
    assert pred.predict_duration(untraceable, [[3, 1, 2]]) is None
    assert pred.compiles == 1        # the failure was cached, not retried
    assert pred.hits == 1


def test_duration_aware_balancer_prices_outstanding_work():
    s1 = Site("a", provider=None, capacity=4)
    s2 = Site("b", provider=None, capacity=4)
    lb = LoadBalancer([s1, s2])
    # duration-blind: equal weights tie toward the first-registered site
    assert lb.pick(None, now=0.0) is s1
    s1.outstanding_work = 10.0
    assert lb.pick(None, now=0.0) is s1   # still blind to predicted work
    lb.duration_aware = True
    assert lb.pick(None, now=0.0) is s2   # queued seconds now priced


def test_sim_and_real_scheduling_decisions_match():
    """The same MolDyn-shaped submit sequence, priced by the same
    predictor, must split across sites identically in a simulated run and
    a real device-pool run — predicted durations, not measured ones,
    drive placement."""
    shapes = [16, 16, 32, 16, 32, 32, 16, 32, 16, 16, 32, 16]

    def run_one(real):
        clock = RealClock() if real else SimClock()
        pred = DurationPredictor()
        eng = Engine(clock, duration_predictor=pred)
        eng.balancer.duration_aware = True
        sites = []
        for name, cap in (("anl_tg", 4), ("uc_tp", 2)):
            if real:
                pool = DeviceExecutorPool(clock, max_bundle=8)
                cfg = FalkonConfig(drp=DRPConfig(
                    min_executors=cap, max_executors=cap,
                    alloc_latency=0.0, alloc_chunk=cap))
                svc = FalkonService(clock, cfg, pool=pool)
                svc.provision(cap)
                sites.append((eng.add_site(name, FalkonProvider(svc),
                                           capacity=cap), svc))
            else:
                prov = VmapClusteringProvider(clock, max_bundle=8)
                sites.append((eng.add_site(name, prov, capacity=cap), None))
        futs = [eng.submit(f"m{i}", body,
                           [np.ones((d,), np.float32),
                            np.ones((d, d), np.float32)], vmap_key="md")
                for i, d in enumerate(shapes)]
        # literal args place synchronously at submit: the split is decided
        # here, before any execution, by predicted durations alone
        split = tuple(s.stats.submitted for s, _ in sites)
        eng.run()
        for _, svc in sites:
            if svc is not None:
                svc.shutdown()
        assert all(f.resolved for f in futs)
        return split

    assert run_one(real=False) == run_one(real=True)


# ---------------------------------------------------------------------------
# GC-safe callable identity
# ---------------------------------------------------------------------------


def test_fn_key_registry_stable_and_gc_safe():
    reg = FnKeyRegistry()

    def f(x):
        return x

    def g(x):
        return x + 1

    kf, kg = reg.key(f), reg.key(g)
    assert kf != kg
    assert reg.key(f) == kf          # stable across calls
    n = len(reg)
    del g
    gc.collect()
    assert len(reg) == n - 1         # dead entry reaped, no id pinning

    # a NEW callable must never inherit a dead callable's key, even if the
    # allocator reuses its id (the bug raw id(fn) keying had)
    seen = {kf}
    for _ in range(50):
        def h(x):
            return x * 3
        k = reg.key(h)
        assert k not in seen
        seen.add(k)
        del h
        gc.collect()


def test_vmap_signature_distinguishes_shapes_and_callables():
    def f(x):
        return x

    a4 = np.ones((4,), np.float32)
    a8 = np.ones((8,), np.float32)
    assert vmap_signature(f, [a4]) == vmap_signature(f, [a4])
    assert vmap_signature(f, [a4]) != vmap_signature(f, [a8])
    assert vmap_signature(f, [a4]) != vmap_signature(lambda x: x, [a4])
    assert stable_fn_key(f) == stable_fn_key(f)


def test_vmap_provider_singleton_fallback_reports_measured_stats():
    eng = Engine(SimClock())
    prov = VmapClusteringProvider(eng.clock, max_bundle=64)
    eng.add_site("d", prov, capacity=8)
    out = eng.submit("solo", body, [np.ones((4,), np.float32),
                                    np.ones((4, 4), np.float32)],
                     vmap_key="s")
    eng.run()
    assert out.resolved
    # a singleton bundle still lands in the throughput stats instead of
    # vanishing (same shape as the real pools' metrics)
    assert prov.run_stat.count == 1
    assert prov.metrics()["bundles"] == 1
    assert prov.fused_tasks == 0
