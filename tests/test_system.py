"""End-to-end behaviour tests for the integrated system.

The paper's headline behaviours, verified on the real engine:
  * futures-driven pipelining reduces makespan vs barrier execution (§5.2)
  * Falkon-style dispatch beats batch-scheduler submission for many small
    tasks (§5.4, the up-to-90%-reduction claim)
  * restart log resumes a partially-completed workflow (§3.12)
  * the engine-driven trainer survives injected step failures and resumes
    from checkpoints
"""
import os

import pytest

from repro.core import (BatchSchedulerProvider, DRPConfig, Engine,
                        FalkonConfig, FalkonProvider, FalkonService,
                        RestartLog, SimClock, Workflow)


def _fmri(engine, volumes=32, stages=(3.0, 5.0, 4.0)):
    wf = Workflow("fmri", engine)
    procs = [wf.sim_proc(f"stage{i}", duration=d)
             for i, d in enumerate(stages)]
    col = list(range(volumes))
    out = wf.foreach(col, procs[0])
    for p in procs[1:]:
        out = wf.foreach(out, p)
    return wf, out


def test_falkon_beats_batch_scheduler_on_small_tasks():
    def run(use_falkon):
        clock = SimClock()
        eng = Engine(clock)
        if use_falkon:
            svc = FalkonService(clock, FalkonConfig(
                drp=DRPConfig(max_executors=8, alloc_latency=81.0)))
            eng.add_site("site", FalkonProvider(svc), capacity=8)
        else:
            eng.add_site("site", BatchSchedulerProvider(
                clock, nodes=8, submit_rate=0.2, sched_latency=60.0),
                capacity=8)
        wf, out = _fmri(eng, volumes=64)
        wf.run()
        assert out.resolved
        return clock.now()

    t_falkon = run(True)
    t_batch = run(False)
    assert t_falkon < t_batch
    # paper: up to 90% reduction; with GRAM-throttled submission (0.2 j/s)
    # the gap here is > 3x
    assert t_batch / t_falkon > 3.0


def test_pipelining_overlaps_stages():
    """Futures make stage k+1 start before stage k fully finishes (§5.2).

    Task durations are heterogeneous (as in the paper's fMRI stages), so a
    barrier pays sum-of-stage-maxima while the pipelined dataflow pays the
    per-volume critical path."""
    vols = list(range(16))
    # anti-correlated stage durations: a volume slow in stage 1 is fast in
    # stage 2, so overlap buys a lot and a barrier wastes it
    d1 = lambda v: 1.0 + (v % 2) * 4.0
    d2 = lambda v: 5.0 - (v % 2) * 4.0

    def run(barrier):
        clock = SimClock()
        eng = Engine(clock)
        svc = FalkonService(clock, FalkonConfig(
            drp=DRPConfig(max_executors=32, alloc_latency=0.0),
            dispatch_overhead=0.0))
        eng.add_site("site", FalkonProvider(svc), capacity=32)
        wf = Workflow("p", eng)
        if barrier:
            out1 = wf.foreach(
                vols, lambda v: eng.submit(f"s1-{v}", None, duration=d1(v)))
            # barrier: stage2 expands only after ALL of stage1 resolved
            out = wf.foreach(list(vols), lambda v: eng.submit(
                f"s2-{v}", None, [out1], duration=d2(v)))
        else:
            # pipelined: per-volume chains, no barrier between stages
            chains = []
            for v in vols:
                f1 = eng.submit(f"s1-{v}", None, duration=d1(v))
                chains.append(eng.submit(f"s2-{v}", None, [f1],
                                         duration=d2(v)))
            out = wf.gather(chains)
        wf.run()
        assert out.resolved
        return clock.now()

    t_pipe = run(False)
    t_barrier = run(True)
    assert t_pipe < t_barrier
    # paper measured 21% reduction for the fMRI workflow
    assert (t_barrier - t_pipe) / t_barrier > 0.10


def test_restart_log_resumes_workflow(tmp_path):
    log_path = os.path.join(tmp_path, "restart.log")
    calls = []

    def make(fail_at):
        clock = SimClock()
        eng = Engine(clock, restart_log=RestartLog(log_path))
        eng.local_site(concurrency=4)
        wf = Workflow("w", eng)

        @wf.atomic(durable=True)
        def work(i):
            if fail_at is not None and i >= fail_at:
                raise RuntimeError("crash")
            calls.append(i)
            return i * 10

        return eng, wf, work

    eng, wf, work = make(fail_at=4)
    outs = [work(i) for i in range(8)]
    wf.run()
    done_first = sum(1 for o in outs if o.resolved)
    assert 0 < done_first < 8

    # "restart": new engine, same log; only unproduced tasks re-run
    calls.clear()
    eng2, wf2, work2 = make(fail_at=None)
    outs2 = [work2(i) for i in range(8)]
    wf2.run()
    assert all(o.resolved for o in outs2)
    assert [o.get() for o in outs2] == [i * 10 for i in range(8)]
    assert len(calls) == 8 - done_first  # restored tasks did NOT re-run
    assert eng2.tasks_restored == done_first


def test_restart_log_picks_up_new_inputs(tmp_path):
    """Paper §3.12 side effect (a): inputs added after a run are processed
    on restart without re-running old work."""
    log_path = os.path.join(tmp_path, "restart.log")

    def run(inputs):
        clock = SimClock()
        eng = Engine(clock, restart_log=RestartLog(log_path))
        eng.local_site(concurrency=4)
        wf = Workflow("w", eng)
        ran = []

        @wf.atomic(durable=True)
        def proc(i):
            ran.append(i)
            return i

        outs = [proc(i) for i in inputs]
        wf.run()
        return ran, outs

    ran1, _ = run([0, 1, 2])
    assert sorted(ran1) == [0, 1, 2]
    ran2, outs2 = run([0, 1, 2, 3, 4])
    assert sorted(ran2) == [3, 4]
    assert all(o.resolved for o in outs2)


def test_trainer_end_to_end_with_faults(tmp_path):
    from repro.configs import registry
    from repro.core.faults import FaultInjector
    from repro.data.pipeline import DataConfig
    from repro.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = registry.smoke_config("qwen1.5-0.5b")
    inj = FaultInjector(seed=0).fail_first_n("train_step", 2)
    tr = Trainer(cfg, adamw.Hyper(lr=1e-3, warmup=2),
                 DataConfig(global_batch=2, seq_len=32), str(tmp_path),
                 TrainerConfig(total_steps=4, ckpt_every=2, eval_every=0),
                 fault_injector=inj)
    hist = tr.fit()
    losses = [h["loss"] for h in hist if "loss" in h]
    assert len(losses) == 4
    assert tr.engine_stats["failed"] == 0  # injected faults were retried

    # resume: runs only the remaining steps
    tr2 = Trainer(cfg, adamw.Hyper(lr=1e-3, warmup=2),
                  DataConfig(global_batch=2, seq_len=32), str(tmp_path),
                  TrainerConfig(total_steps=6, ckpt_every=2, eval_every=0))
    hist2 = tr2.fit()
    steps2 = [h["step"] for h in hist2 if "loss" in h]
    assert steps2 == [4, 5]
