"""Unit tests for the online health layer (DESIGN.md §13):

  * RollingStat — windowed counts/means/percentiles, bucket expiry,
    bulk counter-delta folding, stale-observation drop;
  * HealthMonitor state machine — degrade/recover, drain -> blacklist
    with queued-task revocation, probe-based recovery, replay-identical
    transition logs;
  * straggler detection — rolling-p95 thresholds, flag-once semantics,
    the on_straggler re-dispatch hint, and the bounded dispatch-ordered
    registry (cap + resolved-head drain);
  * feedback seams — suspended sites drop out of `pick`/`idle_slots`
    (the stealer's thief test), per-executor drain on Falkon services;
  * the JSONL metrics stream — emission, `trace_view` validation,
    `live_monitor` rendering, backpressure watermark events;
  * sim/real tracer consistency — the same federated workflow via
    QueueTransport + ThreadExecutorPool on RealClock produces the same
    task/span accounting as its SimClock run (PR 7 tested sim only).
"""
import json
import os
import sys
from types import SimpleNamespace

import pytest

from repro.core import (DRPConfig, Engine, FalkonConfig, FalkonProvider,
                        FalkonService, FaultInjector, FederatedEngine,
                        HealthConfig, HealthMonitor, LocalProvider,
                        METRICS_STREAM_SCHEMA, RealClock, RetryPolicy,
                        RollingStat, SimClock, TaskFailure,
                        ThreadExecutorPool, Tracer, Workflow)

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
from tools.live_monitor import render_table  # noqa: E402
from tools.trace_view import main as trace_view_main  # noqa: E402
from tools.trace_view import validate_metrics_stream  # noqa: E402


# ---------------------------------------------------------------------------
# RollingStat
# ---------------------------------------------------------------------------

def test_rolling_stat_windowed_counts_and_expiry():
    rs = RollingStat(window=10.0, buckets=5)
    rs.observe(1.0, 1.0)
    rs.observe(3.0, 0.0)
    assert rs.count(9.9) == 2
    assert rs.mean(9.9) == pytest.approx(0.5)
    assert rs.rate(9.9) == pytest.approx(0.2)
    # the t=1 bucket (epoch 0) leaves the window at t >= 10
    assert rs.count(10.5) == 1
    # everything expires once the whole window has passed
    assert rs.count(25.0) == 0
    assert rs.mean(25.0) == 0.0


def test_rolling_stat_drops_observations_older_than_window():
    rs = RollingStat(window=10.0, buckets=5)
    rs.observe(100.0, 1.0)
    rs.observe(5.0, 1.0)            # older than the whole window: dropped
    assert rs.count(100.0) == 1
    assert rs.total(100.0) == pytest.approx(1.0)


def test_rolling_stat_percentiles_from_kept_samples():
    rs = RollingStat(window=10.0, buckets=10, keep_samples=4)
    for i, v in enumerate((5.0, 1.0, 9.0, 3.0, 7.0)):
        rs.observe(float(i), v)
    assert rs.percentile(1.0, 4.0) == 9.0
    assert rs.percentile(0.0, 4.0) == 1.0
    # without keep_samples there is nothing to rank
    bare = RollingStat(window=10.0, buckets=10)
    bare.observe(0.0, 5.0)
    assert bare.percentile(0.95, 0.0) == 0.0


def test_rolling_stat_keep_samples_bounded_per_bucket():
    rs = RollingStat(window=10.0, buckets=1, keep_samples=3)
    for v in range(100):
        rs.observe(1.0, float(v))
    assert rs.count(1.0) == 100          # counts stay exact
    b = rs._ring[0]
    assert len(b[2]) == 3                # samples capped


def test_rolling_stat_observe_bulk_matches_individual():
    a = RollingStat(window=20.0, buckets=4)
    b = RollingStat(window=20.0, buckets=4)
    for _ in range(7):
        a.observe(3.0, 1.0)
    for _ in range(5):
        a.observe(3.0, 0.0)
    b.observe_bulk(3.0, 12, 7.0)
    assert a.count(3.0) == b.count(3.0) == 12
    assert a.mean(3.0) == pytest.approx(b.mean(3.0))
    assert a.snapshot(3.0) == b.snapshot(3.0)
    b.observe_bulk(3.0, 0, 0.0)          # no-op
    assert b.count(3.0) == 12


def test_rolling_stat_rejects_bad_parameters():
    with pytest.raises(ValueError):
        RollingStat(window=0.0)
    with pytest.raises(ValueError):
        RollingStat(window=10.0, buckets=0)


# ---------------------------------------------------------------------------
# helpers: a small N-site Falkon grid (mirrors benchmarks/health_recovery)
# ---------------------------------------------------------------------------

def _grid(clock, n_sites=2, cap=8, tracer=None, inj=None,
          host_fail_threshold=None):
    kw = {"host_suspend_time": 300.0}
    if host_fail_threshold is not None:
        kw["host_fail_threshold"] = host_fail_threshold
    eng = Engine(clock, tracer=tracer, fault_injector=inj,
                 retry_policy=RetryPolicy(max_retries=8, backoff=1.0),
                 provenance="summary")
    services = []
    for i in range(n_sites):
        svc = FalkonService(clock, FalkonConfig(
            drp=DRPConfig(max_executors=cap, alloc_latency=0.0,
                          alloc_chunk=cap), **kw), name=f"site{i}")
        svc.provision(cap)
        eng.add_site(f"site{i}", FalkonProvider(svc), capacity=cap)
        services.append(svc)
    return eng, services


# ---------------------------------------------------------------------------
# state machine: degrade / recover
# ---------------------------------------------------------------------------

def test_degraded_site_recovers_when_faults_stop():
    clock = SimClock()
    inj = FaultInjector(seed=7, clock=clock)
    inj.fail_site_window("site1", 0.3, start=6.0, end=14.0)
    eng, _ = _grid(clock, inj=inj)
    cfg = HealthConfig(window=8.0, buckets=4, min_samples=8,
                       degrade_error_rate=0.10, drain_error_rate=0.80,
                       blacklist_error_rate=0.90)
    hm = HealthMonitor(clock, cfg)
    hm.watch(eng)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(500)]
    eng.run()
    assert all(o.resolved for o in outs)
    moves = [(tr["site"], tr["from"], tr["to"]) for tr in hm.transitions]
    assert ("site1", "healthy", "degraded") in moves
    assert ("site1", "degraded", "healthy") in moves
    # site0 never took faults and never left healthy
    assert not [m for m in moves if m[0] == "site0"]
    assert hm.states() == {"site0": "healthy", "site1": "healthy"}
    # degrade actuates through the derate seam and is restored on recovery
    site1 = eng.balancer.sites[1]
    assert site1.derate == 1.0 and site1.health_state == "healthy"


# ---------------------------------------------------------------------------
# state machine: drain -> blacklist, revocation, stream, determinism
# ---------------------------------------------------------------------------

_DRAIN_CFG = HealthConfig(
    window=8.0, buckets=4, min_samples=6,
    degrade_error_rate=0.08, drain_error_rate=0.15,
    blacklist_error_rate=0.45, recover_error_rate=0.10,
    drain_backoff=2.0, backoff_factor=2.0, blacklist_backoff=1e5,
    blacklist_after_drains=2, revoke_on_drain=True, emit_interval=2.0)


def _drain_scenario(stream_path=None):
    """site1 fails every attempt (fail-slow) from t=6; the monitor must
    blacklist it and hand its queued tasks back.  Returns (hm, eng, outs)."""
    clock = SimClock()
    tracer = Tracer()
    inj = FaultInjector(seed=11, clock=clock)
    inj.fail_site_window("site1", 1.0, start=6.0, latency=2.0)
    eng, _ = _grid(clock, inj=inj, tracer=tracer)
    hm = HealthMonitor(clock, _DRAIN_CFG, tracer=tracer)
    hm.watch(eng)
    for svc in (s.provider.service for s in eng.balancer.sites):
        hm.watch_service(svc)
    if stream_path:
        hm.attach_sink(stream_path)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(400)]
    eng.run()
    hm.emit_line()
    hm.close()
    return hm, eng, outs


def test_failing_site_is_blacklisted_and_queue_revoked():
    hm, eng, outs = _drain_scenario()
    assert all(o.resolved for o in outs)
    assert hm.states()["site1"] == "blacklisted"
    assert hm.states()["site0"] == "healthy"
    assert any(tr["site"] == "site1" and tr["to"] == "blacklisted"
               for tr in hm.transitions)
    # drain handed site1's queued tasks back (no retry charge), and the
    # engine's revocation path reported them to the monitor
    assert hm.tasks_revoked > 0
    assert eng.stats().get("revoked", 0) == hm.tasks_revoked
    # the suspension seam holds: the blacklist parked the site for the
    # long backoff (the clock itself runs on to the probe poke at the end)
    site1 = eng.balancer.sites[1]
    assert site1.suspended_until >= _DRAIN_CFG.blacklist_backoff
    assert site1.health_state == "blacklisted"


def test_transition_log_replays_byte_identically():
    hm1, _, _ = _drain_scenario()
    hm2, _, _ = _drain_scenario()
    assert hm1.transitions            # non-trivial log
    assert hm1.transition_log_json() == hm2.transition_log_json()


def test_metrics_stream_emits_and_validates(tmp_path):
    path = str(tmp_path / "run.jsonl")
    hm, _, _ = _drain_scenario(stream_path=path)
    assert hm.lines_emitted > 0
    with open(path, encoding="utf-8") as f:
        lines = f.readlines()
    assert validate_metrics_stream(lines) == []
    assert trace_view_main([path, "--validate"]) == 0
    snaps = [json.loads(ln) for ln in lines]
    last = snaps[-1]
    assert last["schema"] == METRICS_STREAM_SCHEMA
    assert last["sites"]["site1"]["state"] == "blacklisted"
    assert last["transitions"] == len(hm.transitions)
    assert last["revoked"] == hm.tasks_revoked
    # timestamps never go backwards across the stream
    ts = [s["t"] for s in snaps]
    assert ts == sorted(ts)
    # the live view renders it (smoke: names + state marks show up)
    table = render_table(last)
    assert "site1" in table and "blacklisted" in table
    assert "X site1" in table          # blacklist marker


def test_trace_view_rejects_malformed_metrics_stream(tmp_path, capsys):
    bad = tmp_path / "bad.jsonl"
    good_line = json.dumps({
        "schema": METRICS_STREAM_SCHEMA, "t": 1.0, "sites": {},
        "backlog": 0, "inflight": 0, "tracked": 0, "stragglers": 0,
        "revoked": 0, "transitions": 0})
    bad.write_text("\n".join([
        good_line,
        "{not json",
        json.dumps({"schema": "wrong/v1", "t": 2.0}),
        json.dumps({"schema": METRICS_STREAM_SCHEMA, "t": 0.5,
                    "sites": {}, "backlog": 0, "inflight": 0,
                    "tracked": 0, "stragglers": 0, "revoked": 0,
                    "transitions": 0}),                 # t goes backwards
        json.dumps({"schema": METRICS_STREAM_SCHEMA, "t": 3.0,
                    "sites": {"s": {"state": "weird", "error_rate": 2.0,
                                    "window_completions": 0,
                                    "outstanding": 0, "queue": 0}},
                    "backlog": -1, "inflight": 0, "tracked": 0,
                    "stragglers": 0, "revoked": 0, "transitions": 0}),
    ]) + "\n")
    errors = validate_metrics_stream(bad.read_text().splitlines())
    assert len(errors) >= 4
    assert trace_view_main([str(bad), "--validate"]) == 1
    empty = tmp_path / "empty.jsonl"
    empty.write_text("\n")
    assert trace_view_main([str(empty), "--validate"]) == 1
    capsys.readouterr()


# ---------------------------------------------------------------------------
# state machine: probe-based recovery after a drain
# ---------------------------------------------------------------------------

def test_drained_site_recovers_via_probe_when_faults_stop():
    clock = SimClock()
    inj = FaultInjector(seed=3, clock=clock)
    # faults stop at t=8; the drain backoff parks the site past that, so
    # the probe traffic lands on a healthy site again
    inj.fail_site_window("site1", 0.5, start=4.0, end=8.0)
    eng, _ = _grid(clock, inj=inj)
    cfg = HealthConfig(window=4.0, buckets=4, min_samples=4,
                       degrade_error_rate=0.08, drain_error_rate=0.15,
                       blacklist_error_rate=0.95, recover_error_rate=0.10,
                       drain_backoff=6.0, blacklist_after_drains=5,
                       revoke_on_drain=True)
    hm = HealthMonitor(clock, cfg)
    hm.watch(eng)
    for svc in (s.provider.service for s in eng.balancer.sites):
        hm.watch_service(svc)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(600)]
    eng.run()
    assert all(o.resolved for o in outs)
    moves = [(tr["from"], tr["to"], tr["reason"]) for tr in hm.transitions
             if tr["site"] == "site1"]
    assert any(to == "drained" for _, to, _ in moves)
    assert any(frm == "drained" and to == "healthy"
               and reason.startswith("probe ok")
               for frm, to, reason in moves)
    assert hm.states()["site1"] == "healthy"


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------

def test_straggler_flagged_once_with_redispatch_hint():
    clock = SimClock()
    tracer = Tracer()
    hints = []
    cfg = HealthConfig(window=10.0, buckets=5, min_samples=6,
                       duration_window=60.0, duration_stride=1,
                       straggler_factor=3.0, straggler_min_s=1.0,
                       straggler_interval=2.0)
    eng, _ = _grid(clock, n_sites=1, cap=6, tracer=tracer)
    hm = HealthMonitor(clock, cfg, tracer=tracer,
                       on_straggler=lambda t, a, thr: hints.append(
                           (t.name, a, thr)))
    hm.watch(eng)
    # phase 1: build the rolling p95 for the "work" key (stride 1: every
    # success is sampled)
    outs = [eng.submit("work", None, duration=1.0) for _ in range(12)]
    eng.run()
    assert all(o.resolved for o in outs)
    assert hm.stragglers_flagged == 0
    # phase 2: one same-key task runs 40x the p95 -> flagged exactly once
    slow = eng.submit("work", None, duration=40.0)
    eng.run()
    assert slow.resolved
    assert hm.stragglers_flagged == 1
    assert len(hints) == 1
    name, age, thr = hints[0]
    assert name == "work"
    assert thr >= cfg.straggler_min_s
    assert age > thr
    assert len(hm.straggler_log) == 1
    assert tracer.event_counts()["straggler"]["count"] == 1
    assert hm.metrics()["sites"]["site0"]["stragglers"] == 1


def test_straggler_registry_is_capped_and_head_drains():
    clock = SimClock()
    hm = HealthMonitor(clock, HealthConfig(straggler_track_cap=4))

    def fake_task(i):
        return SimpleNamespace(id=i, submit_time=0.0,
                               output=SimpleNamespace(resolved=False))

    tasks = [fake_task(i) for i in range(10)]
    for t in tasks:
        hm.task_dispatched(t, 0.0)
    # admissions past the cap are not registered
    assert len(hm._running) == 4
    assert [t.id for t in hm._running] == [0, 1, 2, 3]
    # completions never touch the registry (§13 hot-path contract)...
    tasks[0].output.resolved = True
    tasks[1].output.resolved = True
    hm.task_finished(tasks[0], None, False, 1.0)
    assert len(hm._running) == 4
    # ...resolved entries drain from the head during scans instead
    hm._scan(1.0)
    assert [t.id for t in hm._running] == [2, 3]
    for t in tasks:
        t.output.resolved = True
    hm._scan(2.0)
    assert len(hm._running) == 0 and not hm._flagged


def test_registry_released_when_run_goes_idle():
    hm, eng, _ = _drain_scenario()
    # the self-disarming tick cleared the registry at idle (§9 GC contract)
    assert not hm._armed
    assert len(hm._running) == 0
    assert hm.snapshot_line()["tracked"] == 0
    assert hm.snapshot_line()["inflight"] == 0


# ---------------------------------------------------------------------------
# fault injector: site-correlated time windows
# ---------------------------------------------------------------------------

def test_fail_site_window_applies_only_inside_window():
    clock = SimClock()
    inj = FaultInjector(seed=0, clock=clock)
    inj.fail_site_window("bad", 1.0, start=10.0, end=20.0,
                         latency=2.5, only_task="sim")
    assert inj.timed                    # latency rules are dispatch-timed

    def check_at(t, name="sim0", site="bad"):
        clock.schedule(t - clock.now(),
                       lambda: inj.check(name, "", 0, site=site))
        clock.run()

    check_at(5.0)                       # before the window: clean
    with pytest.raises(TaskFailure) as exc:
        check_at(15.0)                  # inside: deterministic failure
    assert exc.value.latency == 2.5
    check_at(16.0, site="good")         # other sites unaffected
    check_at(17.0, name="other")        # task filter respected
    check_at(25.0)                      # window closed


def test_fail_site_window_requires_clock():
    with pytest.raises(ValueError):
        FaultInjector(seed=0).fail_site_window("s", 1.0)


# ---------------------------------------------------------------------------
# per-executor drain
# ---------------------------------------------------------------------------

def test_executor_drain_suspends_failing_hosts():
    clock = SimClock()
    tracer = Tracer()
    inj = FaultInjector(seed=5, clock=clock)
    inj.fail_site_window("site0", 1.0, start=0.0, end=6.0)
    # keep Falkon's own consecutive-failure heuristic out of the way so
    # the suspensions observed are the monitor's
    eng, services = _grid(clock, n_sites=1, cap=3, tracer=tracer,
                          inj=inj, host_fail_threshold=99)
    cfg = HealthConfig(window=8.0, buckets=4, min_samples=6,
                       drain_error_rate=0.9, blacklist_error_rate=0.95,
                       degrade_error_rate=0.85,
                       executor_drain_error_rate=0.5,
                       executor_min_samples=2, executor_backoff=3.0)
    hm = HealthMonitor(clock, cfg, tracer=tracer)
    hm.watch(eng)
    hm.watch_service(services[0])
    assert services[0].health is hm     # hook installed when configured
    outs = [eng.submit(f"t{i}", None, duration=0.5) for i in range(20)]
    eng.run()
    assert all(o.resolved for o in outs)
    assert hm.executors_drained >= 1
    assert tracer.event_counts()["executor_drained"]["count"] \
        == hm.executors_drained


def test_watch_service_without_executor_tracking_adds_no_hook():
    clock = SimClock()
    eng, services = _grid(clock, n_sites=1)
    hm = HealthMonitor(clock)           # executor_drain_error_rate=None
    hm.watch(eng)
    hm.watch_service(services[0])
    assert services[0].health is None   # zero service hot-path cost
    hm.on_executor(services[0], None, False, 0.0)   # disabled: no-op
    assert hm.executors_drained == 0


# ---------------------------------------------------------------------------
# federation wiring + the suspended-site steal seam
# ---------------------------------------------------------------------------

def test_monitor_watches_every_federation_shard():
    clock = SimClock()
    fed = FederatedEngine(2, clock=clock,
                          engine_kwargs={"provenance": "summary"})
    for i, eng in enumerate(fed.shards):
        eng.add_site(f"local{i}", LocalProvider(clock, concurrency=4),
                     capacity=4)
    hm = HealthMonitor(clock, HealthConfig(window=4.0, buckets=4))
    hm.watch(fed)
    assert fed.health is hm
    assert all(e.health is hm for e in fed.shards)
    wf = Workflow("fed", fed)
    outs = []
    for c in range(40):
        f = None
        for s in range(3):
            f = fed.submit(f"stage{s}", None,
                           [f] if f is not None else [], duration=1.0)
        outs.append(f)
    out = wf.gather(outs)
    wf.run()
    assert out.resolved
    # the monitor saw sites on both shards, all healthy
    assert hm.states() == {"local0": "healthy", "local1": "healthy"}
    line = hm.snapshot_line()
    assert set(line["sites"]) == {"local0", "local1"}
    assert line["inflight"] == 0


def test_suspended_site_is_skipped_by_pick_and_idle_slots():
    clock = SimClock()
    eng, _ = _grid(clock, n_sites=2, cap=4)
    site0, site1 = eng.balancer.sites
    assert eng.balancer.idle_slots(0.0) == 8
    # a drained site stops being a placement target and a steal thief
    site1.suspended_until = 100.0
    assert eng.balancer.idle_slots(0.0) == 4
    assert eng.balancer.pick(None, 0.0) is site0
    # suspending everything leaves no thief capacity at all
    site0.suspended_until = 100.0
    assert eng.balancer.idle_slots(0.0) == 0
    assert eng.balancer.pick(None, 0.0) is None
    # lapse: capacity comes back
    assert eng.balancer.idle_slots(200.0) == 8


# ---------------------------------------------------------------------------
# tracer event stream: subscribe, windowed rates, alerts, watermarks
# ---------------------------------------------------------------------------

def test_tracer_subscribe_feeds_monitor_alerts():
    clock = SimClock()
    tracer = Tracer()
    hm = HealthMonitor(clock, HealthConfig(window=10.0, buckets=5),
                       tracer=tracer)
    tracer.event("worker_error", 1.0)
    tracer.event("worker_error", 2.0)
    tracer.event("steal", 2.0)          # not alert-worthy: ignored
    assert set(hm._alerts) == {"worker_error"}
    line = hm.snapshot_line(3.0)
    assert line["alerts"]["worker_error"]["count"] == 2
    # windowed event rates ride the same stream and decay
    rates = tracer.event_rates(3.0)
    assert rates["worker_error"]["count"] == 2
    assert rates["steal"]["count"] == 1
    later = 3.0 + 2.0 * tracer.rate_window
    assert tracer.event_rates(later)["worker_error"]["count"] == 0


def test_backpressure_watermark_events():
    clock = SimClock()
    tracer = Tracer()
    eng = Engine(clock, tracer=tracer, provenance="summary")
    # two sites: with a choice to steer, the engine throttles dispatch at
    # slack x capacity and holds the excess in its ready backlog
    eng.add_site("a", LocalProvider(clock, concurrency=2), capacity=2)
    eng.add_site("b", LocalProvider(clock, concurrency=2), capacity=2)
    hm = HealthMonitor(clock, HealthConfig(
        queue_high_watermark=2.0, queue_low_watermark=0.5), tracer=tracer)
    hm.watch(eng)
    outs = [eng.submit(f"t{i}", None, duration=1.0) for i in range(50)]
    assert eng.ready_backlog() > 2 * eng.pool_capacity()
    line = hm.emit_line()               # no sink: returns the line anyway
    assert line["backlog"] == eng.ready_backlog()
    assert tracer.event_counts()["backpressure_high"]["count"] == 1
    eng.run()
    assert all(o.resolved for o in outs)
    hm.emit_line()
    assert tracer.event_counts()["backpressure_low"]["count"] == 1


# ---------------------------------------------------------------------------
# sim/real consistency: QueueTransport + ThreadExecutorPool on RealClock
# ---------------------------------------------------------------------------

def _alternating(key, n):
    _alternating.i += 1
    return _alternating.i % n


def _traced_federated_chain(real):
    """The same 10-task inc chain across 2 shards, every edge crossing the
    transport; sim or real depending on `real`."""
    clock = RealClock() if real else SimClock()
    tracer = Tracer(sample_every=1)
    engines, pools = [], []
    for i in range(2):
        pool = ThreadExecutorPool(clock) if real else None
        svc = FalkonService(clock, FalkonConfig(
            drp=DRPConfig(max_executors=2, alloc_latency=0.0,
                          alloc_chunk=2)), pool=pool)
        eng = Engine(clock, tracer=tracer)
        eng.add_site(f"pod{i}", FalkonProvider(svc), capacity=2)
        engines.append(eng)
        pools.append(pool)
    _alternating.i = -1
    fed = FederatedEngine(engines, clock=clock, partitioner=_alternating,
                          transport="queue", tracer=tracer)
    wf = Workflow("obs", fed)
    inc = wf.atomic(lambda x: x + 1, name="inc")
    v = inc(0)
    for _ in range(9):
        v = inc(v)
    wf.run()
    for p in pools:
        if p is not None:
            p.shutdown()
    assert v.get() == 10
    assert fed.cross_shard_edges >= 9
    return tracer, fed


def test_tracer_consistent_across_sim_and_real_transport():
    """PR 7's federation trace tests ran on SimClock only; the same
    workflow through QueueTransport + ThreadExecutorPool on RealClock must
    produce the same task/span accounting."""
    tr_sim, fed_sim = _traced_federated_chain(real=False)
    tr_real, fed_real = _traced_federated_chain(real=True)
    for tr in (tr_sim, tr_real):
        assert tr.tasks_seen == 10 and tr.tasks_done == 10
        assert tr.tasks_failed == 0
    # full sampling: one span per task, same names, same shard spread
    assert len(tr_sim.spans) == len(tr_real.spans) == 10
    assert sorted(sp.name for sp in tr_sim.spans) == \
        sorted(sp.name for sp in tr_real.spans)
    assert {sp.shard for sp in tr_sim.spans} == \
        {sp.shard for sp in tr_real.spans} == {0, 1}
    assert all(sp.status == "ok" for sp in tr_real.spans)
    # both transports traced their mailbox flushes
    for tr, fed in ((tr_sim, fed_sim), (tr_real, fed_real)):
        assert tr.event_counts()["mailbox_flush"]["count"] >= 1
        assert fed.tasks_completed == 10
