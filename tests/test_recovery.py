"""Crash-recovery tests (DESIGN.md §15): SIGKILL a real workflow process
at randomized progress points, resume from the surviving `JobStore`, and
grade the resume against ground truth.

Reuses the `benchmarks/kill_resume.py` child — a RealClock engine +
thread pool journaling into sqlite, whose task bodies append their index
to a sidecar file (O_APPEND page-cache writes survive SIGKILL).  The
sidecars record *which tasks actually executed* independently of the
store under test, so the assertions don't trust the thing being tested:

  * resumed results are byte-identical to an uninterrupted run's;
  * every task executed at least once across the two runs;
  * re-run count is bounded by the in-flight window (executor slots +
    journal batch + flush lag) — a store that lost its rows would re-run
    ~everything done before the kill, hundreds of tasks over this bound.
"""
import hashlib
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.core import JobStore

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_BENCH = os.path.join(_ROOT, "benchmarks", "kill_resume.py")

N = 600
# executors(4) + journal batch(32) + flush-lag at the smoke rate; a
# broken store re-runs ~kill_fraction * N >= 150, far over this
REDUNDANT_BOUND = 128


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"), _ROOT,
                    env.get("PYTHONPATH")) if p)
    # slow the bodies so mid-flight kills land reliably at N=600
    env.setdefault("KILL_RESUME_BODY_SLEEP", "0.002")
    return env


def _spawn(db, n, sidecar, results_path):
    return subprocess.Popen(
        [sys.executable, _BENCH, "--child", db, str(n), sidecar,
         results_path], env=_env())


def _sidecar(path):
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        return {int(line) for line in f if line.strip()}


def _reference(tmp_path):
    """Uninterrupted subprocess run: results bytes + executed set."""
    results = str(tmp_path / "ref.json")
    proc = _spawn(str(tmp_path / "ref.db"), N,
                  str(tmp_path / "ref.side"), results)
    assert proc.wait(timeout=300) == 0
    with open(results, "rb") as f:
        return f.read()


def _kill_at(tmp_path, fraction, tag):
    """Run the child, SIGKILL once `fraction` of N is durably done;
    return (db, sidecar, done_at_kill)."""
    db = str(tmp_path / f"{tag}.db")
    side = str(tmp_path / f"{tag}.side")
    proc = _spawn(db, N, side, str(tmp_path / f"{tag}.unused.json"))
    target = int(N * fraction)
    done = 0
    try:
        deadline = time.monotonic() + 300.0
        while time.monotonic() < deadline:
            assert proc.poll() is None, \
                f"child finished before the {fraction:.0%} kill point"
            try:
                done = JobStore.peek(db, "killres")["done"]
            except Exception:
                done = 0
            if done >= target:
                break
            time.sleep(0.002)
        else:
            pytest.fail("kill threshold never reached")
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait()
    return db, side, done


def test_no_kill_subprocess_sanity(tmp_path):
    """The harness itself: an uninterrupted child produces the expected
    values and a complete sidecar."""
    ref_bytes = _reference(tmp_path)
    values = json.loads(ref_bytes)
    assert values == [(i * 2654435761) & 0xFFFFFFFF for i in range(N)]
    assert _sidecar(str(tmp_path / "ref.side")) == set(range(N))


@pytest.mark.parametrize("fraction", [0.25, 0.4, 0.6])
def test_sigkill_then_resume_is_exact_and_cheap(tmp_path, fraction):
    """SIGKILL at a randomized-ish progress point, resume from the store:
    byte-identical output, nothing durably done re-ran, and the re-run
    count stays inside the in-flight window at the moment of the kill."""
    import benchmarks.kill_resume as kr
    ref_bytes = _reference(tmp_path)
    db, side1, done_at_kill = _kill_at(tmp_path, fraction, "kill")

    side2 = str(tmp_path / "resume.side")
    results, restored = kr.run_workflow(db, N, side2)

    assert hashlib.sha256(json.dumps(results).encode()).hexdigest() == \
        hashlib.sha256(ref_bytes).hexdigest()
    assert restored >= done_at_kill
    executed1, executed2 = _sidecar(side1), _sidecar(side2)
    assert executed1 | executed2 >= set(range(N))
    redundant = executed1 & executed2
    assert len(redundant) <= REDUNDANT_BOUND, \
        f"{len(redundant)} tasks re-ran (window bound {REDUNDANT_BOUND})"
    # the resume never re-runs more than what was in flight: everything
    # it executed is outside the durable set it restored
    assert len(executed2) <= N - restored + len(redundant)
