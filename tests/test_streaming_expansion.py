"""Streaming (windowed) workflow expansion — DESIGN.md §9.

Covers: window refill order and determinism under `SimClock`, lazy
generator / `Dataset` collections, failure mid-window, `reduce=`
correctness vs eager results, submit-side backpressure (the frontier
tracks pool capacity), the future-GC contract (frontier-bounded live
futures), federated windowed runs with stealing, and the satellite fixes
(callable `duration=` specs, body / `when`-branch exceptions failing the
output future, the affinity-aware `inputs_partitioner`).
"""
import gc
import weakref

import pytest

from repro.core import (CompletionCounter, DataFuture, Dataset, Engine,
                        FederatedEngine, ListMapper, SimClock, Workflow,
                        hash_partitioner, inputs_partitioner, resolved,
                        skewed_partitioner)
from repro.core.datastore import DataObject


def make_engine(concurrency=4):
    eng = Engine(SimClock())
    eng.local_site(concurrency=concurrency)
    return eng


# ---------------------------------------------------------------------------
# CompletionCounter
# ---------------------------------------------------------------------------

def test_completion_counter_counts_without_retaining():
    c = CompletionCounter()
    futs = [DataFuture() for _ in range(5)]
    for f in futs:
        c.add(f)
    drained = []
    c.close(lambda: drained.append(True))
    assert c.pending == 5 and not drained
    for f in futs[:4]:
        f.set(1)
    assert c.done == 4 and not drained
    futs[4].set_error(RuntimeError("boom"))
    assert drained and c.failed == 1
    assert isinstance(c.first_error, RuntimeError)
    # the counter holds no references: futures die with the caller's list
    refs = [weakref.ref(f) for f in futs]
    del futs, f
    gc.collect()
    assert all(r() is None for r in refs)


def test_completion_counter_close_after_done_fires_immediately():
    c = CompletionCounter()
    f = resolved(7)
    c.add(f)
    fired = []
    c.close(lambda: fired.append(True))
    assert fired == [True]


def test_completion_counter_on_each_sees_each_future():
    seen = []
    c = CompletionCounter(on_each=lambda f: seen.append(f._value))
    for v in range(3):
        c.add(resolved(v))
    assert seen == [0, 1, 2]


# ---------------------------------------------------------------------------
# windowed foreach: semantics
# ---------------------------------------------------------------------------

def test_windowed_results_match_eager_in_member_order():
    """keep_results=True under a window fills slots by member index, so the
    result list matches eager even when completions arrive out of order
    (durations descend: later members finish first)."""

    def run(window):
        eng = make_engine(concurrency=8)
        wf = Workflow("t", eng)
        out = wf.foreach(
            range(12),
            lambda m: eng.submit("job", None, duration=float(12 - m)),
            window=window)
        wf.run()
        return out.get()

    eager = run(None)
    windowed = run(3)
    assert windowed == eager
    assert windowed == [None] * 12   # sim tasks resolve to their sim_value


def test_windowed_reduce_matches_eager_reduce():
    def run(window):
        eng = make_engine(concurrency=4)
        wf = Workflow("t", eng)
        p = wf.atomic(lambda m: m * m, name="sq")
        out = wf.foreach(range(20), lambda m: p(m), window=window,
                         reduce=lambda a, b: a + b, init=0)
        wf.run()
        return out.get()

    assert run(None) == run(4) == sum(m * m for m in range(20))


def test_windowed_count_only():
    eng = make_engine()
    wf = Workflow("t", eng)
    out = wf.foreach(range(17), lambda m: eng.submit("j", None, duration=1.0),
                     window=5, keep_results=False)
    wf.run()
    assert out.get() == 17


def test_window_bounds_frontier_and_refills_in_member_order():
    """At most `window` bodies in flight; refills follow member order."""
    eng = make_engine(concurrency=2)
    wf = Workflow("t", eng)
    submitted = []
    in_flight = [0]
    peak = [0]

    def body(m):
        submitted.append(m)
        in_flight[0] += 1
        peak[0] = max(peak[0], in_flight[0])
        f = eng.submit("job", None, duration=1.0)
        f.on_done(lambda _f: in_flight.__setitem__(0, in_flight[0] - 1))
        return f

    out = wf.foreach(range(30), body, window=3, keep_results=False)
    wf.run()
    assert out.get() == 30
    assert submitted == list(range(30))
    assert peak[0] <= 3


def test_windowed_expansion_is_deterministic_under_simclock():
    def run():
        eng = make_engine(concurrency=3)
        wf = Workflow("t", eng)
        order = []

        def body(m):
            order.append(m)
            return eng.submit("job", None, duration=float((m * 7) % 5 + 1))

        out = wf.foreach(range(40), body, window=4, keep_results=False)
        wf.run()
        return order, eng.clock.now(), out.get()

    assert run() == run()


def test_windowed_over_generator_is_lazy():
    """A generator collection is consumed as the window refills, never
    materialized: at most window + 1 items drawn before completions."""
    eng = make_engine(concurrency=1)
    wf = Workflow("t", eng)
    drawn = []
    completed = []

    def gen():
        for m in range(10):
            drawn.append(m)
            yield m

    def body(m):
        f = eng.submit("job", None, duration=1.0)
        f.on_done(lambda _f: completed.append(m))
        # the iterator never runs ahead of completions by more than the
        # window (2) plus the item being submitted
        assert len(drawn) <= len(completed) + 3
        return f

    out = wf.foreach(gen(), body, window=2, keep_results=False)
    wf.run()
    assert out.get() == 10 and drawn == list(range(10))


def test_windowed_over_dataset():
    eng = make_engine()
    wf = Workflow("t", eng)
    ds = Dataset(ListMapper([3, 1, 4, 1, 5]), "vals")
    p = wf.atomic(lambda v: v * 10, name="scale")
    out = wf.foreach(ds, lambda v: p(v), window=2)
    wf.run()
    assert out.get() == [30, 10, 40, 10, 50]


def test_windowed_over_future_collection():
    eng = make_engine()
    wf = Workflow("t", eng)
    coll = eng.submit("make", lambda: list(range(6)), [])
    p = wf.atomic(lambda v: v + 1, name="inc")
    out = wf.foreach(coll, lambda v: p(v), window=2,
                     reduce=lambda a, b: a + b, init=0)
    wf.run()
    assert out.get() == sum(v + 1 for v in range(6))


def test_windowed_non_future_body_results():
    eng = make_engine()
    wf = Workflow("t", eng)
    out = wf.foreach(range(5), lambda m: m * 2, window=2)
    wf.run()
    assert out.get() == [0, 2, 4, 6, 8]


def test_windowed_empty_collection():
    eng = make_engine()
    wf = Workflow("t", eng)
    a = wf.foreach([], lambda m: m, window=2)
    b = wf.foreach([], lambda m: m, window=2, reduce=lambda x, y: x + y,
                   init=42)
    c = wf.foreach([], lambda m: m, window=2, keep_results=False)
    wf.run()
    assert a.get() == [] and b.get() == 42 and c.get() == 0


def test_window_argument_validation():
    eng = make_engine()
    wf = Workflow("t", eng)
    with pytest.raises(ValueError):
        wf.foreach([1], lambda m: m, window=0)
    with pytest.raises(ValueError):
        wf.foreach([1], lambda m: m, reduce=lambda a, b: a,
                   keep_results=True)


# ---------------------------------------------------------------------------
# failure paths
# ---------------------------------------------------------------------------

def test_failure_mid_window_fails_output_and_stops_refilling():
    eng = make_engine(concurrency=1)
    wf = Workflow("t", eng)
    submitted = []

    def body(m):
        submitted.append(m)
        if m == 4:
            return eng.submit("bad", lambda: 1 / 0, [])
        return eng.submit("job", None, duration=1.0)

    out = wf.foreach(range(100), body, window=2, keep_results=False)
    wf.run()
    assert out.failed
    with pytest.raises(ZeroDivisionError):
        out.get()
    # refilling stopped shortly after the failure: nowhere near 100
    assert len(submitted) <= 10


def test_body_exception_fails_output_eager_and_windowed():
    for window in (None, 2):
        eng = make_engine()
        wf = Workflow("t", eng)

        def body(m):
            if m == 1:
                raise RuntimeError("body blew up")
            return eng.submit("job", None, duration=1.0)

        out = wf.foreach(range(4), body, window=window)
        wf.run()
        assert out.failed
        with pytest.raises(RuntimeError, match="body blew up"):
            out.get()


def test_when_branch_exception_fails_output():
    eng = make_engine()
    wf = Workflow("t", eng)
    cond = eng.submit("cond", lambda: True, [])
    out = wf.when(cond, lambda: (_ for _ in ()).throw(RuntimeError("branch")))
    wf.run()
    assert out.failed
    with pytest.raises(RuntimeError, match="branch"):
        out.get()


def test_reducer_exception_fails_output_all_paths():
    """A raising reducer fails the output future in every mode — windowed
    and eager foreach, and streaming gather — instead of escaping the
    clock callback and stranding the future pending."""
    for window in (2, None):
        eng = make_engine()
        wf = Workflow("t", eng)
        out = wf.foreach(range(5),
                         lambda m: eng.submit("j", None, duration=1.0),
                         window=window, reduce=lambda a, b: 1 / 0, init=0)
        wf.run()
        assert out.failed
        with pytest.raises(ZeroDivisionError):
            out.get()

    eng = make_engine()
    wf = Workflow("t", eng)
    futs = [eng.submit("j", None, duration=1.0) for _ in range(3)]
    out = wf.gather(futs, reduce=lambda a, b: 1 / 0, init=0)
    wf.run()
    assert out.failed
    with pytest.raises(ZeroDivisionError):
        out.get()


# ---------------------------------------------------------------------------
# streaming gather
# ---------------------------------------------------------------------------

def test_gather_reduce_and_count():
    eng = make_engine()
    wf = Workflow("t", eng)
    p = wf.atomic(lambda v: v, name="id")
    futs = [p(v) for v in range(8)]
    total = wf.gather(list(futs), reduce=lambda a, b: a + b, init=0)
    count = wf.gather((f for f in futs), keep_results=False)
    wf.run()
    assert total.get() == sum(range(8))
    assert count.get() == 8


def test_gather_reduce_failure_propagates():
    eng = make_engine()
    wf = Workflow("t", eng)
    good = eng.submit("g", None, duration=1.0)
    bad = eng.submit("b", lambda: 1 / 0, [])
    out = wf.gather([good, bad], reduce=lambda a, b: a, init=None)
    wf.run()
    assert out.failed
    with pytest.raises(ZeroDivisionError):
        out.get()


# ---------------------------------------------------------------------------
# backpressure: the frontier tracks pool capacity
# ---------------------------------------------------------------------------

def test_engine_backpressure_surface():
    eng = make_engine(concurrency=2)
    assert eng.pool_capacity() == 2
    assert eng.inflight() == 0 and not eng.saturated()
    futs = [eng.submit("j", None, duration=1.0) for _ in range(10)]
    assert eng.inflight() == 10
    assert eng.dispatchable() == 10     # all dependency-free, at the site
    assert eng.saturated()              # 10 >= slack(2) x capacity(2)
    eng.run()
    assert eng.inflight() == 0 and not eng.saturated()
    assert all(f.resolved for f in futs)


def test_backpressure_throttles_frontier_below_window():
    """With a tiny pool, the standing frontier settles near slack x
    capacity — far below the (huge) window — and the run still completes
    at full pool utilization."""
    eng = make_engine(concurrency=2)
    wf = Workflow("t", eng)
    peak_inflight = [0]

    def body(m):
        f = eng.submit("job", None, duration=1.0)
        peak_inflight[0] = max(peak_inflight[0], eng.inflight())
        return f

    out = wf.foreach(range(60), body, window=1000, keep_results=False)
    wf.run()
    assert out.get() == 60
    # slack x capacity = 4; the frontier never ran meaningfully past it
    assert peak_inflight[0] <= 8
    # full utilization: 60 x 1s jobs on 2 slots take ~30 virtual seconds
    assert eng.clock.now() == pytest.approx(30.0)


def test_backpressure_waiter_resumes_expansion():
    """Refills parked on saturation resume via the completion-side waiter
    hook, not only at whole-body completions."""
    eng = make_engine(concurrency=2)
    wf = Workflow("t", eng)

    def body(m):
        # a two-stage pipeline per item: the second stage is blocked work
        a = eng.submit("a", None, duration=1.0)
        return eng.submit("b", None, [a], duration=1.0)

    out = wf.foreach(range(30), body, window=500, keep_results=False)
    wf.run()
    assert out.get() == 30
    assert not eng._bp_waiters          # no waiter leaked past the run


# ---------------------------------------------------------------------------
# future-GC contract: live futures bounded by the frontier
# ---------------------------------------------------------------------------

def test_windowed_run_keeps_live_futures_frontier_bounded():
    eng = make_engine(concurrency=2)
    live = weakref.WeakSet()
    orig_submit = eng.submit

    def tracking_submit(*args, **kwargs):
        f = orig_submit(*args, **kwargs)
        live.add(f)
        return f

    eng.submit = tracking_submit
    wf = Workflow("t", eng)
    peaks = []

    def body(m):
        f = eng.submit("job", None, duration=1.0)
        if m % 50 == 25:
            gc.collect()
            peaks.append(len(live))
        return f

    out = wf.foreach(range(400), body, window=8, keep_results=False)
    wf.run()
    assert out.get() == 400
    # eager expansion would hold ~400 live futures; the windowed frontier
    # stays O(window)
    assert peaks and max(peaks) <= 40
    gc.collect()
    assert len(live) <= 2


def test_completed_task_records_release_upstream_futures():
    eng = make_engine(concurrency=2)
    f1 = eng.submit("a", None, duration=1.0)
    f2 = eng.submit("b", None, [f1], duration=1.0)
    f3 = eng.submit("c", None, [f2], duration=1.0)
    r1, r2 = weakref.ref(f1), weakref.ref(f2)
    del f1, f2
    eng.run()
    assert f3.resolved
    gc.collect()
    assert r1() is None and r2() is None


# ---------------------------------------------------------------------------
# federated windowed runs
# ---------------------------------------------------------------------------

def _fed_sites(fed, per_shard=4):
    for shard in fed.shards:
        shard.local_site(concurrency=per_shard)


def test_federated_windowed_run_with_stealing():
    def run():
        fed = FederatedEngine(4, partitioner=skewed_partitioner(0.7),
                              steal=True)
        _fed_sites(fed)
        wf = Workflow("t", fed)
        p = wf.atomic(lambda m: m, name="job", duration=2.0)
        out = wf.foreach(range(300), lambda m: p(m), window=16,
                         reduce=lambda a, b: a + b, init=0)
        wf.run()
        return out.get(), fed.clock.now(), fed.stats()["per_shard_completed"]

    total, span, per_shard = run()
    assert total == sum(range(300))
    assert run() == (total, span, per_shard)    # deterministic replay
    assert all(c > 0 for c in per_shard)        # stealing spread the skew


def test_federated_windowed_proxy_maps_stay_bounded():
    fed = FederatedEngine(4)
    _fed_sites(fed)
    wf = Workflow("t", fed)
    shared = fed.submit("seed", None, duration=1.0)

    def body(m):
        a = fed.submit("a", None, [shared], duration=1.0)
        return fed.submit("b", None, [a], duration=1.0)

    high_water = [0]
    orig_proxy = fed._proxy

    def tracking_proxy(fut, consumer):
        p = orig_proxy(fut, consumer)
        high_water[0] = max(high_water[0], len(fed._proxies),
                            len(fed._owner))
        return p

    fed._proxy = tracking_proxy
    out = wf.foreach(range(200), body, window=8, keep_results=False)
    wf.run()
    assert out.get() == 200
    # ownership / proxy maps are pruned at resolution: bounded by the
    # in-flight frontier during the run, empty after it
    assert high_water[0] <= 120
    assert not fed._owner and not fed._proxies


def test_backpressure_waiter_fires_on_federation_attached_shard():
    """A workflow driven over one *shard* of a federation parks waiters on
    that shard engine — completions must still fire them (and not leave a
    stale callback behind)."""
    fed = FederatedEngine(2)
    _fed_sites(fed, per_shard=2)
    shard = fed.shards[0]
    wf = Workflow("t", shard)
    out = wf.foreach(range(40),
                     lambda m: shard.submit("j", None, duration=1.0),
                     window=500, keep_results=False)
    wf.run()
    assert out.get() == 40
    assert not shard._bp_waiters


def test_federated_backpressure_aggregates_shards():
    fed = FederatedEngine(2)
    _fed_sites(fed, per_shard=2)
    assert fed.pool_capacity() == 4
    assert not fed.saturated()
    futs = [fed.submit("j", None, duration=1.0) for _ in range(20)]
    assert fed.inflight() == 20
    assert fed.saturated()
    fed.run()
    assert fed.inflight() == 0 and not fed.saturated()
    assert all(f.resolved for f in futs)


# ---------------------------------------------------------------------------
# satellite: callable duration specs
# ---------------------------------------------------------------------------

def test_callable_duration_resolved_at_submit():
    eng = make_engine(concurrency=2)
    wf = Workflow("t", eng)
    p = wf.atomic(lambda m: m, name="job", duration=lambda m: float(m))
    p(5)
    p(3)
    wf.run()
    # durations 5 and 3 on two slots: makespan is max, not 0 (the seed
    # silently discarded callable specs)
    assert eng.clock.now() == pytest.approx(5.0)


def test_callable_duration_in_windowed_foreach():
    eng = make_engine(concurrency=1)
    wf = Workflow("t", eng)
    p = wf.atomic(lambda m: m, name="job", duration=lambda m: 1.0 + m % 2)
    out = wf.foreach(range(4), lambda m: p(m), window=2,
                     keep_results=False)
    wf.run()
    assert out.get() == 4
    assert eng.clock.now() == pytest.approx(1.0 + 2.0 + 1.0 + 2.0)


# ---------------------------------------------------------------------------
# satellite: affinity-aware federation partitioner
# ---------------------------------------------------------------------------

def test_inputs_partitioner_colocates_co_input_tasks():
    a = DataObject("archive_a.tar", 100e6)
    b = DataObject("archive_b.tar", 100e6)
    small = DataObject("params.cfg", 1e3)
    # same anchor input -> same shard, regardless of task key
    sa = {inputs_partitioner(f"t#{i}", 4, (a,)) for i in range(50)}
    sb = {inputs_partitioner(f"t#{i}", 4, (a, small)) for i in range(50)}
    assert len(sa) == 1 and sa == sb    # anchored on the largest input
    assert inputs_partitioner("x", 4, (b,)) == \
        inputs_partitioner("y", 4, (b, small))
    # no inputs: falls back to the key hash, identical to hash_partitioner
    for key in ("t#0", "t#1", "prep#9"):
        assert inputs_partitioner(key, 4) == hash_partitioner(key, 4)


def test_federated_engine_routes_by_declared_inputs():
    fed = FederatedEngine(4, partitioner=inputs_partitioner)
    _fed_sites(fed)
    wf = Workflow("t", fed)
    archives = [DataObject(f"mol{m}.arc", 50e6) for m in range(8)]
    p = wf.atomic(lambda m: m, name="analyze", duration=1.0,
                  inputs=lambda m: (archives[m % 8],))
    out = wf.foreach(range(64), lambda m: p(m), window=16,
                     keep_results=False)
    wf.run()
    assert out.get() == 64
    # every task sharing an archive landed on one shard: at most 8 distinct
    # (archive -> shard) routes were used, and re-running a molecule's
    # tasks cannot scatter.  With 8 archives over 4 shards each shard saw
    # only its archives' tasks, so totals are multiples of 8.
    per_shard = [e.tasks_submitted for e in fed.shards]
    assert sum(per_shard) == 64
    assert all(c % 8 == 0 for c in per_shard)
