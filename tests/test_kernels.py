"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles (interpret mode on CPU; the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 1, 1, 128, 64),
    (2, 4, 2, 256, 64),
    (1, 8, 1, 128, 32),      # MQA
    (2, 2, 2, 192, 64),      # odd block split
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Hq, Hkv, S, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    exp = ref.ref_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@settings(max_examples=15, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64, 128]),
    s_mult=st.integers(1, 3),
    window=st.sampled_from([0, 32, 96]),
)
def test_flash_attention_block_shape_property(bq, bk, s_mult, window):
    """Property: output is invariant to the kernel block decomposition."""
    S, D = 128 * s_mult, 32
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (1, 2, S, D))
    k = jax.random.normal(ks[1], (1, 2, S, D))
    v = jax.random.normal(ks[2], (1, 2, S, D))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=bq, block_k=bk)
    exp = ref.ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rg-lru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W,chunk,bw", [
    (1, 64, 128, 16, 128),
    (2, 128, 256, 32, 128),
    (2, 96, 128, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_matches_ref(B, S, W, chunk, bw, dtype):
    key = jax.random.PRNGKey(1)
    a = jax.random.uniform(key, (B, S, W), jnp.float32, 0.5, 0.999).astype(dtype)
    b = jax.random.normal(key, (B, S, W)).astype(dtype)
    h0 = jax.random.normal(key, (B, W))
    y, hf = ops.rglru_scan(a, b, h0, chunk=chunk, block_w=bw)
    ye, hfe = ref.ref_linear_scan(a.astype(jnp.float32),
                                  b.astype(jnp.float32), h0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfe),
                               **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32, 64]),
       bw=st.sampled_from([64, 128, 256]))
def test_rglru_chunking_property(chunk, bw):
    """Property: the recurrence result is invariant to chunk/block split."""
    key = jax.random.PRNGKey(7)
    a = jax.random.uniform(key, (2, 64, 256), jnp.float32, 0.2, 0.99)
    b = jax.random.normal(key, (2, 64, 256))
    h0 = jnp.zeros((2, 256))
    y, hf = ops.rglru_scan(a, b, h0, chunk=chunk, block_w=bw)
    ye, hfe = ref.ref_linear_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D,N,chunk,bd", [
    (1, 32, 64, 8, 8, 64),
    (2, 64, 128, 16, 16, 64),
    (1, 96, 64, 8, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_matches_ref(B, S, D, N, chunk, bd, dtype):
    key = jax.random.PRNGKey(2)
    u = jax.random.normal(key, (B, S, D)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, D))).astype(dtype)
    A = -jnp.exp(jax.random.normal(key, (D, N)) * 0.5)
    Bm = jax.random.normal(key, (B, S, N)).astype(dtype)
    Cm = jax.random.normal(key, (B, S, N)).astype(dtype)
    y, hf = ops.mamba_scan(u, dt, A, Bm, Cm, chunk=chunk, block_d=bd)
    ye, hfe = ref.ref_selective_scan(u, dt, A, Bm, Cm)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfe), **tol)


def test_mamba_scan_state_carry():
    """Splitting a sequence across two kernel calls with the carried state
    equals one long call (the decode/prefill contract)."""
    key = jax.random.PRNGKey(3)
    B, S, D, N = 1, 64, 32, 8
    u = jax.random.normal(key, (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, D)))
    A = -jnp.exp(jax.random.normal(key, (D, N)) * 0.5)
    Bm = jax.random.normal(key, (B, S, N))
    Cm = jax.random.normal(key, (B, S, N))
    y_full, h_full = ops.mamba_scan(u, dt, A, Bm, Cm, chunk=16, block_d=32)
    h = S // 2
    y1, h1 = ops.mamba_scan(u[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h],
                            chunk=16, block_d=32)
    y2, h2 = ops.mamba_scan(u[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:],
                            h0=h1, chunk=16, block_d=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-5, atol=1e-5)
